"""Batched serving engine: jitted prefill + decode with a static-shape KV
cache.  serve_step (one decode step) is what the decode_* dry-run shapes
lower; the engine adds the host-side request loop, greedy/temperature
sampling, and continuous batch slots.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import lm


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_seq: int,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            functools.partial(lm.prefill_fn, cfg),
            static_argnames=("max_seq",))
        self._decode = jax.jit(functools.partial(lm.decode_fn, cfg))

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits[:, -1] / self.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompt_tokens: np.ndarray, max_new_tokens: int,
                 extra: Optional[Dict[str, np.ndarray]] = None
                 ) -> np.ndarray:
        """prompt_tokens: (B, S) int32 (right-aligned, no padding support in
        this minimal loop).  Returns (B, max_new_tokens)."""
        b, s = prompt_tokens.shape
        assert s + max_new_tokens <= self.max_seq
        batch = {"tokens": jnp.asarray(prompt_tokens)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, caches = self._prefill(self.params, batch,
                                       max_seq=self.max_seq)
        out = []
        tok = self._sample(logits)
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok[:, None], caches,
                                          jnp.int32(s + i))
            tok = self._sample(logits)
        return np.stack(out, axis=1)
