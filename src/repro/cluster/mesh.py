"""Mesh placement layer (DESIGN.md §13.1).

A `MeshContext` wraps the process's JAX devices (CPU emulation via
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` gives N of them) and
owns the *placement* of catalog partitions onto them: round-robin over the
alive device slots, same convention as the DESIGN.md §5 ``('data',)`` axis.
Placement is physical-layer state only — it never appears in a logical
plan, so explain() output and plan fingerprints are byte-identical with
sharding on or off.

Device loss is modeled the way worker loss is in the runtime scheduler:
``kill_device(slot)`` marks the slot dead and bumps the placement
*generation*.  A dispatch that observes a generation change (or catches
`DeviceLost` from a chaos hook) rebuilds the placement over the survivors
and recomputes — results are identical because every mesh program computes
pure partial states from host-resident partitions (the lineage the
single-host path already has).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple


class DeviceLost(RuntimeError):
    """A mesh device died mid-dispatch (raised by chaos hooks; real device
    loss would surface as an XLA runtime error wrapped into this)."""

    def __init__(self, slot: int):
        super().__init__(f"mesh device slot {slot} lost")
        self.slot = slot


@dataclass(frozen=True)
class MeshPlacement:
    """Partition -> device-slot assignment for ONE dispatch: round-robin of
    `num_parts` partitions over the alive slots at `generation`."""
    generation: int
    alive_slots: Tuple[int, ...]
    device_of: Tuple[int, ...]          # partition ordinal -> alive-slot index
    parts_per_device: int               # padded per-device partition count

    @property
    def n_devices(self) -> int:
        return len(self.alive_slots)


class MeshContext:
    """Device pool + placement authority for mesh-sharded execution.

    Thread-safe: executors on server worker threads share one context.
    The jitted shard_map programs are cached per (generation, shape) key by
    `cluster.shard_exec`, keyed off `mesh()` which is itself cached per
    generation.
    """

    def __init__(self, max_devices: Optional[int] = None,
                 max_retries: int = 3, policy=None):
        import jax
        devs = list(jax.devices())
        if max_devices is not None:
            devs = devs[:max_devices]
        self.devices = devs
        self.alive: List[bool] = [True] * len(devs)
        self.generation = 0
        # the ResiliencePolicy owns the dispatch retry budget when given
        self.max_retries = (policy.mesh_max_retries if policy is not None
                            else max_retries)
        self.chaos = None   # core.faults.ChaosEngine, when installed
        self.lock = threading.RLock()
        # chaos hook: called at every dispatch with (ctx, dispatch_ordinal);
        # tests install a killer that calls kill_device / raises DeviceLost
        self.on_dispatch: Optional[Callable[["MeshContext", int], None]] = None
        self.dispatches = 0
        self.retries = 0                # dispatches re-run after device loss
        self._mesh_cache: Dict[int, object] = {}    # generation -> Mesh

    # -- device liveness ------------------------------------------------------

    def alive_slots(self) -> List[int]:
        with self.lock:
            return [i for i, a in enumerate(self.alive) if a]

    @property
    def n_alive(self) -> int:
        return len(self.alive_slots())

    def kill_device(self, slot: int) -> None:
        """Chaos: mark a device slot dead.  Every placement built at an
        older generation is stale; in-flight dispatches recompute over the
        survivors."""
        with self.lock:
            if not self.alive[slot]:
                return
            if sum(self.alive) == 1:
                raise RuntimeError("cannot kill the last mesh device")
            self.alive[slot] = False
            self.generation += 1

    def revive_all(self) -> None:
        with self.lock:
            if not all(self.alive):
                self.alive = [True] * len(self.devices)
                self.generation += 1

    # -- placement ------------------------------------------------------------

    def mesh(self):
        """1-D ('data',) mesh over the alive devices, cached per
        generation (shard_map program caches key off this object)."""
        from ..parallel import compat
        with self.lock:
            gen = self.generation
            m = self._mesh_cache.get(gen)
            if m is None:
                devs = [self.devices[i] for i in self.alive_slots()]
                m = compat.make_mesh((len(devs),), ("data",), devices=devs)
                self._mesh_cache = {gen: m}     # old generations are stale
            return m, gen

    def place(self, num_parts: int) -> MeshPlacement:
        """Round-robin `num_parts` catalog partitions over the alive
        slots.  `parts_per_device` is the padded per-device count (the
        shard_map leading axis is `n_devices * parts_per_device`)."""
        with self.lock:
            slots = tuple(self.alive_slots())
            n = len(slots)
            device_of = tuple(i % n for i in range(num_parts))
            per = max(1, -(-num_parts // n)) if num_parts else 1
            return MeshPlacement(self.generation, slots, device_of, per)

    # -- dispatch bookkeeping -------------------------------------------------

    def fire_dispatch(self) -> int:
        """Invoke the chaos hook (if any) and count the dispatch.  Returns
        the generation observed at dispatch start, so callers can detect a
        placement made stale *during* the dispatch."""
        with self.lock:
            ordinal = self.dispatches
            self.dispatches += 1
            gen = self.generation
        hook = self.on_dispatch
        if hook is not None:
            hook(self, ordinal)
        # chaos seam "mesh.dispatch": kill an alive device slot and raise
        # DeviceLost — the dispatch retry loop re-places over the survivors
        # and recomputes.  Only armed while >1 slot survives (killing the
        # last device would be unrecoverable, not chaos).
        chaos = self.chaos
        if chaos is not None and self.n_alive > 1:
            trip = chaos.fire("mesh.dispatch")
            if trip is not None:
                slots = self.alive_slots()
                victim = slots[trip.ordinal % len(slots)]
                try:
                    self.kill_device(victim)
                except RuntimeError:
                    pass        # raced another killer down to one slot
                raise DeviceLost(victim)
        return gen

    def stats(self) -> Dict[str, int]:
        with self.lock:
            return {"devices": len(self.devices), "alive": sum(self.alive),
                    "generation": self.generation,
                    "dispatches": self.dispatches, "retries": self.retries}
