"""Mesh-sharded compiled execution (DESIGN.md §13.1).

Two shard_map programs over the MeshContext's 1-D ``('data',)`` mesh:

- `mesh_colscan` — the fused filter+aggregate colscan of DESIGN.md §10 run
  as ONE compiled program over ALL placed partitions at once: the leading
  axis (device × partition-slot) is sharded ``P('data')``, each device
  reduces its own partitions' rows to ``[count, sum, min, max]`` partial
  states.  No collective is needed — the partial states feed the engine's
  standard shuffle/merge reduce, so the final result is computed by exactly
  the code path the single-host oracle uses.
- `mesh_group_exchange` — the compiled exchange of DESIGN.md §11 shipped
  ACROSS devices: each device bucket-assigns its local rows with the same
  radix hash the Pallas partitioner uses (`radix_partition.mix_u32` on
  host-folded uint32 key lanes), packs them into fixed-stride per-
  destination chunks, and an ``all_to_all`` collective moves every bucket
  to its owning device — the shuffle blocks never touch the BlockManager.
  A host-side mirror computes the exact (src, dst) bucket counts with the
  *same* hash to size the stride, and validity flags travel through the
  collective so receivers drop padding without trusting the mirror.

Padded dimensions round up to powers of two (`expr.next_pow2`), so each
program re-traces O(log n) times per mesh generation — the discipline the
compiled expression planner and reduce runners already follow.

Device loss: every public entry point re-reads the placement per attempt
and retries on `DeviceLost` (chaos hook) or a generation bump observed
mid-dispatch — recomputation from host-resident partitions, the same
lineage contract as worker loss in the runtime scheduler.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.expr import _x64, next_pow2
from ..kernels.radix_partition import fold_keys_u32, mix_u32
from .mesh import DeviceLost, MeshContext

# jitted program caches; keys include the Mesh object (cached per placement
# generation) and pow2-padded static dims, so entries stay O(log n)
_COLSCAN_PROGS: Dict[Tuple, object] = {}
_EXCHANGE_PROGS: Dict[Tuple, object] = {}


def _dispatch(ctx: MeshContext, run):
    """Run `run()` (which must re-read mesh + placement itself) with the
    device-loss retry contract."""
    last: Optional[BaseException] = None
    for _ in range(ctx.max_retries + 1):
        try:
            gen0 = ctx.fire_dispatch()
            out = run()
        except DeviceLost as e:
            last = e
            with ctx.lock:
                ctx.retries += 1
            continue
        if ctx.generation != gen0:
            # a device died while the program ran: the placement we used is
            # stale — recompute over the survivors
            with ctx.lock:
                ctx.retries += 1
            continue
        return out
    raise RuntimeError(
        f"mesh dispatch failed after {ctx.max_retries + 1} attempts") from last


# -- colscan under shard_map --------------------------------------------------

def _colscan_program(mesh, per: int, rows: int):
    key = (mesh, per, rows)
    fn = _COLSCAN_PROGS.get(key)
    if fn is None:
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def local(F, A, N, lo, hi):
            # F, A: [per, rows] float64; N: [per] valid-row counts
            pos = jnp.arange(rows, dtype=jnp.int64)[None, :]
            mask = (F >= lo) & (F <= hi) & (pos < N[:, None])
            cnt = jnp.sum(mask.astype(jnp.float64), axis=1)
            s = jnp.sum(jnp.where(mask, A, 0.0), axis=1)
            mn = jnp.min(jnp.where(mask, A, jnp.inf), axis=1)
            mx = jnp.max(jnp.where(mask, A, -jnp.inf), axis=1)
            return jnp.stack([cnt, s, mn, mx], axis=1)

        fn = jax.jit(shard_map(
            local, mesh=mesh,
            in_specs=(P("data"), P("data"), P("data"), P(), P()),
            out_specs=P("data")))
        _COLSCAN_PROGS[key] = fn
    return fn


def mesh_colscan(ctx: MeshContext, fcols: Sequence[np.ndarray],
                 acols: Sequence[np.ndarray], lo: float, hi: float
                 ) -> Tuple[List[Tuple[float, float, float, float]], Dict]:
    """Fused filter+aggregate over every placed partition in one program.
    Returns per-partition ``(count, sum, min, max)`` partial states (same
    contract as `_fused_colscan_fns`) plus a dispatch report."""

    def run():
        mesh, _ = ctx.mesh()
        placement = ctx.place(len(fcols))
        n_dev, per = placement.n_devices, next_pow2(
            placement.parts_per_device)
        rows = next_pow2(max([1] + [f.shape[0] for f in fcols]))
        F = np.zeros((n_dev * per, rows), np.float64)
        A = np.zeros((n_dev * per, rows), np.float64)
        N = np.zeros(n_dev * per, np.int64)
        slot_fill = [0] * n_dev
        rowmap = []
        for p, (f, a) in enumerate(zip(fcols, acols)):
            d = placement.device_of[p]
            r = d * per + slot_fill[d]
            slot_fill[d] += 1
            F[r, :f.shape[0]] = f
            A[r, :a.shape[0]] = a
            N[r] = f.shape[0]
            rowmap.append(r)
        with _x64():
            res = np.asarray(_colscan_program(mesh, per, rows)(
                F, A, N, np.float64(lo), np.float64(hi)))
        report = {"devices": n_dev, "partitions": len(fcols),
                  "generation": placement.generation}
        return [tuple(res[r]) for r in rowmap], report

    return _dispatch(ctx, run)


# -- cross-device radix exchange ----------------------------------------------

def _fold_u32_jnp(k):
    """Device twin of `radix_partition.fold_keys_u32`: xor of the int64
    halves, bit-identical to the host mirror."""
    import jax
    import jax.numpy as jnp
    u = jax.lax.bitcast_convert_type(k.astype(jnp.int64), jnp.uint64)
    return ((u ^ (u >> jnp.uint64(32)))
            & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)


def _exchange_program(mesh, n_dev: int, rows: int, stride: int,
                      vdtype: Optional[str]):
    key = (mesh, rows, stride, vdtype)
    fn = _EXCHANGE_PROGS.get(key)
    if fn is None:
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def local(K, V, F):
            # [1, rows] per-device blocks: keys, values, validity (int32)
            k, v, f = K[0], V[0], F[0]
            dest = (mix_u32(_fold_u32_jnp(k))
                    % jnp.uint32(n_dev)).astype(jnp.int32)
            dest = jnp.where(f > 0, dest, n_dev)    # padding -> sentinel
            lanes = jnp.arange(n_dev, dtype=jnp.int32)
            counts = jnp.sum((dest[None, :] == lanes[:, None]).astype(
                jnp.int64), axis=1)
            starts = jnp.concatenate(
                [jnp.cumsum(counts) - counts,
                 jnp.sum(counts, keepdims=True)])   # sentinel start
            order = jnp.argsort(dest)               # stable
            sd = dest[order]
            rank = jnp.arange(rows, dtype=jnp.int64) - starts[sd]
            target = sd.astype(jnp.int64) * stride + rank
            # pack each destination's rows into its fixed-stride chunk;
            # sentinel rows index past the buffer and drop
            outk = jnp.zeros(n_dev * stride, k.dtype).at[target].set(
                k[order], mode="drop")
            outv = jnp.zeros(n_dev * stride, v.dtype).at[target].set(
                v[order], mode="drop")
            outf = jnp.zeros(n_dev * stride, jnp.int32).at[target].set(
                f[order], mode="drop")
            ex = [jax.lax.all_to_all(x, "data", split_axis=0, concat_axis=0,
                                     tiled=True)
                  for x in (outk, outv, outf)]
            return ex[0][None], ex[1][None], ex[2][None]

        fn = jax.jit(shard_map(
            local, mesh=mesh,
            in_specs=(P("data", None),) * 3,
            out_specs=(P("data", None),) * 3))
        _EXCHANGE_PROGS[key] = fn
    return fn


def mesh_group_exchange(ctx: MeshContext, keys: Sequence[np.ndarray],
                        vals: Optional[Sequence[np.ndarray]]
                        ) -> Tuple[List[Tuple[np.ndarray,
                                              Optional[np.ndarray]]], Dict]:
    """Radix-exchange the placed partitions' (key, value) rows across
    devices: afterwards each device owns every row whose key hashes to it.
    Returns one ``(keys, values)`` pair per device (values is None when no
    value column was shipped) and a report with the exact (src, dst) bucket
    counts from the host mirror."""
    kdtype = keys[0].dtype if keys else np.dtype(np.int64)
    vdtype = (vals[0].dtype if vals is not None and len(vals)
              else np.dtype(np.float64))

    def run():
        mesh, _ = ctx.mesh()
        placement = ctx.place(len(keys))
        n_dev = placement.n_devices
        # per-device concat of the placed partitions' rows
        dev_keys: List[List[np.ndarray]] = [[] for _ in range(n_dev)]
        dev_vals: List[List[np.ndarray]] = [[] for _ in range(n_dev)]
        for p, k in enumerate(keys):
            d = placement.device_of[p]
            dev_keys[d].append(k)
            if vals is not None:
                dev_vals[d].append(vals[p])
        cat_k = [np.concatenate(ks).astype(np.int64) if ks
                 else np.zeros(0, np.int64) for ks in dev_keys]
        rows = next_pow2(max(1, max(k.shape[0] for k in cat_k)))
        K = np.zeros((n_dev, rows), np.int64)
        V = np.zeros((n_dev, rows), vdtype)
        Fv = np.zeros((n_dev, rows), np.int32)
        for d in range(n_dev):
            n = cat_k[d].shape[0]
            K[d, :n] = cat_k[d]
            if vals is not None and n:
                V[d, :n] = np.concatenate(dev_vals[d]).astype(
                    vdtype, copy=False)
            Fv[d, :n] = 1
        # host mirror: same fold + mix as the device program, to size the
        # per-(src,dst) chunk stride exactly
        counts = np.zeros((n_dev, n_dev), np.int64)
        for d in range(n_dev):
            dest = (mix_u32(fold_keys_u32(cat_k[d]))
                    % np.uint32(n_dev)).astype(np.int64)
            counts[d] = np.bincount(dest, minlength=n_dev)
        stride = next_pow2(max(1, int(counts.max())))
        with _x64():
            Kx, Vx, Fx = (
                np.asarray(x) for x in _exchange_program(
                    mesh, n_dev, rows, stride, str(vdtype))(K, V, Fv))
        out = []
        for d in range(n_dev):
            flags = Fx[d] > 0
            kd = Kx[d][flags].astype(kdtype, copy=False)
            vd = Vx[d][flags] if vals is not None else None
            out.append((kd, vd))
        shipped = int(counts.sum() - np.trace(counts))
        report = {"devices": n_dev, "counts": counts,
                  "shipped_rows": shipped,
                  "generation": placement.generation}
        return out, report

    return _dispatch(ctx, run)
