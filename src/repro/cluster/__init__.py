"""Cluster tier (DESIGN.md §13): mesh-sharded execution + replicated
SharkServer fleet.

Two independent scale-out axes over the single-host engine:

- `mesh` — a MeshContext places catalog partitions onto the devices of a
  JAX mesh and runs the compiled aggregate pipeline under shard_map; the
  compiled exchange ships radix-partition buckets *across devices* with
  all_to_all instead of through one BlockManager.  Device loss mid-query
  re-places and recomputes (`DeviceLost` -> new placement generation).
- `fleet` — N full SharkServer replicas behind a routing frontend with one
  catalog-epoch protocol, so plan-fingerprint result caches stay coherent
  across replicas; a replica dying mid-query re-routes to a survivor and
  recomputes from that replica's own lineage.
"""

from .mesh import DeviceLost, MeshContext, MeshPlacement
from .fleet import FleetEpochError, ReplicaLost, SharkFleet

__all__ = ["DeviceLost", "MeshContext", "MeshPlacement",
           "FleetEpochError", "ReplicaLost", "SharkFleet"]
