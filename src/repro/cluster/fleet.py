"""Replicated SharkServer fleet (DESIGN.md §13.2).

N full SharkServer replicas — each with its own workers, block store,
memory budget, and result cache — behind a routing frontend:

    fleet = SharkFleet(num_replicas=4, routing="least_loaded", ...)
    fleet.create_table("rankings", schema, data)     # fanned to every replica
    h = fleet.submit("SELECT ...")                   # routed, async
    fleet.kill_replica(2)                            # chaos: h re-routes

Routing is round-robin or least-loaded (the replica scheduler's queued +
in-flight query count).  Base tables and DDL fan out to every replica under
one DDL lock, and the fleet runs ONE catalog-epoch protocol across them:
after a DDL lands everywhere, every replica's catalog version for the table
is forced to the fleet-wide maximum (`Catalog.adopt_version`), firing each
replica's invalidation listeners.  Plan fingerprints hash the optimized
plan text plus the versions of the tables it reads, so with aligned
versions the SAME query has the SAME fingerprint on every replica — a
result cached on one replica can never be served stale on another, and a
DDL invalidates the entry fleet-wide in one epoch bump.

Replica loss: `kill_replica(i)` marks the replica dead.  A `FleetHandle`
whose query is in flight there re-submits on a survivor, which recomputes
from its own replicated lineage — results are identical to the failure-free
run because every replica holds the same deterministic base tables.  The
dead replica's in-progress work still drains in the background (its
scheduler threads finish and release their shuffle blocks), so nothing
leaks from the shared store of a replica that died mid-query.
"""

from __future__ import annotations

import copy
import threading
import time
from typing import Dict, List, Optional, Union

import numpy as np

from ..core.plan import Node
from ..core.resilience import CircuitBreaker, ResiliencePolicy
from ..core.sql import CreateStmt, parse
from ..core.types import Schema
from ..server.server import SharkServer


class ReplicaLost(RuntimeError):
    """No alive replica can serve the query."""


class FleetEpochError(RuntimeError):
    """Replica catalog versions diverged after a DDL fan-out."""


class _Replica:
    __slots__ = ("index", "server", "alive", "served")

    def __init__(self, index: int, server: SharkServer):
        self.index = index
        self.server = server
        self.alive = True
        self.served = 0


class FleetHandle:
    """Async handle that survives replica loss: `result()` re-routes to a
    survivor if the replica serving the query dies before finishing.  Poll
    cadence and reroute budget come from the fleet's ResiliencePolicy; a
    retryable infrastructure error from an ALIVE replica also reroutes
    (scoring its circuit breaker), while deterministic application errors
    surface immediately — rerouting them would just fail N times."""

    def __init__(self, fleet: "SharkFleet", query, client: str):
        self._fleet = fleet
        self._query = query
        self._client = client
        self.reroutes = 0
        self._replica, self._inner = fleet._submit_on(None, query, client)

    @property
    def replica_index(self) -> int:
        return self._replica.index

    def done(self) -> bool:
        return self._inner.done()

    def result(self, timeout: Optional[float] = None):
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        policy = self._fleet.policy
        while True:
            try:
                out = self._inner.result(timeout=policy.fleet_poll_s)
            except TimeoutError:
                # chaos seam "fleet.poll": the serving replica dies
                # mid-query (only while a survivor exists to reroute to)
                chaos = self._fleet.chaos
                if (chaos is not None and self._replica.alive
                        and not self._inner.done()
                        and len(self._fleet.alive_replicas()) > 1):
                    if chaos.fire("fleet.poll") is not None:
                        self._fleet.kill_replica(self._replica.index)
                if not self._replica.alive and not self._inner.done():
                    self._reroute()     # died mid-query: recompute elsewhere
                    continue
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError("fleet query timed out")
            except Exception as exc:
                if not self._replica.alive:
                    # the dying replica surfaced an error — its failure must
                    # not become the fleet's answer
                    self._reroute()
                    continue
                self._fleet._record_failure(self._replica)
                if (policy.is_retryable(exc)
                        and self.reroutes < policy.fleet_reroute_limit):
                    self._reroute()
                    continue
                raise
            else:
                self._fleet._record_success(self._replica)
                return out

    def _reroute(self) -> None:
        self.reroutes += 1
        with self._fleet._lock:
            self._fleet.reroutes += 1
        self._replica, self._inner = self._fleet._submit_on(
            self._replica, self._query, self._client)


class SharkFleet:
    def __init__(self, num_replicas: int = 2, routing: str = "round_robin",
                 mesh_factory=None, resilience: Optional[ResiliencePolicy] = None,
                 **server_kw):
        """`mesh_factory`: optional callable `index -> MeshContext | None`
        giving each replica its OWN device mesh (DESIGN.md §13.3) — the
        composed cluster tier: a fleet of replicated servers, each of which
        shards its map stages across an intra-replica mesh.  A plain
        `mesh=` in `server_kw` would share one mesh object (and its
        health/retry state) across replicas; the factory keeps replica
        failure domains independent.

        `resilience`: ResiliencePolicy shared by the routing layer (poll
        cadence, reroute budget, circuit breakers) and every replica
        server's scheduler/storage."""
        assert routing in ("round_robin", "least_loaded"), routing
        self.routing = routing
        self.policy = resilience if resilience is not None else ResiliencePolicy()
        if resilience is not None:
            server_kw.setdefault("resilience", resilience)
        if mesh_factory is not None:
            assert "mesh" not in server_kw, "pass mesh_factory OR mesh"
            self.replicas = [
                _Replica(i, SharkServer(mesh=mesh_factory(i), **server_kw))
                for i in range(num_replicas)]
        else:
            self.replicas = [_Replica(i, SharkServer(**server_kw))
                             for i in range(num_replicas)]
        # one circuit breaker per replica: repeated failures open it and
        # routing skips the replica until its reset window elapses
        self.breakers = {r.index: CircuitBreaker(self.policy)
                         for r in self.replicas}
        self.chaos = None   # core.faults.ChaosEngine, when installed
        self._lock = threading.Lock()
        self._ddl_lock = threading.Lock()
        self._rr = 0
        self.reroutes = 0

    # -- routing --------------------------------------------------------------

    def alive_replicas(self) -> List[_Replica]:
        return [r for r in self.replicas if r.alive]

    def _pick(self, exclude: Optional[_Replica]) -> _Replica:
        cands = [r for r in self.replicas if r.alive and r is not exclude]
        if not cands:
            cands = self.alive_replicas()
        if not cands:
            raise ReplicaLost("every replica is dead")
        # health-probe routing: skip replicas whose breaker is OPEN; if every
        # candidate's breaker is open, route anyway (degraded beats dead)
        now = time.monotonic()
        routable = [r for r in cands if self.breakers[r.index].routable(now)]
        if routable:
            cands = routable
        if self.routing == "least_loaded":
            with self._lock:
                r = min(cands,
                        key=lambda c: (c.server.scheduler.load(), c.index))
        else:
            with self._lock:
                r = cands[self._rr % len(cands)]
                self._rr += 1
        self.breakers[r.index].on_route(now)    # consume half-open probe slot
        return r

    def _submit_on(self, exclude: Optional[_Replica], query, client: str):
        r = self._pick(exclude)
        # chaos seam "fleet.submit": the picked replica dies between routing
        # and submission (only while a survivor exists) — re-pick excluding it
        chaos = self.chaos
        if chaos is not None and len(self.alive_replicas()) > 1:
            trip = chaos.fire("fleet.submit")
            if trip is not None:
                try:
                    self.kill_replica(r.index)
                except RuntimeError:
                    pass        # raced down to one replica
                else:
                    self._record_failure(r)
                    r = self._pick(r)
        # plan objects are mutated by optimize(); each replica gets its own
        q = copy.deepcopy(query) if isinstance(query, Node) else query
        handle = r.server.submit(q, client=client)
        with self._lock:
            r.served += 1
        return r, handle

    # -- replica health ------------------------------------------------------

    def _record_failure(self, replica: _Replica) -> None:
        self.breakers[replica.index].record_failure(time.monotonic())

    def _record_success(self, replica: _Replica) -> None:
        self.breakers[replica.index].record_success()

    # -- queries --------------------------------------------------------------

    def submit(self, query: Union[str, Node], client: str = "default"
               ) -> FleetHandle:
        return FleetHandle(self, query, client)

    def sql(self, sql: str, client: str = "default"):
        stmt = parse(sql)
        if isinstance(stmt, CreateStmt):
            return self._ddl(sql, stmt, client)
        return self.submit(sql, client=client).result()

    def sql_np(self, sql: str, client: str = "default"):
        return self.sql(sql, client=client).to_numpy()

    # -- warehouse / epoch protocol -------------------------------------------

    def create_table(self, name: str, schema: Schema,
                     data: Dict[str, np.ndarray],
                     num_partitions: Optional[int] = None,
                     distribute_by: Optional[str] = None) -> None:
        """Load the same base table into every alive replica and align
        catalog epochs — the replicas must be indistinguishable sources of
        truth for the routing layer."""
        with self._ddl_lock:
            for r in self.alive_replicas():
                r.server.create_table(name, schema, data,
                                      num_partitions=num_partitions,
                                      distribute_by=distribute_by)
            self._align_epochs(name)

    def _ddl(self, sql: str, stmt: CreateStmt, client: str):
        """CTAS fan-out: every replica executes the (deterministic) DDL so
        their derived tables are identical, then epochs align fleet-wide."""
        with self._ddl_lock:
            results = [r.server.sql(sql, client=client)
                       for r in self.alive_replicas()]
            self._align_epochs(stmt.name)
            return results[0]

    def _align_epochs(self, name: str) -> None:
        """One epoch protocol across replicas: force every alive replica's
        version of `name` to the fleet-wide maximum.  `adopt_version` fires
        the replica's catalog listeners, so result-cache entries reading
        the table invalidate everywhere in the same logical epoch."""
        alive = self.alive_replicas()
        target = max(r.server.catalog.version(name) for r in alive)
        for r in alive:
            if r.server.catalog.version(name) != target:
                r.server.catalog.adopt_version(name, target)
        versions = {r.server.catalog.version(name) for r in alive}
        if len(versions) != 1:
            raise FleetEpochError(
                f"replica versions diverged for {name!r}: {versions}")

    def epochs(self, name: str) -> List[int]:
        return [r.server.catalog.version(name) for r in self.alive_replicas()]

    # -- chaos / lifecycle ----------------------------------------------------

    def kill_replica(self, index: int) -> None:
        """Chaos: the replica stops receiving queries; in-flight FleetHandles
        bound to it re-route to survivors.  Its scheduler threads drain in
        the background, releasing per-query shuffle blocks as usual."""
        r = self.replicas[index]
        if not r.alive:
            return
        if len(self.alive_replicas()) == 1:
            raise RuntimeError("cannot kill the last replica")
        r.alive = False

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "replicas": len(self.replicas),
                "alive": len(self.alive_replicas()),
                "reroutes": self.reroutes,
                "served": {r.index: r.served for r in self.replicas},
                "load": {r.index: r.server.scheduler.load()
                         for r in self.alive_replicas()},
                "breakers": {i: b.stats() for i, b in self.breakers.items()},
            }

    def describe_resilience(self) -> str:
        lines = [f"fleet: {len(self.alive_replicas())}/{len(self.replicas)} "
                 f"alive, reroutes={self.reroutes}"]
        for i, b in sorted(self.breakers.items()):
            s = b.stats()
            if s["opens"] or s["state"] != "closed":
                lines.append(f"  replica {i}: breaker {s['state']} "
                             f"(opens={s['opens']} closes={s['closes']})")
        return "\n".join(lines)

    def shutdown(self) -> None:
        for r in self.replicas:
            r.server.shutdown()
