"""Plan-fingerprint query result cache (DESIGN.md §6.4).

Interactive warehouse traffic is heavily repetitive — the same dashboard
aggregates hit the warehouse from many analysts.  The server caches *final
query results* keyed by a fingerprint of the optimized logical plan plus
the catalog versions of every base table the plan reads:

    fingerprint = sha1(explain(optimized_plan) | table@version, ...)

Two queries that bind+optimize to the same plan over the same table
versions share one entry, regardless of SQL text differences — and
regardless of *surface*: a fluent SharkFrame query submits its bound plan
object and lands on the same fingerprint as its SQL-text twin, because
both surfaces emit identical logical plans (core/frame.py, DESIGN.md §7)
and the fingerprint hashes the optimized plan, not query text.  Catalog
epochs make invalidation exact: any CREATE TABLE / load / drop bumps the
mutated table's version, which (a) changes the fingerprint of future
queries, and (b) fires a subscription that eagerly drops entries depending
on the table.  Entry bytes are charged to the unified MemoryManager budget
and evicted LRU (after cached partitions — results are small and precious).
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.catalog import Catalog
from ..core.physical import ExecResult
from ..core.plan import Node, ScanNode, explain


def plan_tables(node: Node) -> List[str]:
    """Base tables a plan reads, sorted and de-duplicated."""
    out = set()

    def walk(n: Node):
        if isinstance(n, ScanNode):
            out.add(n.table)
        for ch in n.children():
            walk(ch)

    walk(node)
    return sorted(out)


def plan_fingerprint(node: Node, catalog: Catalog
                     ) -> Tuple[str, Dict[str, int]]:
    """(fingerprint, {table: version}) for an *optimized* plan."""
    deps = {t: catalog.version(t) for t in plan_tables(node)}
    text = explain(node) + "|" + ",".join(
        f"{t}@{v}" for t, v in sorted(deps.items()))
    return hashlib.sha1(text.encode()).hexdigest(), deps


@dataclass
class CacheEntry:
    result: ExecResult
    nbytes: int
    deps: Dict[str, int]


class ResultCache:
    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._lock = threading.RLock()
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        self._nbytes = 0
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.invalidations = 0
        self.evictions = 0

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._nbytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, fingerprint: str, catalog: Catalog) -> Optional[ExecResult]:
        with self._lock:
            entry = self._entries.get(fingerprint)
            if entry is not None:
                # versions are baked into the fingerprint, but re-validate in
                # case a mutation slipped between bind and lookup
                if all(catalog.version(t) == v
                       for t, v in entry.deps.items()):
                    self._entries.move_to_end(fingerprint)
                    self.hits += 1
                    return entry.result
                self._drop(fingerprint)
                self.invalidations += 1
            self.misses += 1
            return None

    def put(self, fingerprint: str, result: ExecResult,
            deps: Dict[str, int]) -> None:
        nbytes = int(sum(b.nbytes for b in result.batches))
        with self._lock:
            if fingerprint in self._entries:
                self._drop(fingerprint)
            self._entries[fingerprint] = CacheEntry(result, nbytes, deps)
            self._nbytes += nbytes
            self.puts += 1
            while len(self._entries) > self.max_entries:
                self.evict_lru()

    def invalidate_table(self, name: str) -> int:
        """Drop every entry whose plan read `name`; returns count dropped."""
        with self._lock:
            stale = [fp for fp, e in self._entries.items() if name in e.deps]
            for fp in stale:
                self._drop(fp)
            self.invalidations += len(stale)
            return len(stale)

    def evict_lru(self) -> int:
        """Drop the least-recently-used entry; returns bytes freed."""
        with self._lock:
            if not self._entries:
                return 0
            fp = next(iter(self._entries))
            freed = self._entries[fp].nbytes
            self._drop(fp)
            self.evictions += 1
            return freed

    def _drop(self, fingerprint: str) -> None:
        entry = self._entries.pop(fingerprint, None)
        if entry is not None:
            self._nbytes -= entry.nbytes

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._entries), "nbytes": self._nbytes,
                    "hits": self.hits, "misses": self.misses,
                    "puts": self.puts, "evictions": self.evictions,
                    "invalidations": self.invalidations}
