"""Unified memory manager for the server tier (DESIGN.md §6.3).

Shark's cached tables are a *cache*, not primary storage (paper §3.2): any
cached partition can be dropped under memory pressure and transparently
recomputed from RDD lineage on the next access.  The seed runtime never
evicted, so that fallback path was dead code.  The MemoryManager makes it
live: it does unified byte accounting over everything the BlockManager
holds (cached partitions + in-flight shuffle output) plus the query result
cache, and enforces a configurable budget.

The budget governs *evictable cache bytes* — cached partition blocks plus
result-cache entries.  Shuffle map outputs are working memory, not cache:
a running reducer holds a fetch dependency on them, so *dropping* them
would only trade eviction for immediate lineage recovery churn.  They are
accounted and reported (`working_bytes`), and the server releases them
deterministically when their query completes (`BlockManager.drop_shuffle`);
a worker death dropping them mid-query is already handled by the
scheduler's lineage recovery.  With a spill-mode StorageManager attached,
however, the working set obeys the budget too: when cache eviction alone
cannot satisfy it, shuffle blocks are *spilled* (largest first) to
checksummed segments and fault back in on fetch — a lost segment degrades
to FetchFailed -> lineage recompute, the same contract as everything else.

Eviction policy (deterministic, documented order):
  1. cached partition blocks, least-recently-used first — cheapest to hold
     wrong and always recomputable from lineage;
  2. query-result-cache entries, LRU — tiny (final aggregates), so they are
     evicted only when partition eviction alone cannot satisfy the budget;
  3. memoized decode caches (HOT -> WARM, first half): pure derived state
     that re-materializes on the next decode;
  4. with a StorageManager attached (DESIGN.md §12), the storage-hierarchy
     rungs: adaptive recompression of resident catalog partitions
     (WARM, second half), then spilling the coldest partition to disk
     (COLD) — least-recently-scanned first.

If the just-inserted partition alone exceeds what the budget can hold even
after evicting everything else, it is itself dropped — a cache-admission
*bypass*: the query that computed it already has the batch in hand, so
correctness is unaffected.

Accounting: `cache_bytes()` always includes the memoized decode caches
(they are real memory, not free), and — when a StorageManager is attached —
the catalog's resident encoded bytes, since the storage tier can actually
release those.  Spill-file bytes live on disk, not in memory: they are
reported (`spill_bytes`) but never counted against the memory budget.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Set, Tuple

from ..core.runtime import BlockManager


class MemoryManager:
    def __init__(self, block_manager: BlockManager,
                 budget_bytes: Optional[int] = None):
        self.bm = block_manager
        self.budget_bytes = budget_bytes
        self.lock = threading.RLock()
        self._result_cache = None  # attached by the server
        self._evicted: Set[Tuple] = set()
        # counters (all monotonic; exposed via stats())
        self.evictions = 0
        self.evicted_bytes = 0
        self.recomputes = 0
        self.result_evictions = 0
        self.bypasses = 0
        self.over_budget_events = 0
        self.decode_cache_drops = 0
        self.decode_cache_dropped_bytes = 0
        self.chaos_pressure_drops = 0
        self._catalog = None
        self.storage = None        # core.storage.StorageManager, optional
        self.chaos = None          # core.faults.ChaosEngine, when installed
        self.bm.memory_manager = self

    def attach_result_cache(self, result_cache) -> None:
        self._result_cache = result_cache

    def attach_catalog(self, catalog) -> None:
        """Register the catalog whose tables' memoized decode caches
        (`Encoded._decoded`, see core/compression.py) this manager may
        release under pressure."""
        self._catalog = catalog

    def attach_storage(self, storage) -> None:
        """Attach the out-of-core storage tier (DESIGN.md §12): enables the
        recompression and spill rungs of `enforce()` and adds the catalog's
        resident encoded bytes to the governed budget.  In spill mode the
        BlockManager gains the shuffle spill/fault path too (drop mode
        keeps shuffle output pinned — dropping it mid-query just forces
        recompute storms)."""
        self.storage = storage
        if storage is not None and storage.mode == "spill":
            self.bm.shuffle_storage = storage

    def drop_decoded_caches(self) -> int:
        """Release every catalog table's memoized decode cache — pure
        derived state that re-materializes on the next decode.  Returns
        bytes freed."""
        cat = getattr(self, "_catalog", None)
        if cat is None:
            return 0
        freed = 0
        for table in list(cat._tables.values()):
            freed += table.drop_decoded()
        if freed:
            self.decode_cache_drops += 1
            self.decode_cache_dropped_bytes += freed
        return freed

    # -- accounting ----------------------------------------------------------

    def accounted_bytes(self) -> int:
        """Everything tracked: cache bytes + in-flight shuffle output."""
        rc = self._result_cache
        return (self.bm.nbytes() + (rc.nbytes if rc is not None else 0)
                + self.decoded_cache_bytes() + self.catalog_resident_bytes())

    def decoded_cache_bytes(self) -> int:
        """Memoized decode caches across catalog tables — real memory the
        budget must govern (historically unaccounted)."""
        cat = self._catalog
        if cat is None:
            return 0
        return sum(t.decoded_cache_nbytes for t in list(cat._tables.values()))

    def catalog_resident_bytes(self) -> int:
        """Resident encoded bytes of catalog tables.  Governed only when a
        storage tier is attached — without one these bytes are primary
        storage the manager cannot release, so counting them would just
        burn the budget on unevictable state."""
        if self.storage is None or self._catalog is None:
            return 0
        return sum(t.resident_nbytes
                   for t in list(self._catalog._tables.values()))

    def cache_bytes(self) -> int:
        """Evictable bytes the budget governs: partition blocks + results +
        decode memos (+ catalog resident bytes when spillable)."""
        rc = self._result_cache
        return (self.bm.part_bytes + (rc.nbytes if rc is not None else 0)
                + self.decoded_cache_bytes() + self.catalog_resident_bytes())

    # -- BlockManager hooks ---------------------------------------------------

    def on_put(self, key: Tuple) -> None:
        """A block was just inserted: enforce the budget, protecting it."""
        with self.lock:
            self._evicted.discard(key)
        self.enforce(protect=key)

    def on_miss(self, key: Tuple) -> None:
        """A cached-partition read missed.  If we evicted that block, this
        miss is the paper's recompute-from-lineage fallback in action."""
        with self.lock:
            if key in self._evicted:
                self._evicted.discard(key)
                self.recomputes += 1

    # -- enforcement ----------------------------------------------------------

    def enforce(self, protect: Optional[Tuple] = None) -> None:
        # chaos seam "memory.enforce": simulated memory pressure drops one
        # unprotected LRU cached partition — always recoverable (cached
        # partitions recompute from lineage on the next miss, exactly the
        # real eviction path below)
        if self.chaos is not None:
            trip = self.chaos.fire("memory.enforce")
            if trip is not None:
                with self.lock:
                    for key in self.bm.lru_partition_keys():
                        if key == protect:
                            continue
                        freed = self.bm.drop_block(key)
                        if freed:
                            self.evictions += 1
                            self.evicted_bytes += freed
                            self.chaos_pressure_drops += 1
                            self._evicted.add(key)
                        break
        if self.budget_bytes is None:
            return
        with self.lock:
            while self.cache_bytes() > self.budget_bytes:
                victim = None
                for key in self.bm.lru_partition_keys():
                    if key != protect:
                        victim = key
                        break
                if victim is not None:
                    freed = self.bm.drop_block(victim)
                    if freed:
                        self.evictions += 1
                        self.evicted_bytes += freed
                        self._evicted.add(victim)
                    continue
                rc = self._result_cache
                if rc is not None and rc.nbytes > 0:
                    if rc.evict_lru() > 0:
                        self.result_evictions += 1
                        continue
                # HOT -> WARM, first half: release the column store's
                # memoized decode caches (derived state that re-materializes
                # on the next decode)
                if self.drop_decoded_caches() > 0:
                    continue
                if self.storage is not None:
                    # WARM, second half: adaptively recompress resident
                    # catalog partitions (RLE / BITPACK / FOR from stats)
                    if self._recompress_pass() > 0:
                        continue
                    # WARM -> COLD: spill the least-recently-scanned
                    # partition to disk (or drop it, in drop mode)
                    if self._spill_coldest() > 0:
                        continue
                if (protect is not None and protect[0] == "part"
                        and protect in self.bm.sizes):
                    # the new block alone exceeds the budget: refuse
                    # admission rather than blow it
                    self.bm.drop_block(protect)
                    self.bypasses += 1
                    self._evicted.add(protect)
                self.over_budget_events += (
                    self.cache_bytes() > self.budget_bytes)
                break
            self._enforce_working_set(protect)

    def _enforce_working_set(self, protect: Optional[Tuple]) -> None:
        """Working-set rung: with a spill-mode storage tier attached, total
        accounted bytes (cache + shuffle output) obey the budget too —
        shuffle blocks spill largest-first and fault back in on fetch.
        Runs after the cache rungs so catalog state always yields before
        mid-query working memory does."""
        if (self.storage is None or self.storage.mode != "spill"
                or self.bm.shuffle_storage is None):
            return
        if self.accounted_bytes() <= self.budget_bytes:
            return
        for key in self.bm.shuffle_spill_candidates():
            if key == protect:
                continue
            self.bm.spill_shuffle_block(key)
            if self.accounted_bytes() <= self.budget_bytes:
                return

    # -- storage-hierarchy rungs (DESIGN.md §12) ------------------------------

    def _recompress_pass(self) -> int:
        """One WARM pass: recompress every resident catalog partition.
        Idempotent — a second pass over already-recompressed blocks frees
        nothing, so enforce() falls through to the spill rung."""
        cat = self._catalog
        if cat is None:
            return 0
        freed = 0
        for table in list(cat._tables.values()):
            for part in table.partitions:
                if part.resident:
                    freed += self.storage.recompress_partition(part)
        return freed

    def _spill_coldest(self) -> int:
        """One COLD transition: evict the least-recently-scanned resident
        catalog partition.  Lineage-bearing partitions go first (their
        recovery story is complete even if the segment is later lost); in
        drop mode they are the only candidates, since dropping without
        lineage would lose data outright."""
        cat = self._catalog
        if cat is None:
            return 0
        candidates = []
        for name, table in list(cat._tables.items()):
            for part in table.partitions:
                if part.resident and part.resident_nbytes > 0:
                    candidates.append((part.lineage is None,
                                       part.last_access, name, part))
        if self.storage.mode == "drop":
            candidates = [c for c in candidates if not c[0]]
        if not candidates:
            return 0
        _, _, name, part = min(candidates, key=lambda c: (c[0], c[1]))
        return self.storage.evict(name, part)

    # -- reporting -------------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        rc = self._result_cache
        part_bytes = self.bm.part_bytes
        st = self.storage.stats() if self.storage is not None else {}
        return {
            "budget_bytes": self.budget_bytes or 0,
            "partition_bytes": part_bytes,
            "working_bytes": self.bm.nbytes() - part_bytes,  # shuffle
            "result_cache_bytes": rc.nbytes if rc is not None else 0,
            "decoded_cache_bytes": self.decoded_cache_bytes(),
            "catalog_resident_bytes": self.catalog_resident_bytes(),
            "cache_bytes": self.cache_bytes(),
            "accounted_bytes": self.accounted_bytes(),
            "partition_hits": self.bm.part_hits,
            "partition_misses": self.bm.part_misses,
            "evictions": self.evictions,
            "evicted_bytes": self.evicted_bytes,
            "recomputes": self.recomputes,
            "result_evictions": self.result_evictions,
            "bypasses": self.bypasses,
            "over_budget_events": self.over_budget_events,
            "decode_cache_drops": self.decode_cache_drops,
            "decode_cache_dropped_bytes": self.decode_cache_dropped_bytes,
            "chaos_pressure_drops": self.chaos_pressure_drops,
            # storage tier (zeros when no StorageManager is attached, so
            # BENCH_concurrent.json always carries the keys)
            "spills": st.get("spills", 0),
            "spill_bytes": st.get("spill_bytes", 0),
            "spill_reads": st.get("spill_reads", 0),
            "recompressions": st.get("recompressions", 0),
            "lineage_faults": st.get("lineage_faults", 0),
            "shuffle_spills": st.get("shuffle_spills", 0),
            "shuffle_faults": st.get("shuffle_faults", 0),
            "shuffle_lost": st.get("shuffle_lost", 0),
        }
