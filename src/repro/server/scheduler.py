"""Weighted fair-share query scheduler with admission control (DESIGN.md §6.2).

Many client sessions share one warehouse; a scan-heavy tenant must not
starve interactive ones.  Classic weighted fair queuing over *measured
execution time*: each client carries a virtual time

    vtime += elapsed_seconds / weight

and the dispatcher always runs the backlogged client with the smallest
vtime.  A weight-2 client therefore receives twice the execution share of a
weight-1 client under contention, and an idle client re-entering the system
is reset to the current virtual floor so it cannot monopolize the pool with
banked credit.

Admission control bounds the in-flight work: at most `max_concurrent`
queries execute at once (the worker pool size) and at most
`max_queue_depth` queries may wait.  A submit over the limit either blocks
(backpressure) until space frees or a timeout expires, or fails fast with
`AdmissionError` when `block=False`.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Dict, Optional, Tuple


class AdmissionError(RuntimeError):
    """Queue full: the server refused the query (backpressure)."""


class QueryHandle:
    """Async handle for a submitted query (a tiny Future with timings).

    A query is either SQL text (`sql`) or a bound logical plan (`plan`,
    a `core.plan.Node` — what `SharkFrame.collect()` submits).  Exactly one
    of the two is set; both run through the same admission control, fair
    scheduling, and plan-fingerprint result cache."""

    QUEUED, RUNNING, DONE, FAILED = "queued", "running", "done", "failed"

    def __init__(self, sql: Optional[str], client: str, plan=None):
        assert (sql is None) != (plan is None), \
            "QueryHandle takes SQL text or a logical plan, not both"
        self.sql = sql
        self.plan = plan
        self.client = client
        self.status = self.QUEUED
        self.cached = False          # served from the result cache
        self.submitted = time.monotonic()
        self.started: Optional[float] = None
        self.finished: Optional[float] = None
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None

    @property
    def describe(self) -> str:
        return self.sql if self.sql is not None else f"<plan {self.plan!r}>"

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"query not finished: {self.describe!r}")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def wait_s(self) -> float:
        return (self.started or self.submitted) - self.submitted

    @property
    def run_s(self) -> float:
        if self.started is None or self.finished is None:
            return 0.0
        return self.finished - self.started

    @property
    def latency_s(self) -> float:
        end = self.finished if self.finished is not None else time.monotonic()
        return end - self.submitted


class _ClientState:
    __slots__ = ("name", "weight", "vtime", "queue", "served", "service_s")

    def __init__(self, name: str, weight: float):
        self.name = name
        self.weight = max(weight, 1e-6)
        self.vtime = 0.0
        self.queue: deque = deque()
        self.served = 0
        self.service_s = 0.0


class FairScheduler:
    def __init__(self, run_fn: Callable[[QueryHandle], Tuple[object, bool]],
                 max_concurrent: int = 4, max_queue_depth: int = 32):
        self._run_fn = run_fn
        self.max_concurrent = max_concurrent
        self.max_queue_depth = max_queue_depth
        self._cv = threading.Condition()
        self._clients: Dict[str, _ClientState] = {}
        self._queued = 0
        self._inflight = 0
        self._vfloor = 0.0
        self._shutdown = False
        self.rejected = 0
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"shark-query-{i}")
            for i in range(max_concurrent)]
        for t in self._workers:
            t.start()

    # -- clients ---------------------------------------------------------------

    def register_client(self, name: str, weight: float = 1.0) -> None:
        with self._cv:
            state = self._clients.get(name)
            if state is None:
                self._clients[name] = _ClientState(name, weight)
            else:
                state.weight = max(weight, 1e-6)

    # -- submission ------------------------------------------------------------

    def submit(self, handle: QueryHandle, block: bool = True,
               timeout: Optional[float] = None) -> QueryHandle:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._shutdown:
                raise RuntimeError("scheduler is shut down")
            while self._queued >= self.max_queue_depth:
                if not block:
                    self.rejected += 1
                    raise AdmissionError(
                        f"queue full ({self._queued}/{self.max_queue_depth})")
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self.rejected += 1
                    raise AdmissionError("timed out waiting for queue space")
                self._cv.wait(remaining)
                if self._shutdown:
                    raise RuntimeError("scheduler is shut down")
            client = self._clients.get(handle.client)
            if client is None:
                client = _ClientState(handle.client, 1.0)
                self._clients[handle.client] = client
            if not client.queue:
                # idle client waking up: no banked credit from idle time
                client.vtime = max(client.vtime, self._vfloor)
            client.queue.append(handle)
            self._queued += 1
            self._cv.notify_all()
        return handle

    # -- dispatch --------------------------------------------------------------

    def _pick(self) -> Optional[Tuple[_ClientState, QueryHandle]]:
        # caller holds self._cv
        best = None
        for c in self._clients.values():
            if c.queue and (best is None or c.vtime < best.vtime):
                best = c
        if best is None:
            return None
        return best, best.queue.popleft()

    def _worker(self) -> None:
        while True:
            with self._cv:
                picked = self._pick()
                while picked is None and not self._shutdown:
                    self._cv.wait(0.5)
                    picked = self._pick()
                if picked is None:  # shutdown with empty queues
                    return
                client, handle = picked
                self._queued -= 1
                self._inflight += 1
                self._vfloor = max(self._vfloor, client.vtime)
                self._cv.notify_all()  # queue space freed: wake submitters
            handle.started = time.monotonic()
            handle.status = QueryHandle.RUNNING
            try:
                result, cached = self._run_fn(handle)
                handle._result = result
                handle.cached = cached
                handle.status = QueryHandle.DONE
            except BaseException as e:  # surfaces via handle.result()
                handle._error = e
                handle.status = QueryHandle.FAILED
            handle.finished = time.monotonic()
            elapsed = handle.finished - handle.started
            with self._cv:
                client.vtime += elapsed / client.weight
                client.served += 1
                client.service_s += elapsed
                self._inflight -= 1
            handle._event.set()

    # -- lifecycle / reporting -------------------------------------------------

    def load(self) -> int:
        """Queued + in-flight query count — the routing signal the fleet's
        least-loaded frontend uses (cluster/fleet.py)."""
        with self._cv:
            return self._queued + self._inflight

    def stats(self) -> Dict[str, object]:
        with self._cv:
            return {
                "queued": self._queued,
                "inflight": self._inflight,
                "rejected": self.rejected,
                "clients": {
                    name: {"weight": c.weight, "served": c.served,
                           "service_s": round(c.service_s, 6),
                           "vtime": round(c.vtime, 6),
                           "backlog": len(c.queue)}
                    for name, c in self._clients.items()},
            }

    def shutdown(self, wait: bool = True) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        if wait:
            for t in self._workers:
                t.join(timeout=5.0)
