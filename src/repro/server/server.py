"""SharkServer — concurrent multi-session query service (DESIGN.md §6).

One server owns ONE shared SharkContext (workers + block store), ONE
catalog, and the unified MemoryManager; many client sessions submit queries
concurrently:

    srv = SharkServer(cache_budget_bytes=64 << 20)
    srv.create_table("rankings", schema, data)
    etl = srv.session("etl", weight=1.0)        # scan-heavy tenant
    dash = srv.session("dash", weight=4.0)      # interactive tenant
    h = etl.submit("SELECT ... GROUP BY ...")   # async QueryHandle
    res = dash.sql("SELECT COUNT(*) FROM rankings")  # sync, fair-scheduled

Execution path per query (worker-pool thread):
  parse -> bind -> optimize -> fingerprint -> result-cache probe
        -> compile/execute on the shared runtime (cached scans under the
           memory budget; evicted partitions recompute from lineage)
        -> release the query's shuffle map outputs -> result-cache fill.

`submit()` also accepts a *bound logical plan* (what `SharkFrame.collect()`
sends): the plan path joins the pipeline at the optimize step, so frame
queries and SQL text get identical admission control, fair scheduling, and
result-cache behavior — one plan fingerprint, one cache entry.

Each query gets a fresh Executor (per-query metrics, no cross-query state)
but all executors share the context, catalog, scan cache, and therefore
the block store — that sharing is the whole point of the server tier.
"""

from __future__ import annotations

import copy
import threading
from typing import Dict, Optional, Union

import numpy as np

from ..core.catalog import Catalog, ExternalSource
from ..core.columnar import Table, from_arrays
from ..core.pde import PDEConfig
from ..core.physical import ExecResult, Executor, ScanCache
from ..core.runtime import SharkContext
from ..core.sql import Binder, CreateStmt, parse
from ..core.plan import Node, optimize
from ..core.types import Schema
from .memory import MemoryManager
from .result_cache import ResultCache, plan_fingerprint
from .scheduler import AdmissionError, FairScheduler, QueryHandle

__all__ = ["SharkServer", "AdmissionError", "QueryHandle"]


class SharkServer:
    def __init__(self, num_workers: int = 8, max_threads: int = 8, *,
                 cache_budget_bytes: Optional[int] = None,
                 max_concurrent_queries: int = 4,
                 max_queue_depth: int = 32,
                 enable_result_cache: bool = True,
                 result_cache_entries: int = 256,
                 enable_pde: bool = True, enable_map_pruning: bool = True,
                 default_partitions: int = 8,
                 default_shuffle_buckets: int = 64,
                 pde_config: Optional[PDEConfig] = None,
                 speculation: bool = True,
                 task_launch_overhead_s: float = 0.0,
                 backend: str = "compiled", exchange: str = "coded",
                 spill_dir: Optional[str] = None,
                 spill_mode: Optional[str] = None,
                 mesh=None, stage_fusion: str = "on",
                 resilience=None):
        self.ctx = SharkContext(num_workers=num_workers,
                                max_threads=max_threads,
                                speculation=speculation,
                                task_launch_overhead_s=task_launch_overhead_s,
                                policy=resilience)
        self.catalog = Catalog()
        self.memory = MemoryManager(self.ctx.block_manager,
                                    budget_bytes=cache_budget_bytes)
        # out-of-core storage tier (DESIGN.md §12): opt-in — without it the
        # server behaves exactly as before (LRU eviction + recompute only)
        self.storage = None
        if spill_mode is not None or spill_dir is not None:
            from ..core.storage import StorageManager
            self.storage = StorageManager(spill_dir=spill_dir,
                                          mode=spill_mode or "spill",
                                          policy=self.ctx.policy)
            self.memory.attach_storage(self.storage)
        self.scan_cache = ScanCache()
        self.result_cache = (ResultCache(result_cache_entries)
                             if enable_result_cache else None)
        if self.result_cache is not None:
            self.memory.attach_result_cache(self.result_cache)
        self.memory.attach_catalog(self.catalog)
        self.catalog.subscribe(self._on_catalog_change)
        self.default_partitions = default_partitions
        self._exec_kw = dict(
            pde=pde_config or PDEConfig(), enable_pde=enable_pde,
            enable_map_pruning=enable_map_pruning,
            default_shuffle_buckets=default_shuffle_buckets,
            backend=backend, exchange=exchange, mesh=mesh,
            stage_fusion=stage_fusion)
        self.scheduler = FairScheduler(
            self._run_query, max_concurrent=max_concurrent_queries,
            max_queue_depth=max_queue_depth)
        self._session_counter = 0
        self._lock = threading.Lock()

    def _on_catalog_change(self, name: str, epoch: int) -> None:
        """Catalog epoch bump: eagerly drop result-cache entries reading the
        mutated table (stale scan RDDs are retired lazily by version key)."""
        if self.result_cache is not None:
            self.result_cache.invalidate_table(name)

    # -- sessions -------------------------------------------------------------

    def session(self, client_id: Optional[str] = None, weight: float = 1.0):
        """A SharkSession attached to this server (shared warehouse, fair-
        scheduled execution)."""
        from ..core.session import SharkSession
        with self._lock:
            if client_id is None:
                client_id = f"client-{self._session_counter}"
            self._session_counter += 1
        return SharkSession(server=self, client_id=client_id, weight=weight)

    def register_client(self, client_id: str, weight: float = 1.0) -> None:
        self.scheduler.register_client(client_id, weight)

    # -- warehouse ------------------------------------------------------------

    def create_table(self, name: str, schema: Schema,
                     data: Dict[str, np.ndarray],
                     num_partitions: Optional[int] = None,
                     distribute_by: Optional[str] = None) -> Table:
        table = from_arrays(name, schema, data,
                            num_partitions or self.default_partitions,
                            distribute_by)
        self.catalog.register_table(table)
        return table

    def register_external(self, src: ExternalSource) -> None:
        self.catalog.register_external(src)

    # -- query submission -----------------------------------------------------

    def submit(self, query: Union[str, Node], client: str = "default",
               block: bool = True,
               timeout: Optional[float] = None) -> QueryHandle:
        """Enqueue a query for async execution; blocks (or raises
        AdmissionError) when the admission queue is full.

        `query` is SQL text, a SharkFrame, or a *bound logical plan* (a
        `core.plan.Node`, what `SharkFrame.collect()` submits).  All forms
        share admission control, fair scheduling, and — because the result
        cache is keyed by the fingerprint of the optimized plan — one cache
        entry: a frame query and its SQL-text twin hit each other's
        results."""
        from ..core.frame import SharkFrame
        if isinstance(query, SharkFrame):
            handle = QueryHandle(None, client, plan=query.logical_plan())
        elif isinstance(query, Node):
            handle = QueryHandle(None, client, plan=query)
        elif isinstance(query, str):
            handle = QueryHandle(query, client)
        else:
            raise TypeError(
                f"submit() takes SQL text, a SharkFrame, or a logical plan "
                f"Node; got {type(query).__name__}")
        return self.scheduler.submit(handle, block=block, timeout=timeout)

    def sql(self, sql: str, client: str = "default") -> ExecResult:
        return self.submit(sql, client=client).result()

    def sql_np(self, sql: str, client: str = "default"):
        return self.sql(sql, client=client).to_numpy()

    # -- execution (runs on scheduler worker threads) --------------------------

    def make_executor(self) -> Executor:
        return Executor(self.ctx, self.catalog,
                        scan_cache=self.scan_cache, **self._exec_kw)

    def _run_query(self, handle: QueryHandle):
        if handle.plan is not None:
            # frame submission: the plan object is owned by the (immutable,
            # possibly shared) frame — optimize a private copy
            node = optimize(copy.deepcopy(handle.plan), self.catalog)
            return self._execute_plan(node)

        stmt = parse(handle.sql)
        if isinstance(stmt, CreateStmt):
            from ..core.session import create_table_as
            executor = self.make_executor()
            try:
                result = create_table_as(executor, self.catalog, stmt,
                                         self.default_partitions)
            finally:
                self._release_shuffles(executor)
            return result, False

        node = optimize(Binder(self.catalog).bind(stmt), self.catalog)
        return self._execute_plan(node)

    def _execute_plan(self, node: Node):
        """Result-cache probe -> execute -> fill, for an optimized plan.
        Shared by the SQL-text and frame (plan-object) submission paths, so
        the two surfaces are indistinguishable from bind onward."""
        fingerprint = deps = None
        if self.result_cache is not None:
            fingerprint, deps = plan_fingerprint(node, self.catalog)
            hit = self.result_cache.get(fingerprint, self.catalog)
            if hit is not None:
                return hit, True

        executor = self.make_executor()
        try:
            result = executor.execute(node)
            result.metrics = executor.metrics
        finally:
            self._release_shuffles(executor)
        if self.result_cache is not None:
            self.result_cache.put(fingerprint, result, deps)
            self.memory.enforce()
        return result, False

    def _release_shuffles(self, executor: Executor) -> None:
        """Shuffle map outputs are query-scoped: the result stage has fully
        consumed them once execute returns, so release their memory."""
        for shuffle_id in executor.created_shuffles:
            self.ctx.block_manager.drop_shuffle(shuffle_id)

    # -- reporting / lifecycle --------------------------------------------------

    def stats(self) -> Dict[str, object]:
        out = {"memory": self.memory.stats(),
               "scheduler": self.scheduler.stats(),
               "resilience": self.ctx.scheduler.resilience_stats()}
        if self.result_cache is not None:
            out["result_cache"] = self.result_cache.stats()
        return out

    def describe_resilience(self) -> str:
        return self.ctx.scheduler.describe_resilience()

    def shutdown(self) -> None:
        self.scheduler.shutdown()
        self.scan_cache.clear()
        if self.storage is not None:
            self.storage.shutdown()
        self.ctx.shutdown()
