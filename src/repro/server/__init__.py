"""Server tier: concurrent multi-session query service (DESIGN.md §6).

`SharkServer` owns one shared context/catalog and serves many client
sessions with weighted fair scheduling, admission control, a unified
memory budget with partition-granular LRU eviction (recompute-from-lineage
on miss), and a plan-fingerprint query result cache invalidated by catalog
epochs.
"""

from .memory import MemoryManager
from .result_cache import ResultCache, plan_fingerprint
from .scheduler import AdmissionError, FairScheduler, QueryHandle
from .server import SharkServer

__all__ = ["SharkServer", "MemoryManager", "ResultCache", "plan_fingerprint",
           "AdmissionError", "FairScheduler", "QueryHandle"]
