"""Training-data pipeline on top of the Shark engine (the unification the
paper argues for in §4: SQL selects the data, the same engine feeds ML).

A corpus is a columnar table with one row per token:

    corpus(doc: int64, pos: int32, tok: int32, quality: float32)

Columnar compression is effective exactly as §3.2 predicts: `doc` is
RLE-encoded (long runs), `tok` bit-packs to ceil(log2 V) bits, and partition
stats on `doc`/`quality` enable map pruning for filtered selects.

`TokenPipeline` runs a SQL selection (e.g. quality filter) through the
engine once, caches the selected token stream, and serves deterministic
(step -> batch) training batches.  Determinism makes the pipeline itself
lineage-recoverable: the checkpoint manifest stores (table, filter, step)
and restart replays from there — the RDD lineage story applied to training
input (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core.columnar import Table
from ..core.session import SharkSession
from ..core.types import DType, Schema


def synthetic_corpus(session: SharkSession, name: str, vocab: int,
                     n_docs: int = 200, mean_doc_len: int = 512,
                     seed: int = 0, num_partitions: int = 8) -> Table:
    """Generate and load a synthetic tokenized corpus into the memory store."""
    rng = np.random.default_rng(seed)
    lens = np.maximum(8, rng.poisson(mean_doc_len, n_docs))
    doc = np.repeat(np.arange(n_docs, dtype=np.int64), lens)
    pos = np.concatenate([np.arange(l, dtype=np.int32) for l in lens])
    # zipf-ish token distribution, bounded to vocab
    tok = (rng.zipf(1.3, size=len(doc)) % vocab).astype(np.int32)
    quality = np.repeat(rng.uniform(0, 1, n_docs).astype(np.float32), lens)
    schema = Schema.of(doc=DType.INT64, pos=DType.INT32, tok=DType.INT32,
                       quality=DType.FLOAT32)
    return session.create_table(
        name, schema,
        {"doc": doc, "pos": pos, "tok": tok, "quality": quality},
        num_partitions=num_partitions)


@dataclasses.dataclass
class PipelineManifest:
    table: str
    sql_filter: Optional[str]
    seq_len: int
    global_batch: int
    seed: int
    step: int


class TokenPipeline:
    """SQL-selected, deterministic training batches.

    batch_at(step) is a pure function of (corpus, filter, seed, step):
    restartable mid-epoch from the manifest, and identical across hosts —
    each data-parallel host slices its own batch shard deterministically.
    """

    def __init__(self, session: SharkSession, table: str, seq_len: int,
                 global_batch: int, sql_filter: Optional[str] = None,
                 seed: int = 0):
        self.session = session
        self.table = table
        self.sql_filter = sql_filter
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        where = f" WHERE {sql_filter}" if sql_filter else ""
        res = session.sql_np(f"SELECT tok FROM {table}{where}")
        self.stream = np.asarray(res["tok"], dtype=np.int32)
        if len(self.stream) < seq_len + 1:
            reps = (seq_len + 1) // max(len(self.stream), 1) + 1
            self.stream = np.tile(self.stream, reps)
        self._rng_base = np.random.SeedSequence(seed)

    @property
    def tokens_per_batch(self) -> int:
        return self.seq_len * self.global_batch

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch: offsets drawn from a counter-based RNG keyed
        by (seed, step) — replayable after restart, no cursor state."""
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed,
                                   spawn_key=(step,)))
        n = len(self.stream) - self.seq_len - 1
        offs = rng.integers(0, max(n, 1), self.global_batch)
        toks = np.stack([self.stream[o:o + self.seq_len] for o in offs])
        labels = np.stack([self.stream[o + 1:o + self.seq_len + 1]
                           for o in offs])
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def manifest(self, step: int) -> Dict:
        return dataclasses.asdict(PipelineManifest(
            self.table, self.sql_filter, self.seq_len, self.global_batch,
            self.seed, step))

    @staticmethod
    def from_manifest(session: SharkSession, m: Dict) -> "TokenPipeline":
        return TokenPipeline(session, m["table"], m["seq_len"],
                             m["global_batch"], m["sql_filter"], m["seed"])
