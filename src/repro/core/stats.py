"""Run-time statistics for Partial DAG Execution (paper §3.1).

While map output materializes, each task gathers customizable statistics at
global and per-partition granularity through a pluggable accumulator API:

  1. partition sizes and record counts (skew detection),
  2. "heavy hitters" — frequently occurring keys,
  3. approximate histograms of the key distribution.

Workers send these to the master, which aggregates them and hands them to the
optimizer.  The paper bounds their size to 1–2 KB per task using lossy
compression: partition sizes are *logarithmically encoded*, representing up
to 32 GB in one byte with at most 10% error.  We reproduce that encoding
exactly (base such that 255 steps cover 32 GiB at ≤10% relative error) and
the accumulator API.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

# --------------------------------------------------------------------------
# Logarithmic size encoding: value -> one unsigned byte.
# With base b, code k represents b^k; max relative error is (b-1)/2 per
# rounding step.  b = 1.1 gives codes up to 1.1^255 ≈ 3.6e10 > 32 GiB with
# ≤10% error, exactly the paper's claim.
# --------------------------------------------------------------------------

LOG_BASE = 1.1


def encode_size(nbytes: int) -> int:
    """code k in 1..255 represents 1.1^(k-1) bytes; 0 means empty."""
    if nbytes <= 0:
        return 0
    code = int(round(math.log(nbytes, LOG_BASE))) + 1
    return max(1, min(255, code))


def decode_size(code: int) -> float:
    if code == 0:
        return 0.0
    return LOG_BASE ** (code - 1)


# --------------------------------------------------------------------------
# Pluggable accumulator API
# --------------------------------------------------------------------------


class Accumulator:
    """One statistic gathered while a map task materializes its output."""

    name: str = "accumulator"

    def update(self, bucket: int, batch) -> None:
        raise NotImplementedError

    def payload(self) -> Any:
        """Lossy-compressed bytes-bounded summary sent to the master."""
        raise NotImplementedError


class SizeAccumulator(Accumulator):
    """Per-output-bucket byte sizes + record counts (log-encoded)."""

    name = "sizes"

    def __init__(self, num_buckets: int):
        self.codes = np.zeros(num_buckets, np.uint8)
        self.records = np.zeros(num_buckets, np.int64)

    def update(self, bucket: int, batch) -> None:
        raw = decode_size(int(self.codes[bucket])) + batch.nbytes
        self.codes[bucket] = encode_size(int(raw))
        self.records[bucket] += batch.num_rows

    def payload(self):
        return {"codes": self.codes.copy(), "records": self.records.copy()}


class HeavyHitterAccumulator(Accumulator):
    """Misra–Gries top-k sketch over join/group keys (paper example 2)."""

    name = "heavy_hitters"

    def __init__(self, key_col: str, k: int = 64):
        self.key_col = key_col
        self.k = k
        self.counters: Dict[Any, int] = {}

    def update(self, bucket: int, batch) -> None:
        if self.key_col not in batch.cols:
            return
        v = batch.col(self.key_col)
        if v.is_string:
            # sketch on codes, decode only the (few) DISTINCT values — the
            # map side of the dictionary-preserving exchange never
            # materializes a string column row-wise
            codes, counts = np.unique(np.asarray(v.arr), return_counts=True)
            keys = v.sdict[codes]
        else:
            keys, counts = np.unique(np.asarray(v.arr), return_counts=True)
        for key, c in zip(keys.tolist(), counts.tolist()):
            if key in self.counters:
                self.counters[key] += c
            elif len(self.counters) < self.k:
                self.counters[key] = c
            else:
                dec = min(c, min(self.counters.values()))
                self.counters = {k2: v - dec for k2, v in self.counters.items()
                                 if v - dec > 0}
                if c - dec > 0:
                    self.counters[key] = c - dec

    def payload(self):
        return dict(sorted(self.counters.items(), key=lambda kv: -kv[1]))


class HistogramAccumulator(Accumulator):
    """Approximate equi-width histogram of a numeric key (paper example 3)."""

    name = "histogram"

    def __init__(self, key_col: str, lo: float, hi: float, bins: int = 64):
        self.key_col = key_col
        self.lo, self.hi, self.bins = lo, hi, bins
        self.counts = np.zeros(bins, np.int64)

    def update(self, bucket: int, batch) -> None:
        if self.key_col not in batch.cols:
            return
        v = np.asarray(batch.col(self.key_col).arr, dtype=np.float64)
        idx = np.clip(((v - self.lo) / max(self.hi - self.lo, 1e-12)
                       * self.bins).astype(np.int64), 0, self.bins - 1)
        np.add.at(self.counts, idx, 1)

    def payload(self):
        # lossy: log-encode bin counts to one byte each
        return np.array([encode_size(int(c)) for c in self.counts], np.uint8)


@dataclasses.dataclass
class TaskStats:
    """What one map task reports to the master (bounded to ~1–2 KB)."""
    task_id: int
    stage_id: int
    payloads: Dict[str, Any]

    def nbytes(self) -> int:
        total = 0
        for v in self.payloads.values():
            if isinstance(v, np.ndarray):
                total += v.nbytes
            elif isinstance(v, dict):
                total += sum(np.asarray(x).nbytes if isinstance(x, np.ndarray)
                             else 16 for x in v.values())
            else:
                total += 16
        return total


@dataclasses.dataclass
class StageStats:
    """Master-side aggregation of all TaskStats of a finished stage."""
    stage_id: int
    per_task: List[TaskStats] = dataclasses.field(default_factory=list)

    def add(self, ts: TaskStats) -> None:
        self.per_task.append(ts)

    # -- derived views used by the PDE optimizer ---------------------------

    def output_bytes_per_bucket(self, num_buckets: int) -> np.ndarray:
        """Decoded (approximate) bytes destined for each reduce bucket."""
        total = np.zeros(num_buckets, np.float64)
        for ts in self.per_task:
            p = ts.payloads.get("sizes")
            if p is None:
                continue
            total += np.array([decode_size(int(c)) for c in p["codes"]])
        return total

    def records_per_bucket(self, num_buckets: int) -> np.ndarray:
        total = np.zeros(num_buckets, np.int64)
        for ts in self.per_task:
            p = ts.payloads.get("sizes")
            if p is not None:
                total += p["records"]
        return total

    def total_output_bytes(self) -> float:
        total = 0.0
        for ts in self.per_task:
            p = ts.payloads.get("sizes")
            if p is not None:
                total += float(sum(decode_size(int(c)) for c in p["codes"]))
        return total

    def heavy_hitters(self, top: int = 16) -> Dict[Any, int]:
        merged: Dict[Any, int] = {}
        for ts in self.per_task:
            p = ts.payloads.get("heavy_hitters")
            if not p:
                continue
            for k, v in p.items():
                merged[k] = merged.get(k, 0) + v
        return dict(sorted(merged.items(), key=lambda kv: -kv[1])[:top])


# --------------------------------------------------------------------------
# Catalog / partition statistics for cost-based join ordering.
#
# The paper's PDE re-plans from *observed* statistics at run time; the
# initial join order, however, must be chosen before anything has executed.
# These estimators derive that prior from what the columnar store already
# piggybacks on load (§3.3, §3.5): per-partition row counts, byte sizes,
# min/max ranges, and small distinct-value sets.
# --------------------------------------------------------------------------


def predicate_selectivity(pred) -> float:
    """System-R-style selectivity heuristic for a filter predicate.

    Used only to *rank* candidate join orders, so coarse class-based factors
    are enough; PDE corrects any misestimate at the shuffle boundary."""
    from .expr import (And, Between, Cmp, Expr, InList, Not, Or)
    if pred is None:
        return 1.0
    if isinstance(pred, And):
        return predicate_selectivity(pred.left) * predicate_selectivity(pred.right)
    if isinstance(pred, Or):
        s = (predicate_selectivity(pred.left)
             + predicate_selectivity(pred.right))
        return min(1.0, s)
    if isinstance(pred, Not):
        return max(0.05, 1.0 - predicate_selectivity(pred.child))
    if isinstance(pred, Cmp):
        return 0.1 if pred.op == "=" else (0.9 if pred.op == "!=" else 0.33)
    if isinstance(pred, Between):
        return 0.25
    if isinstance(pred, InList):
        return min(1.0, 0.05 * max(len(pred.values), 1))
    return 0.5


def table_column_ndv(table, col: str) -> Optional[int]:
    """Number of distinct values of `col`, from the per-partition distinct
    sets piggybacked on loading — exact when every partition kept its set
    (enum-ish columns), else None (caller falls back to row count)."""
    union: set = set()
    for p in table.partitions:
        block = p.columns.get(col)
        if block is None or block.stats.distinct is None:
            return None
        union.update(block.stats.distinct)
    return len(union) if union else None


def block_ndv(block) -> Optional[int]:
    """Distinct-value count of one partition's column block, from what the
    store already holds: the string dictionary, the DICT-encoding
    dictionary, or the piggybacked distinct set (§3.3).  None when unknown
    — the caller (compiled-segment backend selection) then avoids the
    one-hot-matmul group-by, whose tile width scales with NDV."""
    sd = getattr(block, "str_dict", None)
    if sd is not None:
        return len(sd)
    enc = getattr(block, "enc", None)
    if enc is not None and getattr(enc, "dictionary", None) is not None:
        return len(enc.dictionary)
    stats = getattr(block, "stats", None)
    if stats is not None and stats.distinct is not None:
        return len(stats.distinct)
    return None


def surviving_partition_fraction(table, pred) -> float:
    """Fraction of partitions whose piggybacked stats could satisfy `pred`
    (the same refutation test map pruning uses, §3.5) — a second, data-aware
    selectivity signal for the join-order prior."""
    from .pruning import may_match
    total = table.num_partitions
    if total == 0:
        return 1.0
    kept = sum(1 for p in table.partitions if may_match(pred, p.stats()))
    return kept / total


@dataclasses.dataclass
class RelEstimate:
    """Pre-execution size estimate of one relation (a join input subtree)."""
    rows: float
    nbytes: float
    # table backing a bare scan (for NDV lookups / co-partition checks);
    # None once the subtree contains anything but Scan/Filter/Project
    table: Optional[Any] = None

    @property
    def bytes_per_row(self) -> float:
        return self.nbytes / self.rows if self.rows > 0 else 0.0


# --------------------------------------------------------------------------
# Greedy bin-packing used for reducer coalescing / skew mitigation (§3.1.2)
# --------------------------------------------------------------------------


def greedy_bin_pack(sizes: Sequence[float], num_bins: int) -> List[List[int]]:
    """Assign fine-grained partitions to `num_bins` coalesced partitions,
    equalizing bin totals: sort descending, place each into the lightest bin."""
    order = np.argsort(-np.asarray(sizes, dtype=np.float64))
    bins: List[List[int]] = [[] for _ in range(num_bins)]
    loads = np.zeros(num_bins, np.float64)
    for i in order.tolist():
        b = int(np.argmin(loads))
        bins[b].append(i)
        loads[b] += sizes[i]
    return bins


def choose_num_reducers(bucket_bytes: np.ndarray,
                        target_bytes_per_reducer: float = 64 << 20,
                        min_reducers: int = 1,
                        max_reducers: int = 4096) -> int:
    total = float(bucket_bytes.sum())
    n = int(math.ceil(total / max(target_bytes_per_reducer, 1.0)))
    return max(min_reducers, min(max_reducers, n))
