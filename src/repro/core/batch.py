"""PartitionBatch: the unit of data flowing between physical operators.

One batch = one partition's columns.  Numeric columns are arrays; string
columns stay dictionary-encoded (codes + partition-local dictionary) end to
end — including ACROSS shuffles (DESIGN.md §11): a shuffle block ships each
string column as (codes, partition-local dictionary), and the reduce side
unifies the per-piece dictionaries with a vectorized merge-remap
(`merge_string_dicts`) instead of decoding rows.  The engine only
materializes strings at result collection.  This mirrors Shark's columnar
store, where a block of tuples is a single object and per-row
materialization never happens.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

import time as _time

from .columnar import Partition
from .expr import ColumnVal
from .types import DType, Schema

# Wall-clock spent on the exchange path, summed across worker threads
# (plain dict adds under the GIL — diagnostics, not exact accounting):
#   hash     — shuffle key hashing / join-key materialization,
#   decode   — map-side raw-string materialization (legacy exchange only),
#   assemble — reduce-side piece assembly (concat + dictionary unification).
# benchmarks/shuffle_bench.py resets and reads these to price the exchange
# separately from the (shared) scan/aggregate work around it.
EXCHANGE_TIMERS = {"hash": 0.0, "decode": 0.0, "assemble": 0.0}


def reset_exchange_timers() -> None:
    for k in EXCHANGE_TIMERS:
        EXCHANGE_TIMERS[k] = 0.0


def merge_string_dicts(dicts: Sequence[np.ndarray]
                       ) -> "tuple[np.ndarray, List[np.ndarray]]":
    """Unify several partition-local string dictionaries into one sorted,
    unique dictionary plus a per-input code remap — the reduce-side half of
    the dictionary-preserving exchange.  Vectorized over the (small)
    dictionaries only; row data is never touched.  Input dictionaries may be
    unsorted and may contain duplicates (string-function transforms);
    `searchsorted` maps every entry by value, so the remapped codes are
    always codes into the sorted unified dictionary."""
    if len(dicts) == 1:
        d = dicts[0]
        if len(d) <= 1 or bool(np.all(d[:-1] < d[1:])):
            return d, [np.arange(len(d), dtype=np.int32)]
    unified = np.unique(np.concatenate(dicts)) if dicts \
        else np.zeros(0, np.str_)
    remaps = [np.searchsorted(unified, d).astype(np.int32) for d in dicts]
    return unified, remaps


@dataclasses.dataclass
class PartitionBatch:
    cols: Dict[str, ColumnVal]

    @property
    def num_rows(self) -> int:
        if not self.cols:
            return 0
        v = next(iter(self.cols.values()))
        if not v.materialized and v.block is not None:
            return v.block.n
        return int(np.asarray(v.arr).shape[0])

    @property
    def nbytes(self) -> int:
        total = 0
        for v in self.cols.values():
            if not v.materialized and v.block is not None:
                # still encoded in the column store: account encoded bytes
                # rather than forcing a decode just to size the batch
                total += v.block.nbytes
                continue
            total += np.asarray(v.arr).nbytes
            if v.sdict is not None:
                total += v.sdict.nbytes
        return total

    def names(self) -> List[str]:
        return list(self.cols)

    def col(self, name: str) -> ColumnVal:
        return self.cols[name]

    def mask(self, m: np.ndarray) -> "PartitionBatch":
        m = np.asarray(m)
        return PartitionBatch({
            n: ColumnVal(np.asarray(v.arr)[m], v.sdict, v.sorted_dict)
            for n, v in self.cols.items()})

    def take(self, idx: np.ndarray) -> "PartitionBatch":
        return PartitionBatch({
            n: ColumnVal(np.asarray(v.arr)[idx], v.sdict, v.sorted_dict)
            for n, v in self.cols.items()})

    def head(self, n: int) -> "PartitionBatch":
        return PartitionBatch({
            k: ColumnVal(np.asarray(v.arr)[:n], v.sdict, v.sorted_dict)
            for k, v in self.cols.items()})

    def select(self, names: Sequence[str]) -> "PartitionBatch":
        return PartitionBatch({n: self.cols[n] for n in names})

    def with_col(self, name: str, v: ColumnVal) -> "PartitionBatch":
        d = dict(self.cols)
        d[name] = v
        return PartitionBatch(d)

    def rename(self, mapping: Dict[str, str]) -> "PartitionBatch":
        return PartitionBatch({mapping.get(n, n): v for n, v in self.cols.items()})

    def decoded(self) -> Dict[str, np.ndarray]:
        """Materialize logical values (strings decoded)."""
        return {n: v.decoded() for n, v in self.cols.items()}

    def decode_strings(self) -> "PartitionBatch":
        """Replace dictionary-coded strings with raw string arrays — the
        LEGACY exchange's map-side step (exchange="decoded"); the
        dictionary-preserving exchange never calls this."""
        t0 = _time.perf_counter()
        out = {}
        for n, v in self.cols.items():
            if v.is_string:
                out[n] = ColumnVal(v.decoded(), None)
            else:
                out[n] = v
        EXCHANGE_TIMERS["decode"] += _time.perf_counter() - t0
        return PartitionBatch(out)

    @staticmethod
    def from_partition(p: Partition, columns: Optional[Sequence[str]] = None
                       ) -> "PartitionBatch":
        """Block-backed batch: columns stay encoded until something reads
        `.arr` (memoized decode) — the compiled segment executor evaluates
        predicates on dictionary codes and may never materialize them."""
        names = list(columns) if columns is not None else list(p.columns)
        out = {}
        for n in names:
            b = p.columns[n]
            out[n] = ColumnVal(None, b.str_dict, True, block=b)
        return PartitionBatch(out)

    @staticmethod
    def from_numpy(d: Dict[str, np.ndarray]) -> "PartitionBatch":
        out = {}
        for n, v in d.items():
            v = np.asarray(v)
            if v.dtype.kind in ("U", "S", "O"):
                out[n] = ColumnVal(v.astype(np.str_), None)
                # raw string array: represent as codes over itself lazily
                sdict, codes = np.unique(v.astype(np.str_), return_inverse=True)
                out[n] = ColumnVal(codes.astype(np.int32), sdict, True)
            else:
                out[n] = ColumnVal(v, None)
        return PartitionBatch(out)

    @staticmethod
    def concat(batches: Sequence["PartitionBatch"]) -> "PartitionBatch":
        """Merge fetched shuffle pieces into one reduce input.

        Row offsets are computed once and every column is assembled into a
        single preallocated output array (one copy per piece, no
        intermediate concatenations).  String columns stay dictionary
        codes: the per-piece dictionaries are unified with a vectorized
        merge-remap (`merge_string_dicts`) — rows are never decoded, which
        is what keeps the exchange decode-free end to end."""
        batches = [b for b in batches if b is not None]
        if not batches:
            return PartitionBatch({})
        if len(batches) == 1 and all(
                (not v.is_string) or v.sorted_dict
                for v in batches[0].cols.values()):
            # single piece with order-preserving dictionaries: nothing to
            # unify (a lone unsorted-dict column still needs the remap below
            # so downstream code-space grouping sees one code per value)
            return batches[0]
        t0 = _time.perf_counter()
        names = batches[0].names()
        sizes = [b.num_rows for b in batches]
        total = int(sum(sizes))
        offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        out: Dict[str, ColumnVal] = {}
        for n in names:
            vals = [b.cols[n] for b in batches]
            if all(v.is_string for v in vals):
                # compact each piece's dictionary to the codes it actually
                # references first: a shuffle bucket keeps its map
                # partition's FULL dictionary, so merging uncompacted dicts
                # would redo |dict| work per bucket instead of per row
                sdicts, code_arrays = [], []
                for v in vals:
                    codes = np.asarray(v.arr)
                    nd = len(v.sdict)
                    used = np.zeros(nd, bool)
                    used[codes] = True
                    if used.all():
                        sdicts.append(v.sdict)
                        code_arrays.append(codes)
                    else:
                        new_of_old = np.cumsum(used) - 1
                        sdicts.append(v.sdict[used])
                        code_arrays.append(
                            new_of_old[codes].astype(np.int32))
                sdict, remaps = merge_string_dicts(sdicts)
                codes = np.empty(total, np.int32)
                for c, remap, lo, hi in zip(code_arrays, remaps, offsets,
                                            offsets[1:]):
                    codes[lo:hi] = remap[c]
                out[n] = ColumnVal(codes, sdict, True)
            elif any(v.is_string for v in vals):
                # mixed coded/raw pieces (legacy decoded-exchange blocks):
                # fall back to decode + re-encode to a fresh dictionary
                raw = np.concatenate([v.decoded() for v in vals])
                sdict, codes = np.unique(raw, return_inverse=True)
                out[n] = ColumnVal(codes.astype(np.int32), sdict, True)
            else:
                arrs = [np.asarray(v.arr) for v in vals]
                dt = np.result_type(*arrs)
                merged = np.empty(total, dt)
                for a, lo, hi in zip(arrs, offsets, offsets[1:]):
                    merged[lo:hi] = a
                out[n] = ColumnVal(merged)
        EXCHANGE_TIMERS["assemble"] += _time.perf_counter() - t0
        return PartitionBatch(out)

    @staticmethod
    def empty_like(b: "PartitionBatch") -> "PartitionBatch":
        return b.head(0)
