"""PartitionBatch: the unit of data flowing between physical operators.

One batch = one partition's columns.  Numeric columns are arrays; string
columns stay dictionary-encoded (codes + partition-local dictionary) end to
end — the engine only materializes strings at result collection or when a
shuffle must hash raw values.  This mirrors Shark's columnar store, where a
block of tuples is a single object and per-row materialization never happens.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .columnar import Partition
from .expr import ColumnVal
from .types import DType, Schema


@dataclasses.dataclass
class PartitionBatch:
    cols: Dict[str, ColumnVal]

    @property
    def num_rows(self) -> int:
        if not self.cols:
            return 0
        v = next(iter(self.cols.values()))
        if not v.materialized and v.block is not None:
            return v.block.n
        return int(np.asarray(v.arr).shape[0])

    @property
    def nbytes(self) -> int:
        total = 0
        for v in self.cols.values():
            if not v.materialized and v.block is not None:
                # still encoded in the column store: account encoded bytes
                # rather than forcing a decode just to size the batch
                total += v.block.nbytes
                continue
            total += np.asarray(v.arr).nbytes
            if v.sdict is not None:
                total += v.sdict.nbytes
        return total

    def names(self) -> List[str]:
        return list(self.cols)

    def col(self, name: str) -> ColumnVal:
        return self.cols[name]

    def mask(self, m: np.ndarray) -> "PartitionBatch":
        m = np.asarray(m)
        return PartitionBatch({
            n: ColumnVal(np.asarray(v.arr)[m], v.sdict, v.sorted_dict)
            for n, v in self.cols.items()})

    def take(self, idx: np.ndarray) -> "PartitionBatch":
        return PartitionBatch({
            n: ColumnVal(np.asarray(v.arr)[idx], v.sdict, v.sorted_dict)
            for n, v in self.cols.items()})

    def head(self, n: int) -> "PartitionBatch":
        return PartitionBatch({
            k: ColumnVal(np.asarray(v.arr)[:n], v.sdict, v.sorted_dict)
            for k, v in self.cols.items()})

    def select(self, names: Sequence[str]) -> "PartitionBatch":
        return PartitionBatch({n: self.cols[n] for n in names})

    def with_col(self, name: str, v: ColumnVal) -> "PartitionBatch":
        d = dict(self.cols)
        d[name] = v
        return PartitionBatch(d)

    def rename(self, mapping: Dict[str, str]) -> "PartitionBatch":
        return PartitionBatch({mapping.get(n, n): v for n, v in self.cols.items()})

    def decoded(self) -> Dict[str, np.ndarray]:
        """Materialize logical values (strings decoded)."""
        return {n: v.decoded() for n, v in self.cols.items()}

    def decode_strings(self) -> "PartitionBatch":
        """Replace dictionary-coded strings with raw string arrays (used at
        shuffle boundaries where codes from different partitions collide)."""
        out = {}
        for n, v in self.cols.items():
            if v.is_string:
                out[n] = ColumnVal(v.decoded(), None)
            else:
                out[n] = v
        return PartitionBatch(out)

    @staticmethod
    def from_partition(p: Partition, columns: Optional[Sequence[str]] = None
                       ) -> "PartitionBatch":
        """Block-backed batch: columns stay encoded until something reads
        `.arr` (memoized decode) — the compiled segment executor evaluates
        predicates on dictionary codes and may never materialize them."""
        names = list(columns) if columns is not None else list(p.columns)
        out = {}
        for n in names:
            b = p.columns[n]
            out[n] = ColumnVal(None, b.str_dict, True, block=b)
        return PartitionBatch(out)

    @staticmethod
    def from_numpy(d: Dict[str, np.ndarray]) -> "PartitionBatch":
        out = {}
        for n, v in d.items():
            v = np.asarray(v)
            if v.dtype.kind in ("U", "S", "O"):
                out[n] = ColumnVal(v.astype(np.str_), None)
                # raw string array: represent as codes over itself lazily
                sdict, codes = np.unique(v.astype(np.str_), return_inverse=True)
                out[n] = ColumnVal(codes.astype(np.int32), sdict, True)
            else:
                out[n] = ColumnVal(v, None)
        return PartitionBatch(out)

    @staticmethod
    def concat(batches: Sequence["PartitionBatch"]) -> "PartitionBatch":
        batches = [b for b in batches if b is not None]
        if not batches:
            return PartitionBatch({})
        names = batches[0].names()
        out: Dict[str, ColumnVal] = {}
        for n in names:
            vals = [b.cols[n] for b in batches]
            if any(v.is_string for v in vals):
                # merge via decode + re-encode to a fresh shared dictionary
                raw = np.concatenate([v.decoded() for v in vals]) \
                    if vals else np.zeros(0, np.str_)
                sdict, codes = np.unique(raw, return_inverse=True)
                out[n] = ColumnVal(codes.astype(np.int32), sdict, True)
            else:
                out[n] = ColumnVal(
                    np.concatenate([np.asarray(v.arr) for v in vals]))
        return PartitionBatch(out)

    @staticmethod
    def empty_like(b: "PartitionBatch") -> "PartitionBatch":
        return b.head(0)
