"""Partial DAG Execution (paper §3.1) — the paper's central contribution.

The query plan DAG is *altered while the query runs*, based on statistics
gathered at shuffle boundaries:

  §3.1.1 Join optimization — run the pre-shuffle map stages, observe the
  materialized sizes, then choose: map (broadcast) join if one side is small,
  else shuffle join.  With a prior that one side will be small (e.g. a
  filtered dimension table), pre-shuffle ONLY that side first and skip the
  big table's map stage entirely when the broadcast decision lands (the 3x
  win of §6.3.2).

  §3.1.2 Degree of parallelism & skew — coalesce many fine-grained map
  buckets into fewer reduce partitions by greedy bin-packing on observed
  bucket sizes, equalizing reducer load.

Decisions are pure functions of StageStats, so they are unit-testable and
the dry-run can replay them.  On the TPU SPMD side the same decision logic
selects the collective pattern (all-gather of small side vs all-to-all of
both), which is exactly the collective roofline term the §Perf loop
minimizes — see repro/parallel and EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np

from .stats import StageStats, choose_num_reducers, greedy_bin_pack


class JoinChoice(enum.Enum):
    SHUFFLE = "shuffle"
    BROADCAST_LEFT = "broadcast_left"    # left side is small -> broadcast it
    BROADCAST_RIGHT = "broadcast_right"


@dataclasses.dataclass
class PDEConfig:
    # broadcast threshold: map-join if one side's observed materialized size
    # is below this (Hive's default autoconvert threshold era: tens of MB).
    broadcast_threshold_bytes: float = 32 << 20
    # target bytes per reduce task when coalescing
    target_reduce_bytes: float = 64 << 20
    min_reducers: int = 1
    max_reducers: int = 4096
    # skew: a bucket this many times the mean is "skewed"
    skew_factor: float = 4.0


@dataclasses.dataclass
class JoinDecision:
    choice: JoinChoice
    left_bytes: float
    right_bytes: float
    reason: str


def decide_join(left_stats: Optional[StageStats],
                right_stats: Optional[StageStats],
                cfg: PDEConfig = PDEConfig()) -> JoinDecision:
    """§3.1.1: pick join strategy from observed (or partially observed)
    map-output sizes.  Either side's stats may be missing when the optimizer
    scheduled only the likely-small side first."""
    lb = left_stats.total_output_bytes() if left_stats else float("inf")
    rb = right_stats.total_output_bytes() if right_stats else float("inf")
    if lb <= cfg.broadcast_threshold_bytes and lb <= rb:
        return JoinDecision(JoinChoice.BROADCAST_LEFT, lb, rb,
                            f"left observed {lb:.0f}B <= "
                            f"{cfg.broadcast_threshold_bytes:.0f}B threshold")
    if rb <= cfg.broadcast_threshold_bytes:
        return JoinDecision(JoinChoice.BROADCAST_RIGHT, lb, rb,
                            f"right observed {rb:.0f}B <= "
                            f"{cfg.broadcast_threshold_bytes:.0f}B threshold")
    return JoinDecision(JoinChoice.SHUFFLE, lb, rb,
                        "both sides above broadcast threshold")


@dataclasses.dataclass
class ParallelismDecision:
    num_reducers: int
    bucket_groups: List[List[int]]
    skewed_buckets: List[int]
    reason: str


def decide_parallelism(stats: StageStats, num_buckets: int,
                       cfg: PDEConfig = PDEConfig()) -> ParallelismDecision:
    """§3.1.2: choose the reduce degree of parallelism at run time by
    coalescing fine-grained buckets with greedy bin-packing, equalizing
    coalesced partition sizes."""
    sizes = stats.output_bytes_per_bucket(num_buckets)
    n = choose_num_reducers(sizes, cfg.target_reduce_bytes,
                            cfg.min_reducers,
                            min(cfg.max_reducers, num_buckets))
    groups = greedy_bin_pack(sizes.tolist(), n)
    groups = [g for g in groups if g]  # drop empty bins
    mean = float(sizes.mean()) if len(sizes) else 0.0
    skewed = [i for i, s in enumerate(sizes.tolist())
              if mean > 0 and s > cfg.skew_factor * mean]
    return ParallelismDecision(
        len(groups), groups, skewed,
        f"total {sizes.sum():.0f}B -> {len(groups)} reducers "
        f"(target {cfg.target_reduce_bytes:.0f}B each), "
        f"{len(skewed)} skewed buckets bin-packed")


def likely_small_side(left_hint_bytes: Optional[float],
                      right_hint_bytes: Optional[float],
                      left_filtered: bool, right_filtered: bool) -> Optional[str]:
    """Static prior used to order pre-shuffle stages (§6.3.2): a side that is
    initially smaller AND carries a filter predicate is likely to come out
    small, so schedule its map stage first and hope to skip the other side's
    pre-shuffle entirely."""
    def score(hint, filtered):
        s = 0.0
        if filtered:
            s += 1.0
        if hint is not None:
            s += 1.0 / (1.0 + hint / (64 << 20))
        return s
    ls, rs = score(left_hint_bytes, left_filtered), score(right_hint_bytes, right_filtered)
    if ls == rs:
        if left_hint_bytes is not None and right_hint_bytes is not None:
            return "left" if left_hint_bytes <= right_hint_bytes else "right"
        return None
    return "left" if ls > rs else "right"
