"""Partial DAG Execution (paper §3.1) — the paper's central contribution.

The query plan DAG is *altered while the query runs*, based on statistics
gathered at shuffle boundaries:

  §3.1.1 Join optimization — run the pre-shuffle map stages, observe the
  materialized sizes, then choose: map (broadcast) join if one side is small,
  else shuffle join.  With a prior that one side will be small (e.g. a
  filtered dimension table), pre-shuffle ONLY that side first and skip the
  big table's map stage entirely when the broadcast decision lands (the 3x
  win of §6.3.2).

  §3.1.2 Degree of parallelism & skew — coalesce many fine-grained map
  buckets into fewer reduce partitions by greedy bin-packing on observed
  bucket sizes, equalizing reducer load.

Decisions are pure functions of StageStats, so they are unit-testable and
the dry-run can replay them.  On the TPU SPMD side the same decision logic
selects the collective pattern (all-gather of small side vs all-to-all of
both), which is exactly the collective roofline term the §Perf loop
minimizes — see repro/parallel and EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np

from .stats import StageStats, choose_num_reducers, greedy_bin_pack


class JoinChoice(enum.Enum):
    SHUFFLE = "shuffle"
    BROADCAST_LEFT = "broadcast_left"    # left side is small -> broadcast it
    BROADCAST_RIGHT = "broadcast_right"


@dataclasses.dataclass
class PDEConfig:
    # broadcast threshold: map-join if one side's observed materialized size
    # is below this (Hive's default autoconvert threshold era: tens of MB).
    broadcast_threshold_bytes: float = 32 << 20
    # target bytes per reduce task when coalescing
    target_reduce_bytes: float = 64 << 20
    min_reducers: int = 1
    max_reducers: int = 4096
    # skew: a bucket this many times the mean is "skewed"
    skew_factor: float = 4.0
    # -- compiled pipeline segments (DESIGN.md §10) --------------------------
    # below this row count the jit/XLA dispatch overhead outweighs the fused
    # kernel: evaluate the partition with the numpy oracle instead
    segment_min_compiled_rows: int = 64
    # Pallas kernels (colscan / fused_decode_scan / groupby_mxu) only beat
    # the generic jitted segment on partitions at least this large
    segment_kernel_min_rows: int = 4096
    # group-by keys with more distinct values than this stay on the
    # sort/segment-sum path (one-hot matmul tiles scale with NDV)
    segment_groupby_max_ndv: int = 512
    # Pallas interpret mode on CPU is a correctness tool, not a fast path:
    # kernels are only routed to on a real TPU unless forced (tests force
    # this to exercise the kernel route under interpret mode)
    segment_force_kernels: bool = False
    # -- compiled exchange / reduce side (DESIGN.md §11) ---------------------
    # below this many partial-state rows the reduce-side merge / join probe
    # runs the interpreted numpy oracle (jit dispatch dominates tiny bucket
    # groups); at or above it, the compiled (jitted) reduce kernels
    reduce_min_compiled_rows: int = 2048
    # force the compiled reduce path regardless of size (differential tests
    # drive the oracle grid with this on and off)
    reduce_force_compiled: bool = False
    # -- whole-stage fusion (DESIGN.md §14) ----------------------------------
    # below this many rows the fused stage program gains nothing over the
    # segment-at-a-time path (the partition routes to the numpy oracle
    # anyway); partitions at/above it fuse map-side work and bucketing into
    # the stage program when the session allows
    stage_fusion_min_rows: int = 64
    # the pipelined map→reduce overlap adds one runnable thread per reduce
    # split; it can only shorten the critical path when the executor pool
    # keeps at least this many slots free of map tasks — a saturated pool
    # means the overlap thread steals time from the maps (GIL + block-store
    # lock convoy), so the boundary falls back to the sequential pull fetch
    pipeline_reduce_slack_threads: int = 1
    # -- compressed-domain execution (DESIGN.md §12) -------------------------
    # evaluate range predicates on frame-of-reference codes and run-level
    # predicates/aggregates on RLE runs without widening the column; off
    # forces the decode-then-evaluate routes (differential tests drive the
    # oracle grid both ways)
    compressed_domain: bool = True


@dataclasses.dataclass
class JoinDecision:
    choice: JoinChoice
    left_bytes: float
    right_bytes: float
    reason: str


def decide_join(left_stats: Optional[StageStats],
                right_stats: Optional[StageStats],
                cfg: PDEConfig = PDEConfig()) -> JoinDecision:
    """§3.1.1: pick join strategy from observed (or partially observed)
    map-output sizes.  Either side's stats may be missing when the optimizer
    scheduled only the likely-small side first."""
    lb = left_stats.total_output_bytes() if left_stats else float("inf")
    rb = right_stats.total_output_bytes() if right_stats else float("inf")
    if lb <= cfg.broadcast_threshold_bytes and lb <= rb:
        return JoinDecision(JoinChoice.BROADCAST_LEFT, lb, rb,
                            f"left observed {lb:.0f}B <= "
                            f"{cfg.broadcast_threshold_bytes:.0f}B threshold")
    if rb <= cfg.broadcast_threshold_bytes:
        return JoinDecision(JoinChoice.BROADCAST_RIGHT, lb, rb,
                            f"right observed {rb:.0f}B <= "
                            f"{cfg.broadcast_threshold_bytes:.0f}B threshold")
    return JoinDecision(JoinChoice.SHUFFLE, lb, rb,
                        "both sides above broadcast threshold")


@dataclasses.dataclass
class ParallelismDecision:
    num_reducers: int
    bucket_groups: List[List[int]]
    skewed_buckets: List[int]
    reason: str


def decide_parallelism(stats: StageStats, num_buckets: int,
                       cfg: PDEConfig = PDEConfig()) -> ParallelismDecision:
    """§3.1.2: choose the reduce degree of parallelism at run time by
    coalescing fine-grained buckets with greedy bin-packing, equalizing
    coalesced partition sizes."""
    sizes = stats.output_bytes_per_bucket(num_buckets)
    n = choose_num_reducers(sizes, cfg.target_reduce_bytes,
                            cfg.min_reducers,
                            min(cfg.max_reducers, num_buckets))
    groups = greedy_bin_pack(sizes.tolist(), n)
    groups = [g for g in groups if g]  # drop empty bins
    mean = float(sizes.mean()) if len(sizes) else 0.0
    skewed = [i for i, s in enumerate(sizes.tolist())
              if mean > 0 and s > cfg.skew_factor * mean]
    return ParallelismDecision(
        len(groups), groups, skewed,
        f"total {sizes.sum():.0f}B -> {len(groups)} reducers "
        f"(target {cfg.target_reduce_bytes:.0f}B each), "
        f"{len(skewed)} skewed buckets bin-packed")


# ---------------------------------------------------------------------------
# Skew-aware shuffle-join splitting (§3.1.2, "data skew" paragraph).
#
# Bin-packing equalizes reducer loads only down to the granularity of one
# hash bucket; a heavy-hitter join key puts its whole bucket on one reducer
# no matter how buckets are grouped.  The runtime fix: *split* a skewed
# bucket's probe-side rows across several reducers and replicate the other
# (build) side's bucket to each — every probe row still meets every matching
# build row exactly once, so the join is unchanged but the hot key's work is
# parallelized.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SkewShard:
    """One reduce split handling 1/num_shards of a skewed bucket: the
    `shard_side` input's bucket is partitioned across shards at MAP-OUTPUT
    granularity (shard s reads map tasks s, s+num_shards, ... — each map
    output is read exactly once across shards, so splitting adds no fetch
    amplification on the big side); the other side's bucket is replicated
    to every shard (broadcast-within-bucket)."""
    bucket: int
    shard: int
    num_shards: int
    shard_side: str  # "left" | "right": the probe side being partitioned


@dataclasses.dataclass
class SkewJoinDecision:
    """Reduce-side plan of one shuffle-join boundary: plain bin-packed
    bucket groups plus SkewShard splits for heavy-hitter buckets."""
    splits: List[object]            # List[int] group | SkewShard
    skewed_buckets: List[int]
    num_reducers: int
    hot_keys: List[object]          # merged heavy-hitter sketch (top keys)
    reason: str


def _skew_side_maps(lsz, rsz, b: int, how: str,
                    left_maps: Optional[int],
                    right_maps: Optional[int]) -> int:
    """Map-task count of the side that would be sharded for bucket `b` —
    the upper bound on how many ways the bucket can split."""
    if how == "inner":
        side_maps = left_maps if lsz[b] >= rsz[b] else right_maps
    else:
        side_maps = left_maps
    return side_maps if side_maps is not None else 1 << 30


def decide_skew_join(left_stats: StageStats, right_stats: StageStats,
                     num_buckets: int, how: str = "inner",
                     cfg: PDEConfig = PDEConfig(),
                     left_maps: Optional[int] = None,
                     right_maps: Optional[int] = None) -> SkewJoinDecision:
    """§3.1.2 applied to joins: bin-pack the well-behaved buckets, split the
    skewed ones.  A bucket is skewed when its combined materialized size
    exceeds `skew_factor`× the mean AND the reducer byte target (splitting
    tiny buckets only adds task overhead).  Shards partition the probe side
    at map-output granularity, so a bucket splits at most as many ways as
    its probe side has map tasks.  For outer joins only the preserved
    (left) side may be strided — striding the NULL-padding side would
    duplicate unmatched left rows per shard."""
    lsz = left_stats.output_bytes_per_bucket(num_buckets)
    rsz = right_stats.output_bytes_per_bucket(num_buckets)
    combined = lsz + rsz
    mean = float(combined.mean()) if num_buckets else 0.0
    skewed = [b for b in range(num_buckets)
              if mean > 0 and combined[b] > cfg.skew_factor * mean
              and combined[b] > cfg.target_reduce_bytes
              and _skew_side_maps(lsz, rsz, b, how, left_maps,
                                  right_maps) >= 2]
    skew_set = set(skewed)
    normal = [b for b in range(num_buckets) if b not in skew_set]

    splits: List[object] = []
    if normal:
        sizes = combined[normal]
        n = choose_num_reducers(sizes, cfg.target_reduce_bytes,
                                cfg.min_reducers,
                                min(cfg.max_reducers, len(normal)))
        groups = greedy_bin_pack(sizes.tolist(), n)
        splits.extend([[normal[i] for i in g] for g in groups if g])

    for b in skewed:
        if how == "inner":
            side = "left" if lsz[b] >= rsz[b] else "right"
        else:
            side = "left"
        cap = _skew_side_maps(lsz, rsz, b, how, left_maps, right_maps)
        num_shards = max(2, int(np.ceil(combined[b]
                                        / cfg.target_reduce_bytes)))
        num_shards = min(num_shards, cfg.max_reducers, cap)
        splits.extend(SkewShard(b, s, num_shards, side)
                      for s in range(num_shards))

    hot = list(left_stats.heavy_hitters(4)) + list(right_stats.heavy_hitters(4))
    reason = (f"{combined.sum():.0f}B over {num_buckets} buckets -> "
              f"{len(splits)} reducers; {len(skewed)} skewed bucket(s) "
              f"split" + (f" (hot keys {hot[:4]})" if skewed and hot else ""))
    return SkewJoinDecision(splits, skewed, len(splits), hot, reason)


# ---------------------------------------------------------------------------
# Compiled-segment backend selection (DESIGN.md §10).
#
# Every pipeline segment executes per partition, and each partition picks
# its evaluation engine at run time from what the columnar store knows about
# it: row count, per-column encodings, and group-key NDV — the same
# piggybacked statistics map pruning uses (§3.3/§3.5).  Pure function of its
# inputs, so unit-testable and replayable, like the join/parallelism
# decisions above.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SegmentBackendDecision:
    route: str        # numpy | jit | colscan | fused_decode_scan | groupby_mxu
    reason: str


def decide_segment_backend(num_rows: int,
                           kernel_eligible: Optional[str] = None,
                           group_ndv: Optional[int] = None,
                           on_tpu: bool = False,
                           cfg: PDEConfig = PDEConfig()
                           ) -> SegmentBackendDecision:
    """Choose how one partition of a pipeline segment executes.

    `kernel_eligible` names the Pallas kernel the segment's shape could
    lower to (decided by the executor from the plan: range-filter+aggregate
    -> colscan / fused_decode_scan, small-group aggregate -> groupby_mxu);
    this function decides whether the partition should actually take it."""
    if num_rows < cfg.segment_min_compiled_rows:
        return SegmentBackendDecision(
            "numpy", f"{num_rows} rows < {cfg.segment_min_compiled_rows} "
            "compiled threshold")
    if kernel_eligible is not None:
        if (kernel_eligible == "groupby_mxu" and group_ndv is not None
                and group_ndv > cfg.segment_groupby_max_ndv):
            return SegmentBackendDecision(
                "jit", f"group NDV {group_ndv} > "
                f"{cfg.segment_groupby_max_ndv}: sort/segment-sum path")
        if num_rows < cfg.segment_kernel_min_rows:
            return SegmentBackendDecision(
                "jit", f"{num_rows} rows < {cfg.segment_kernel_min_rows} "
                "kernel threshold")
        if on_tpu or cfg.segment_force_kernels:
            return SegmentBackendDecision(
                kernel_eligible,
                f"{num_rows} rows, kernel-shaped segment -> "
                f"{kernel_eligible}"
                + ("" if on_tpu else " (forced interpret mode)"))
        return SegmentBackendDecision(
            "jit", "kernel-shaped but no TPU: Pallas interpret mode is a "
            "correctness tool, XLA-fused jit is the CPU fast path")
    return SegmentBackendDecision("jit", f"{num_rows} rows -> fused jit")


def decide_reduce_backend(num_rows: int,
                          kernel_eligible: Optional[str] = None,
                          group_ndv: Optional[int] = None,
                          on_tpu: bool = False,
                          cfg: PDEConfig = PDEConfig()
                          ) -> SegmentBackendDecision:
    """Reduce-side twin of `decide_segment_backend` (DESIGN.md §11): choose
    how one reduce task's merge-aggregate or join probe executes.

    `num_rows` is the task's fetched input size (partial-state rows for a
    merge, combined build+probe rows for a join).  `kernel_eligible` names
    the Pallas kernel the shape could lower to (`segmented_merge` for
    float-state merges with modest group cardinality).  Routing: tiny
    bucket groups always stay on the numpy oracle; on TPU (or forced) the
    jitted/kernel reduce runs, but on CPU numpy IS the fast path — after
    dictionary compaction the reduce states are small host-resident
    arrays, and measured XLA dispatch costs ~2ms against a ~0.2ms
    interpreted merge (DESIGN.md §11), the reduce-side analogue of 'Pallas
    interpret mode is a correctness tool, not a fast path'."""
    if not cfg.reduce_force_compiled \
            and num_rows < cfg.reduce_min_compiled_rows:
        return SegmentBackendDecision(
            "numpy", f"{num_rows} rows < {cfg.reduce_min_compiled_rows} "
            "reduce compiled threshold")
    if not (on_tpu or cfg.reduce_force_compiled):
        return SegmentBackendDecision(
            "numpy", "no TPU: host numpy is the reduce fast path "
            "(compiled reduce engages on TPU or when forced)")
    if kernel_eligible is not None and (on_tpu or cfg.segment_force_kernels):
        if (group_ndv is not None
                and group_ndv > cfg.segment_groupby_max_ndv):
            return SegmentBackendDecision(
                "jit", f"group NDV {group_ndv} > "
                f"{cfg.segment_groupby_max_ndv}: jitted segmented reduce")
        return SegmentBackendDecision(
            kernel_eligible,
            f"{num_rows} rows, kernel-shaped reduce -> {kernel_eligible}"
            + ("" if on_tpu else " (forced interpret mode)"))
    return SegmentBackendDecision(
        "jit", f"{num_rows} rows -> compiled reduce")


def decide_train_backend(num_rows: int, dims: int,
                         kernel_eligible: Optional[str] = None,
                         on_tpu: bool = False,
                         cfg: PDEConfig = PDEConfig()
                         ) -> SegmentBackendDecision:
    """Training twin of `decide_segment_backend` (DESIGN.md §15): choose how
    one cached feature partition computes its per-iteration statistics
    (gradient / centroid assignment).

    `kernel_eligible` names the Pallas kernel the algorithm's update shape
    could lower to (`train_grad` for logistic/linear gradients — the
    groupby_mxu-style tiled-partials kernel); k-means assignment has no
    kernel form yet and passes None.  Routing mirrors the segment rule:
    tiny partitions stay on the numpy oracle (jit dispatch dominates), the
    kernel engages on TPU or when forced and the partition is large enough,
    and the fused assemble+train jit — which decodes DICT/FOR/BITPACK/RLE
    feature blocks in-trace — is the default compiled path."""
    if num_rows < cfg.segment_min_compiled_rows:
        return SegmentBackendDecision(
            "numpy", f"{num_rows} rows < {cfg.segment_min_compiled_rows} "
            "compiled threshold: numpy oracle gradient")
    if kernel_eligible is not None:
        if num_rows < cfg.segment_kernel_min_rows:
            return SegmentBackendDecision(
                "jit", f"{num_rows} rows < {cfg.segment_kernel_min_rows} "
                "kernel threshold")
        if on_tpu or cfg.segment_force_kernels:
            return SegmentBackendDecision(
                kernel_eligible,
                f"{num_rows}x{dims} partition, gradient-shaped update -> "
                f"{kernel_eligible}"
                + ("" if on_tpu else " (forced interpret mode)"))
        return SegmentBackendDecision(
            "jit", "kernel-shaped but no TPU: Pallas interpret mode is a "
            "correctness tool, the fused assemble+train jit is the CPU "
            "fast path")
    return SegmentBackendDecision(
        "jit", f"{num_rows}x{dims} partition -> fused assemble+train jit")


def decide_stage_fusion(num_rows: int, mode: str = "on",
                        backend: str = "compiled", exchange: str = "coded",
                        cfg: PDEConfig = PDEConfig()
                        ) -> SegmentBackendDecision:
    """Whole-stage fusion decision (DESIGN.md §14): should this partition's
    map-side work run as ONE fused stage program — segment + partial
    aggregate + radix bucketing with no host seam before the shuffle — or
    stay on the segment-at-a-time path?

    Routes: "whole-stage" or "segment".  The fused program requires the
    compiled backend and the dictionary-preserving exchange (the decoded
    exchange re-materializes strings between the segment and the shuffle,
    a host seam by definition); `mode="force"` bypasses the row threshold
    (differential tests drive the oracle grid with it), `mode="off"` is
    the semantic-oracle escape hatch."""
    if mode == "off":
        return SegmentBackendDecision("segment", "stage fusion disabled")
    if backend != "compiled":
        return SegmentBackendDecision(
            "segment", "numpy backend: the interpreted oracle keeps every "
            "host seam")
    if exchange != "coded":
        return SegmentBackendDecision(
            "segment", "decoded exchange re-materializes strings before "
            "the shuffle: host seam required")
    if mode != "force" and num_rows < cfg.stage_fusion_min_rows:
        return SegmentBackendDecision(
            "segment", f"{num_rows} rows < {cfg.stage_fusion_min_rows} "
            "stage-fusion threshold")
    return SegmentBackendDecision(
        "whole-stage", f"{num_rows} rows -> fused stage program")


def decide_pipelined_reduce(num_map_splits: int, max_threads: int,
                            mode: str = "on",
                            cfg: PDEConfig = PDEConfig()
                            ) -> SegmentBackendDecision:
    """Should a single-bucket boundary start its reduce DURING the map stage
    (DESIGN.md §14)?  The overlap is an admission decision: the reduce runs
    as an extra runnable thread, so it only shortens the critical path when
    the executor pool has slots the map stage is not using — on a pool the
    map splits saturate, the thread can only steal time from the maps.
    Routes: "pipelined" or "pull".  `mode="force"` bypasses the slack check
    (the §14 chaos/differential tiers drive the overlap machinery
    deterministically at any scale)."""
    if mode == "force":
        return SegmentBackendDecision(
            "pipelined", "stage fusion forced -> overlapped reduce")
    slack = max_threads - num_map_splits
    if slack >= cfg.pipeline_reduce_slack_threads:
        return SegmentBackendDecision(
            "pipelined", f"{slack} spare pool threads -> overlapped reduce")
    return SegmentBackendDecision(
        "pull", f"{num_map_splits} map splits saturate {max_threads} pool "
        "threads -> sequential fetch")


def likely_small_side(left_hint_bytes: Optional[float],
                      right_hint_bytes: Optional[float],
                      left_filtered: bool, right_filtered: bool) -> Optional[str]:
    """Static prior used to order pre-shuffle stages (§6.3.2): a side that is
    initially smaller AND carries a filter predicate is likely to come out
    small, so schedule its map stage first and hope to skip the other side's
    pre-shuffle entirely."""
    def score(hint, filtered):
        s = 0.0
        if filtered:
            s += 1.0
        if hint is not None:
            s += 1.0 / (1.0 + hint / (64 << 20))
        return s
    ls, rs = score(left_hint_bytes, left_filtered), score(right_hint_bytes, right_filtered)
    if ls == rs:
        if left_hint_bytes is not None and right_hint_bytes is not None:
            return "left" if left_hint_bytes <= right_hint_bytes else "right"
        return None
    return "left" if ls > rs else "right"
