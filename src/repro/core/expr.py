"""Expression AST and compiler (paper §5, "Bytecode Compilation of Expression
Evaluators").

Hive interprets operator trees row-by-row; the paper reports that when data is
served from the memory store, the majority of CPU cycles go to interpreting
these evaluators, and proposes compiling them to JVM bytecode.  Our analogue
is strictly stronger: the AST is *traced* into a jaxpr over whole column
arrays, so XLA emits one fused vector kernel per partition — the evaluator is
compiled, vectorized, and fused with the consuming operator.

String semantics: STRING columns are dictionary codes + a partition-local
sorted dictionary.  Because `np.unique` dictionaries are sorted, code order
is lexicographic order, so string comparisons compile to *integer* compares
against a code bound resolved host-side per partition — the evaluator never
touches string bytes on device.  String functions (SUBSTR, LOWER, ...) are
evaluated once on the (small) dictionary and the codes are remapped — the
classic columnar trick, and the reason dictionary encoding is "virtually free
CPU-wise" (§3.2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .types import DType, Schema, common_dtype

# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------


class Expr:
    def columns(self) -> List[str]:
        out: List[str] = []
        self._collect(out)
        return out

    def _collect(self, out: List[str]) -> None:
        for child in self.children():
            child._collect(out)

    def children(self) -> Sequence["Expr"]:
        return ()

    # sugar
    def __add__(self, o): return BinOp("+", self, _lit(o))
    def __sub__(self, o): return BinOp("-", self, _lit(o))
    def __mul__(self, o): return BinOp("*", self, _lit(o))
    def __truediv__(self, o): return BinOp("/", self, _lit(o))
    def __mod__(self, o): return BinOp("%", self, _lit(o))
    def __eq__(self, o): return Cmp("=", self, _lit(o))   # type: ignore[override]
    def __ne__(self, o): return Cmp("!=", self, _lit(o))  # type: ignore[override]
    def __lt__(self, o): return Cmp("<", self, _lit(o))
    def __le__(self, o): return Cmp("<=", self, _lit(o))
    def __gt__(self, o): return Cmp(">", self, _lit(o))
    def __ge__(self, o): return Cmp(">=", self, _lit(o))
    def __and__(self, o): return And(self, o)
    def __or__(self, o): return Or(self, o)
    def __invert__(self): return Not(self)
    def __hash__(self):  # Exprs used as dict keys in planners
        return id(self)

    def alias(self, name: str) -> "Aliased":
        """Name this expression in a SharkFrame select/agg list."""
        return Aliased(name, self)


def _lit(v) -> "Expr":
    return v if isinstance(v, Expr) else Lit(v)


@dataclasses.dataclass(eq=False)
class Aliased:
    """An (output name, expression) pair produced by `Expr.alias()`.

    Not an Expr itself: it is only meaningful in a SharkFrame select/agg
    list (or a GROUP BY key), where the name becomes the output column."""
    name: str
    expr: "Expr"

    def __repr__(self): return f"{self.expr} AS {self.name}"


@dataclasses.dataclass(eq=False)
class Col(Expr):
    name: str

    def _collect(self, out: List[str]) -> None:
        out.append(self.name)

    def __repr__(self): return self.name


@dataclasses.dataclass(eq=False)
class Lit(Expr):
    value: Any

    def __repr__(self): return repr(self.value)


@dataclasses.dataclass(eq=False)
class BinOp(Expr):
    op: str  # + - * / %
    left: Expr
    right: Expr

    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} {self.op} {self.right})"


@dataclasses.dataclass(eq=False)
class Cmp(Expr):
    op: str  # = != < <= > >=
    left: Expr
    right: Expr

    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} {self.op} {self.right})"


@dataclasses.dataclass(eq=False)
class And(Expr):
    left: Expr
    right: Expr
    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} AND {self.right})"


@dataclasses.dataclass(eq=False)
class Or(Expr):
    left: Expr
    right: Expr
    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} OR {self.right})"


@dataclasses.dataclass(eq=False)
class Not(Expr):
    child: Expr
    def children(self): return (self.child,)
    def __repr__(self): return f"(NOT {self.child})"


@dataclasses.dataclass(eq=False)
class Func(Expr):
    """Scalar function call.  Numeric: ABS, FLOOR, CEIL, SQRT, LOG, EXP.
    String (dictionary-evaluated): SUBSTR, LOWER, UPPER, LENGTH."""
    name: str
    args: Tuple[Expr, ...]

    def children(self): return self.args
    def __repr__(self):
        return f"{self.name}({', '.join(map(repr, self.args))})"


@dataclasses.dataclass(eq=False)
class InList(Expr):
    child: Expr
    values: Tuple[Any, ...]
    def children(self): return (self.child,)
    def __repr__(self): return f"({self.child} IN {self.values})"


@dataclasses.dataclass(eq=False)
class Between(Expr):
    child: Expr
    lo: Any
    hi: Any
    def children(self): return (self.child,)
    def __repr__(self): return f"({self.child} BETWEEN {self.lo} AND {self.hi})"


STRING_FUNCS = {"SUBSTR", "LOWER", "UPPER", "CONCAT"}
NUMERIC_FUNCS = {"ABS", "FLOOR", "CEIL", "SQRT", "LOG", "EXP", "LENGTH", "YEAR"}


def infer_dtype(e: Expr, schema: Schema) -> DType:
    if isinstance(e, Col):
        return schema.dtype(e.name)
    if isinstance(e, Lit):
        v = e.value
        if isinstance(v, bool):
            return DType.BOOL
        if isinstance(v, (int, np.integer)):
            return DType.INT64
        if isinstance(v, (float, np.floating)):
            return DType.FLOAT64
        return DType.STRING
    if isinstance(e, BinOp):
        lt, rt = infer_dtype(e.left, schema), infer_dtype(e.right, schema)
        if e.op == "/":
            return DType.FLOAT64
        return common_dtype(lt, rt)
    if isinstance(e, (Cmp, And, Or, Not, InList, Between)):
        return DType.BOOL
    if isinstance(e, Func):
        if e.name in STRING_FUNCS:
            return DType.STRING
        if e.name == "LENGTH" or e.name == "YEAR":
            return DType.INT32
        return DType.FLOAT64
    raise TypeError(type(e))


# ---------------------------------------------------------------------------
# Evaluation context: per-partition columns as (array, optional string dict)
# ---------------------------------------------------------------------------


# Materialization counters.  The dictionary-preserving exchange
# (DESIGN.md §11) promises that shuffle/join/group paths never decode
# string columns to raw values; every ColumnVal.decoded() of a string
# column bumps string_cols/string_rows, so tests and
# benchmarks/shuffle_bench.py can assert the promise (counter delta == 0
# across execute()).  The encoded feature pipeline (DESIGN.md §15) makes
# the same promise for numeric blocks: compression.decode_np bumps
# numeric_blocks/numeric_rows on every host-side materialization of a
# non-PLAIN block (memo misses only), so the encoded FeatureRDD train
# path can assert it hands DICT/FOR/BITPACK/RLE arrays to XLA without a
# single host decode.  Plain dict mutation under the GIL — diagnostic
# counters, not exact statistics.
DECODE_COUNTERS = {"string_cols": 0, "string_rows": 0,
                   "numeric_blocks": 0, "numeric_rows": 0}


def reset_decode_counters() -> None:
    DECODE_COUNTERS["string_cols"] = 0
    DECODE_COUNTERS["string_rows"] = 0
    DECODE_COUNTERS["numeric_blocks"] = 0
    DECODE_COUNTERS["numeric_rows"] = 0


def string_decode_events() -> int:
    return DECODE_COUNTERS["string_cols"]


def numeric_decode_events() -> int:
    return DECODE_COUNTERS["numeric_blocks"]


class ColumnVal:
    """Evaluated column value: either numeric array, or (codes, dictionary).

    May be *block-backed* (the scan path): `block` references the columnar
    store's ColumnBlock and `arr` materializes lazily through the memoized
    decode on first access — the compiled pipeline-segment executor reads
    dictionary codes straight off the block and may never touch `arr` for a
    filter-only column."""

    __slots__ = ("_arr", "sdict", "sorted_dict", "block")

    def __init__(self, arr: Any = None, sdict: Optional[np.ndarray] = None,
                 sorted_dict: bool = True, block: Any = None):
        if arr is None and block is None:
            raise ValueError("ColumnVal needs an array or a backing block")
        self._arr = arr
        self.sdict = sdict          # sorted str dict when string-typed
        self.sorted_dict = sorted_dict  # codes order-preserving w.r.t. strings?
        self.block = block          # columnar.ColumnBlock backing (scan path)

    @property
    def arr(self) -> Any:
        """np/jnp array (codes for strings); decodes lazily when block-backed."""
        if self._arr is None:
            self._arr = self.block.values()
        return self._arr

    @property
    def materialized(self) -> bool:
        return self._arr is not None

    @property
    def is_string(self) -> bool:
        return self.sdict is not None

    def decoded(self) -> np.ndarray:
        if self.sdict is None:
            return np.asarray(self.arr)
        arr = np.asarray(self.arr)
        DECODE_COUNTERS["string_cols"] += 1
        DECODE_COUNTERS["string_rows"] += int(arr.shape[0]) if arr.ndim else 1
        return self.sdict[arr]

    def __repr__(self):
        backing = "lazy" if self._arr is None else "materialized"
        return f"ColumnVal({backing}, string={self.is_string})"


class Evaluator:
    """Compiles/evaluates an Expr against a partition context.

    `xp` is numpy or jax.numpy: the same tree evaluates eagerly on host or
    traces into a jaxpr inside a jitted partition kernel.  Dictionary lookups
    for string literals happen host-side (they depend only on the partition's
    dictionary, not on row data), so the traced function stays numeric.
    """

    def __init__(self, ctx: Dict[str, ColumnVal], xp=np):
        self.ctx = ctx
        self.xp = xp

    def eval(self, e: Expr) -> ColumnVal:
        xp = self.xp
        if isinstance(e, Col):
            if e.name not in self.ctx:
                raise KeyError(f"unbound column {e.name!r}")
            return self.ctx[e.name]
        if isinstance(e, Lit):
            return ColumnVal(e.value)
        if isinstance(e, BinOp):
            l, r = self.eval(e.left), self.eval(e.right)
            a, b = l.arr, r.arr
            if e.op == "+": out = a + b
            elif e.op == "-": out = a - b
            elif e.op == "*": out = a * b
            elif e.op == "/":
                out = xp.asarray(a, dtype=np.float64) / b if not np.isscalar(a) else a / xp.asarray(b, dtype=np.float64)
            elif e.op == "%": out = a % b
            else: raise ValueError(e.op)
            return ColumnVal(out)
        if isinstance(e, Cmp):
            return self._cmp(e)
        if isinstance(e, And):
            return ColumnVal(self.eval(e.left).arr & self.eval(e.right).arr)
        if isinstance(e, Or):
            return ColumnVal(self.eval(e.left).arr | self.eval(e.right).arr)
        if isinstance(e, Not):
            # logical_not, NOT `~`: Python scalar bools invert bitwise
            # (~True == -2), which hypothesis caught on degenerate predicates
            return ColumnVal(xp.logical_not(self.eval(e.child).arr))
        if isinstance(e, InList):
            c = self.eval(e.child)
            if c.is_string:
                mask = None
                for v in e.values:
                    m = self._string_eq(c, str(v))
                    mask = m if mask is None else (mask | m)
                return ColumnVal(mask)
            mask = None
            for v in e.values:
                m = c.arr == v
                mask = m if mask is None else (mask | m)
            return ColumnVal(mask)
        if isinstance(e, Between):
            c = self.eval(e.child)
            if c.is_string:
                lo = self._string_bound(c, str(e.lo), "ge")
                hi = self._string_bound(c, str(e.hi), "le")
                return ColumnVal(lo & hi)
            return ColumnVal((c.arr >= e.lo) & (c.arr <= e.hi))
        if isinstance(e, Func):
            return self._func(e)
        raise TypeError(type(e))

    # -- string machinery ---------------------------------------------------

    def _string_eq(self, c: ColumnVal, v: str):
        assert c.sdict is not None
        if c.sorted_dict:
            i = int(np.searchsorted(c.sdict, v))
            if i < len(c.sdict) and c.sdict[i] == v:
                return c.arr == i
            return self.xp.zeros_like(c.arr, dtype=bool)
        hits = np.flatnonzero(c.sdict == v)
        if len(hits) == 0:
            return self.xp.zeros_like(c.arr, dtype=bool)
        mask = None
        for i in hits.tolist():
            m = c.arr == i
            mask = m if mask is None else (mask | m)
        return mask

    def _string_bound(self, c: ColumnVal, v: str, kind: str):
        """Order comparison against a literal via the sorted dictionary."""
        assert c.sdict is not None
        if not c.sorted_dict:
            # re-sort: map codes through rank of dict
            order = np.argsort(c.sdict)
            rank = np.empty(len(c.sdict), np.int32)
            rank[order] = np.arange(len(c.sdict), dtype=np.int32)
            codes = self.xp.asarray(rank)[c.arr]
            sdict = c.sdict[order]
            c = ColumnVal(codes, sdict, True)
        lo_i = int(np.searchsorted(c.sdict, v, side="left"))
        ri = int(np.searchsorted(c.sdict, v, side="right"))
        if kind == "lt": return c.arr < lo_i
        if kind == "le": return c.arr < ri
        if kind == "gt": return c.arr >= ri
        if kind == "ge": return c.arr >= lo_i
        raise ValueError(kind)

    def _cmp(self, e: Cmp) -> ColumnVal:
        l, r = self.eval(e.left), self.eval(e.right)
        # string vs literal
        if l.is_string and not r.is_string and isinstance(r.arr, str):
            v = r.arr
            if e.op == "=": return ColumnVal(self._string_eq(l, v))
            if e.op == "!=": return ColumnVal(~self._string_eq(l, v))
            kind = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge"}[e.op]
            return ColumnVal(self._string_bound(l, v, kind))
        if r.is_string and not l.is_string and isinstance(l.arr, str):
            flip = {"=": "=", "!=": "!=", "<": ">", "<=": ">=", ">": "<", ">=": "<="}
            return self._cmp(Cmp(flip[e.op], e.right, e.left))
        if l.is_string and r.is_string:
            # decode both (host path only) — rare in our workloads
            a, b = l.decoded(), r.decoded()
        else:
            a, b = l.arr, r.arr
        if e.op == "=": return ColumnVal(a == b)
        if e.op == "!=": return ColumnVal(a != b)
        if e.op == "<": return ColumnVal(a < b)
        if e.op == "<=": return ColumnVal(a <= b)
        if e.op == ">": return ColumnVal(a > b)
        if e.op == ">=": return ColumnVal(a >= b)
        raise ValueError(e.op)

    def _func(self, e: Func) -> ColumnVal:
        xp = self.xp
        if e.name in STRING_FUNCS:
            c = self.eval(e.args[0])
            assert c.is_string, f"{e.name} needs a string column"
            d = c.sdict
            if e.name == "SUBSTR":
                start = int(_const(e.args[1])) - 1  # SQL is 1-based
                ln = int(_const(e.args[2]))
                nd = np.array([s[start:start + ln] for s in d])
            elif e.name == "LOWER":
                nd = np.char.lower(d)
            elif e.name == "UPPER":
                nd = np.char.upper(d)
            else:
                raise NotImplementedError(e.name)
            # transformed dictionary is generally neither unique nor sorted
            return ColumnVal(c.arr, nd, sorted_dict=False)
        if e.name == "LENGTH":
            c = self.eval(e.args[0])
            assert c.is_string
            lens = np.char.str_len(c.sdict).astype(np.int32)
            return ColumnVal(xp.asarray(lens)[c.arr])
        c = self.eval(e.args[0])
        a = c.arr
        if e.name == "ABS": return ColumnVal(xp.abs(a))
        if e.name == "SQRT": return ColumnVal(xp.sqrt(a))
        if e.name == "LOG": return ColumnVal(xp.log(a))
        if e.name == "EXP": return ColumnVal(xp.exp(a))
        if e.name == "FLOOR": return ColumnVal(xp.floor(a))
        if e.name == "CEIL": return ColumnVal(xp.ceil(a))
        if e.name == "YEAR":
            # DATE is days-since-epoch; approximate Hive YEAR()
            return ColumnVal((a // 365.2425 + 1970).astype(np.int32) if xp is np
                             else (a // 365.2425 + 1970).astype(np.int32))
        raise NotImplementedError(e.name)


def _const(e: Expr):
    assert isinstance(e, Lit), f"expected literal, got {e}"
    return e.value


def evaluate(e: Expr, ctx: Dict[str, ColumnVal], xp=np) -> ColumnVal:
    return Evaluator(ctx, xp).eval(e)


# ---------------------------------------------------------------------------
# Expression compiler (paper §5): `compile_expr(e)` lowers an Expr tree into
# ONE traceable columnar closure.  Per partition, the host resolves every
# dictionary-dependent constant (string-literal code bounds, numeric-dict
# bounds, LENGTH tables) into a flat `consts` tuple; the jitted function is
# pure array math over (column arrays, consts) and is therefore shared
# across partitions — XLA emits a single fused vector kernel per segment.
#
# `evaluate(..., xp=)` above remains the semantic oracle: the lowering must
# agree with it bit-for-bit on ints/bools/strings and to rounding on floats
# (tests/test_compile_expr_property.py).  Anything the lowering cannot
# express (string-transforming Funcs, unsorted dictionaries, string-vs-
# string column compares) raises ExprCompileError and the segment executor
# falls back to the numpy evaluator for that partition — recorded per
# partition in ExecMetrics.
# ---------------------------------------------------------------------------


class ExprCompileError(Exception):
    """The expression cannot be lowered to the traced columnar form."""


def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1).  The compiled exchange pads rows,
    groups, and pair counts to powers of two so every jitted reduce program
    re-traces O(log n) times per signature — the shared discipline of
    _PLAN_CACHE, aggregate.CompiledMerge, and joins.CompiledProbe."""
    return 1 << max(0, (int(n) - 1).bit_length())


def literal_compare_columns(*exprs: Expr) -> set:
    """Columns appearing ONLY as the direct child of a literal comparison
    (Cmp vs Lit, Between, InList) across all given trees: their predicates
    can run in dictionary-code space without ever decoding the column."""
    compare_pos: set = set()
    value_pos: set = set()

    def walk(n: Expr) -> None:
        if isinstance(n, Cmp):
            if isinstance(n.left, Col) and isinstance(n.right, Lit):
                compare_pos.add(n.left.name)
                return
            if isinstance(n.right, Col) and isinstance(n.left, Lit):
                compare_pos.add(n.right.name)
                return
        if isinstance(n, (Between, InList)) and isinstance(n.child, Col):
            compare_pos.add(n.child.name)
            return
        if isinstance(n, Col):
            value_pos.add(n.name)
            return
        for ch in n.children():
            walk(ch)

    for e in exprs:
        walk(e)
    return compare_pos - value_pos


@dataclasses.dataclass
class _Low:
    """One lowered subtree: fn(env, consts, xp) -> array, plus a tag saying
    what space the result lives in: ("num",) for plain value arrays,
    ("str", col) / ("ndict", col) for dictionary codes of `col`, and
    ("for", col) for frame-of-reference codes (value - bias) of `col`."""
    fn: Callable
    tag: Tuple


_FLIP_CMP = {"=": "=", "!=": "!=", "<": ">", "<=": ">=", ">": "<", ">=": "<="}


class _Lowering:
    def __init__(self, kinds: Dict[str, str]):
        self.kinds = kinds
        self.extractors: List[Callable] = []

    def _const_idx(self, f: Callable) -> int:
        self.extractors.append(f)
        return len(self.extractors) - 1

    def _bound_idx(self, name: str, kind: str, value, side: str) -> int:
        """Per-partition bound of `value` in the column's code space: a
        searchsorted index into the sorted dictionary (string dict / numeric
        DICT dict), or for frame-of-reference codes the identity-map bound
        `ceil(v) - bias` (left) / `floor(v) + 1 - bias` (right) — FOR codes
        are order-preserving integers, so the same `code >= left-bound`
        compare semantics apply without any dictionary."""
        if kind == "str":
            value = str(value)
        if kind == "for":
            if isinstance(value, str):
                raise ExprCompileError("numeric column vs string literal")
            v = float(value)
            if not math.isfinite(v):
                raise ExprCompileError("non-finite literal vs FOR codes")
            offs = math.ceil(v) if side == "left" else math.floor(v) + 1

            def extract(ctx, name=name, offs=offs):
                fs = ctx[name].block.frame_space()
                if fs is None:   # block recompressed since kinds_for()
                    raise ExprCompileError("FOR frame gone (recompressed)")
                return np.int64(offs - int(fs[1]))

            return self._const_idx(extract)

        def extract(ctx, name=name, kind=kind, value=value, side=side):
            if kind == "str":
                d = ctx[name].sdict
            else:
                cs = ctx[name].block.code_space()
                if cs is None:   # block recompressed since kinds_for()
                    raise ExprCompileError("dict codes gone (recompressed)")
                d = cs[1]
            return np.int64(np.searchsorted(d, value, side=side))

        return self._const_idx(extract)

    @staticmethod
    def _need_num(low: _Low) -> None:
        if low.tag[0] != "num":
            raise ExprCompileError(
                f"dictionary-coded value used in a value position: {low.tag}")

    # -- dictionary-space comparisons ---------------------------------------

    def _dict_cmp(self, op: str, tag: Tuple, value) -> _Low:
        kind, name = tag
        if kind == "str" and not isinstance(value, str):
            raise ExprCompileError("string column vs non-string literal")
        if kind in ("ndict", "for") and isinstance(value, str):
            raise ExprCompileError("numeric column vs string literal")
        lo = self._bound_idx(name, kind, value, "left")
        ri = self._bound_idx(name, kind, value, "right")

        def fn(env, c, xp, name=name, lo=lo, ri=ri, op=op):
            a = env[name]
            if op == "=":
                return (a >= c[lo]) & (a < c[ri])
            if op == "!=":
                return ~((a >= c[lo]) & (a < c[ri]))
            if op == "<":
                return a < c[lo]
            if op == "<=":
                return a < c[ri]
            if op == ">":
                return a >= c[ri]
            if op == ">=":
                return a >= c[lo]
            raise ValueError(op)

        return _Low(fn, ("num",))

    # -- recursive lowering ---------------------------------------------------

    def lower(self, e: Expr) -> _Low:
        if isinstance(e, Col):
            name = e.name
            kind = self.kinds[name]
            fn = lambda env, c, xp, name=name: env[name]
            if kind == "str":
                return _Low(fn, ("str", name))
            if kind == "ndict":
                return _Low(fn, ("ndict", name))
            if kind == "for":
                return _Low(fn, ("for", name))
            return _Low(fn, ("num",))
        if isinstance(e, Lit):
            v = e.value
            if isinstance(v, str):
                raise ExprCompileError("bare string literal")
            return _Low(lambda env, c, xp, v=v: v, ("num",))
        if isinstance(e, BinOp):
            l, r = self.lower(e.left), self.lower(e.right)
            self._need_num(l)
            self._need_num(r)
            op = e.op

            def fn(env, c, xp, l=l, r=r, op=op):
                a, b = l.fn(env, c, xp), r.fn(env, c, xp)
                if op == "+":
                    return a + b
                if op == "-":
                    return a - b
                if op == "*":
                    return a * b
                if op == "/":
                    return (xp.asarray(a, dtype=np.float64) / b
                            if not np.isscalar(a)
                            else a / xp.asarray(b, dtype=np.float64))
                if op == "%":
                    return a % b
                raise ValueError(op)

            return _Low(fn, ("num",))
        if isinstance(e, Cmp):
            # dictionary-space forms first: the literal child must not be
            # lowered (string literals only exist as host-resolved bounds)
            if isinstance(e.right, Lit):
                l = self.lower(e.left)
                if l.tag[0] in ("str", "ndict", "for"):
                    return self._dict_cmp(e.op, l.tag, e.right.value)
            if isinstance(e.left, Lit):
                r = self.lower(e.right)
                if r.tag[0] in ("str", "ndict", "for"):
                    return self._dict_cmp(_FLIP_CMP[e.op], r.tag,
                                          e.left.value)
            l, r = self.lower(e.left), self.lower(e.right)
            self._need_num(l)
            self._need_num(r)
            op = e.op

            def fn(env, c, xp, l=l, r=r, op=op):
                a, b = l.fn(env, c, xp), r.fn(env, c, xp)
                if op == "=":
                    return a == b
                if op == "!=":
                    return a != b
                if op == "<":
                    return a < b
                if op == "<=":
                    return a <= b
                if op == ">":
                    return a > b
                return a >= b

            return _Low(fn, ("num",))
        if isinstance(e, And):
            l, r = self.lower(e.left), self.lower(e.right)
            self._need_num(l)
            self._need_num(r)
            return _Low(lambda env, c, xp, l=l, r=r:
                        l.fn(env, c, xp) & r.fn(env, c, xp), ("num",))
        if isinstance(e, Or):
            l, r = self.lower(e.left), self.lower(e.right)
            self._need_num(l)
            self._need_num(r)
            return _Low(lambda env, c, xp, l=l, r=r:
                        l.fn(env, c, xp) | r.fn(env, c, xp), ("num",))
        if isinstance(e, Not):
            ch = self.lower(e.child)
            self._need_num(ch)
            return _Low(lambda env, c, xp, ch=ch:
                        xp.logical_not(ch.fn(env, c, xp)), ("num",))
        if isinstance(e, InList):
            ch = self.lower(e.child)
            if ch.tag[0] in ("str", "ndict", "for"):
                parts = [self._dict_cmp("=", ch.tag, v) for v in e.values]

                def fn(env, c, xp, parts=parts):
                    mask = None
                    for p in parts:
                        m = p.fn(env, c, xp)
                        mask = m if mask is None else (mask | m)
                    return mask

                return _Low(fn, ("num",))
            self._need_num(ch)
            values = tuple(e.values)
            if any(isinstance(v, str) for v in values):
                raise ExprCompileError("string IN-list on numeric value")

            def fn(env, c, xp, ch=ch, values=values):
                a = ch.fn(env, c, xp)
                mask = None
                for v in values:
                    m = a == v
                    mask = m if mask is None else (mask | m)
                return mask

            return _Low(fn, ("num",))
        if isinstance(e, Between):
            ch = self.lower(e.child)
            if ch.tag[0] in ("str", "ndict", "for"):
                kind, name = ch.tag
                lo = self._bound_idx(name, kind, e.lo, "left")
                ri = self._bound_idx(name, kind, e.hi, "right")
                return _Low(lambda env, c, xp, name=name, lo=lo, ri=ri:
                            (env[name] >= c[lo]) & (env[name] < c[ri]),
                            ("num",))
            self._need_num(ch)
            lo, hi = e.lo, e.hi
            if isinstance(lo, str) or isinstance(hi, str):
                raise ExprCompileError("string BETWEEN on numeric value")
            return _Low(lambda env, c, xp, ch=ch, lo=lo, hi=hi:
                        (lambda a: (a >= lo) & (a <= hi))(ch.fn(env, c, xp)),
                        ("num",))
        if isinstance(e, Func):
            if e.name in STRING_FUNCS:
                raise ExprCompileError(
                    f"string function {e.name} (dictionary transform)")
            if e.name == "LENGTH":
                ch = self.lower(e.args[0])
                if ch.tag[0] != "str":
                    raise ExprCompileError("LENGTH of non-string")
                name = ch.tag[1]

                def extract(ctx, name=name):
                    return np.char.str_len(ctx[name].sdict).astype(np.int32)

                li = self._const_idx(extract)
                return _Low(lambda env, c, xp, name=name, li=li:
                            xp.asarray(c[li])[env[name]], ("num",))
            ch = self.lower(e.args[0])
            self._need_num(ch)
            fname = e.name

            def fn(env, c, xp, ch=ch, fname=fname):
                a = ch.fn(env, c, xp)
                if fname == "ABS":
                    return xp.abs(a)
                if fname == "SQRT":
                    return xp.sqrt(a)
                if fname == "LOG":
                    return xp.log(a)
                if fname == "EXP":
                    return xp.exp(a)
                if fname == "FLOOR":
                    return xp.floor(a)
                if fname == "CEIL":
                    return xp.ceil(a)
                if fname == "YEAR":
                    return (a // 365.2425 + 1970).astype(np.int32)
                raise ExprCompileError(fname)

            return _Low(fn, ("num",))
        raise ExprCompileError(f"cannot lower {type(e).__name__}")


@dataclasses.dataclass
class _ExprPlan:
    jitfn: Callable
    extractors: List[Callable]
    out_str_cols: List[Optional[str]]   # per output: codes of this str col


# Compiled plans are shared process-wide, keyed by (expression structure,
# partition layout signature): two queries with the same predicate shape
# reuse one jitted function instead of re-tracing — jax.jit caches per
# function object, so without this every query would recompile.
_PLAN_CACHE: Dict[Tuple, _ExprPlan] = {}
_PLAN_CACHE_MAX = 512


def _plan_cache_get(key: Tuple) -> Optional[_ExprPlan]:
    return _PLAN_CACHE.get(key)


def _plan_cache_put(key: Tuple, plan: _ExprPlan) -> None:
    if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
        _PLAN_CACHE.clear()     # crude but bounded; plans rebuild on demand
    _PLAN_CACHE[key] = plan


class CompiledExprSet:
    """Several expressions (a segment's predicate plus its computed
    projections) lowered through ONE shared lowering and traced into ONE
    jitted function returning all outputs — the whole segment is a single
    fused XLA program per partition.

    Lowering is cached per *signature* — the tuple of (column, space)
    choices, which can differ between partitions because compression is
    chosen per partition (§3.2) — so every partition with the same layout
    reuses one compiled function."""

    def __init__(self, exprs: Sequence[Expr], compressed_domain: bool = True):
        self.exprs = list(exprs)
        self.compressed_domain = compressed_domain
        for e in self.exprs:
            if not _structurally_compilable(e):
                raise ExprCompileError("string-transforming function in tree")
        cols: set = set()
        for e in self.exprs:
            cols.update(e.columns())
        self.cols = sorted(cols)
        self.code_candidates = literal_compare_columns(*self.exprs)
        # structural identity for the cross-query plan cache: reprs carry
        # operators, column names, and literal values
        self._key = tuple(repr(e) for e in self.exprs)
        self._plans: Dict[Tuple, _ExprPlan] = {}

    # -- per-partition layout --------------------------------------------------

    def kinds_for(self, ctx: Dict[str, ColumnVal]) -> Dict[str, str]:
        kinds: Dict[str, str] = {}
        for name in self.cols:
            if name not in ctx:
                raise ExprCompileError(f"unbound column {name!r}")
            v = ctx[name]
            if v.is_string:
                if not v.sorted_dict:
                    raise ExprCompileError("unsorted string dictionary")
                kinds[name] = "str"
            elif (name in self.code_candidates and v.block is not None
                    and v.block.code_space() is not None):
                kinds[name] = "ndict"
            elif (self.compressed_domain and name in self.code_candidates
                    and v.block is not None
                    and v.block.frame_space() is not None):
                # frame-of-reference codes: range predicates run on the
                # narrow (value - bias) lane without widening (§12)
                kinds[name] = "for"
            else:
                kinds[name] = "vals"
        return kinds

    def _plan_for(self, kinds: Dict[str, str]) -> _ExprPlan:
        sig = tuple((n, kinds[n]) for n in self.cols)
        plan = self._plans.get(sig)
        if plan is not None:
            return plan
        cache_key = (self._key, sig)
        plan = _plan_cache_get(cache_key)
        if plan is not None:
            self._plans[sig] = plan
            return plan
        import jax
        import jax.numpy as jnp
        lowering = _Lowering(kinds)
        lows: List[_Low] = []
        out_str_cols: List[Optional[str]] = []
        for e in self.exprs:
            low = lowering.lower(e)
            if low.tag[0] == "str":
                out_str_cols.append(low.tag[1])
            elif low.tag[0] == "ndict":
                # bare numeric-dict column as an output: decode fused at
                # the boundary (dictionary gather inside the traced fn)
                name = low.tag[1]

                def _dict_of(ctx, name=name):
                    cs = ctx[name].block.code_space()
                    if cs is None:   # recompressed since kinds_for()
                        raise ExprCompileError("dict codes gone")
                    return cs[1]

                di = lowering._const_idx(_dict_of)
                inner = low
                low = _Low(lambda env, c, xp, inner=inner, di=di:
                           xp.asarray(c[di])[inner.fn(env, c, xp)], ("num",))
                out_str_cols.append(None)
            elif low.tag[0] == "for":
                # bare FOR column as an output: un-bias fused at the
                # boundary (add the frame base in the original dtype)
                name = low.tag[1]

                def _bias_of(ctx, name=name):
                    blk = ctx[name].block
                    fs = blk.frame_space()
                    if fs is None:   # recompressed since kinds_for()
                        raise ExprCompileError("FOR frame gone")
                    return np.asarray(fs[1], dtype=blk.enc.orig_dtype)

                bi = lowering._const_idx(_bias_of)
                inner = low
                low = _Low(lambda env, c, xp, inner=inner, bi=bi:
                           xp.asarray(inner.fn(env, c, xp),
                                      dtype=c[bi].dtype) + c[bi], ("num",))
                out_str_cols.append(None)
            else:
                out_str_cols.append(None)
            lows.append(low)

        def traced(env, consts, lows=tuple(lows)):
            return tuple(low.fn(env, consts, jnp) for low in lows)

        plan = _ExprPlan(jax.jit(traced), lowering.extractors, out_str_cols)
        self._plans[sig] = plan
        _plan_cache_put(cache_key, plan)
        return plan

    # -- execution -------------------------------------------------------------

    def __call__(self, ctx: Dict[str, ColumnVal]) -> List[ColumnVal]:
        kinds = self.kinds_for(ctx)
        plan = self._plan_for(kinds)
        env = {}
        for n in self.cols:
            if kinds[n] == "ndict":
                cs = ctx[n].block.code_space()
                if cs is None:   # recompressed between kinds_for and here
                    raise ExprCompileError("dict codes gone (recompressed)")
                env[n] = np.asarray(cs[0])
            elif kinds[n] == "for":
                fs = ctx[n].block.frame_space()
                if fs is None:   # recompressed between kinds_for and here
                    raise ExprCompileError("FOR frame gone (recompressed)")
                env[n] = np.asarray(fs[0])
            else:
                env[n] = np.asarray(ctx[n].arr)
        consts = tuple(np.asarray(f(ctx)) for f in plan.extractors)
        with _x64():
            outs = plan.jitfn(env, consts)
        results: List[ColumnVal] = []
        for out, str_col in zip(outs, plan.out_str_cols):
            arr = np.asarray(out)
            if str_col is not None:
                src = ctx[str_col]
                results.append(ColumnVal(arr, src.sdict, src.sorted_dict))
            else:
                results.append(ColumnVal(arr))
        return results


class CompiledExpr(CompiledExprSet):
    """`compile_expr(e)`: a one-expression CompiledExprSet returning the
    single ColumnVal directly."""

    def __init__(self, expr: Expr, compressed_domain: bool = True):
        super().__init__([expr], compressed_domain=compressed_domain)
        self.expr = expr

    def __call__(self, ctx: Dict[str, ColumnVal]) -> ColumnVal:
        return super().__call__(ctx)[0]


def _structurally_compilable(e: Expr) -> bool:
    if isinstance(e, Func) and e.name in STRING_FUNCS:
        return False
    return all(_structurally_compilable(ch) for ch in e.children())


def compile_expr(e: Expr) -> CompiledExpr:
    """Compile an expression to a traced columnar function.  Raises
    ExprCompileError eagerly for trees the lowering can never express
    (string-transforming functions); partition-layout-dependent failures
    surface at call time instead and the caller falls back to evaluate()."""
    return CompiledExpr(e)


# ---------------------------------------------------------------------------
# Predicate normalization helpers used by map pruning and pushdown
# ---------------------------------------------------------------------------


def rewrite_expr(e: Expr, fn: Callable[[Expr], Optional[Expr]]) -> Expr:
    """Generic top-down expression rewrite: `fn(node)` returns a replacement
    subtree (recursion stops there) or None to keep the node, in which case
    it is shallow-copied and its children rewritten.  The single walker for
    every rewriter (predicate pushdown substitution, HAVING resolution, ...)
    so Expr attribute conventions live in one place."""
    out = fn(e)
    if out is not None:
        return out
    import copy
    c = copy.copy(e)
    for attr in ("left", "right"):
        if hasattr(c, attr):
            setattr(c, attr, rewrite_expr(getattr(c, attr), fn))
    if hasattr(c, "child") and isinstance(getattr(c, "child"), Expr):
        c.child = rewrite_expr(c.child, fn)
    if hasattr(c, "args"):
        c.args = tuple(rewrite_expr(x, fn) for x in c.args)
    return c


def split_conjuncts(e: Optional[Expr]) -> List[Expr]:
    if e is None:
        return []
    if isinstance(e, And):
        return split_conjuncts(e.left) + split_conjuncts(e.right)
    return [e]


def conjoin(exprs: Sequence[Expr]) -> Optional[Expr]:
    out: Optional[Expr] = None
    for e in exprs:
        out = e if out is None else And(out, e)
    return out
