"""Expression AST and compiler (paper §5, "Bytecode Compilation of Expression
Evaluators").

Hive interprets operator trees row-by-row; the paper reports that when data is
served from the memory store, the majority of CPU cycles go to interpreting
these evaluators, and proposes compiling them to JVM bytecode.  Our analogue
is strictly stronger: the AST is *traced* into a jaxpr over whole column
arrays, so XLA emits one fused vector kernel per partition — the evaluator is
compiled, vectorized, and fused with the consuming operator.

String semantics: STRING columns are dictionary codes + a partition-local
sorted dictionary.  Because `np.unique` dictionaries are sorted, code order
is lexicographic order, so string comparisons compile to *integer* compares
against a code bound resolved host-side per partition — the evaluator never
touches string bytes on device.  String functions (SUBSTR, LOWER, ...) are
evaluated once on the (small) dictionary and the codes are remapped — the
classic columnar trick, and the reason dictionary encoding is "virtually free
CPU-wise" (§3.2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .types import DType, Schema, common_dtype

# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------


class Expr:
    def columns(self) -> List[str]:
        out: List[str] = []
        self._collect(out)
        return out

    def _collect(self, out: List[str]) -> None:
        for child in self.children():
            child._collect(out)

    def children(self) -> Sequence["Expr"]:
        return ()

    # sugar
    def __add__(self, o): return BinOp("+", self, _lit(o))
    def __sub__(self, o): return BinOp("-", self, _lit(o))
    def __mul__(self, o): return BinOp("*", self, _lit(o))
    def __truediv__(self, o): return BinOp("/", self, _lit(o))
    def __mod__(self, o): return BinOp("%", self, _lit(o))
    def __eq__(self, o): return Cmp("=", self, _lit(o))   # type: ignore[override]
    def __ne__(self, o): return Cmp("!=", self, _lit(o))  # type: ignore[override]
    def __lt__(self, o): return Cmp("<", self, _lit(o))
    def __le__(self, o): return Cmp("<=", self, _lit(o))
    def __gt__(self, o): return Cmp(">", self, _lit(o))
    def __ge__(self, o): return Cmp(">=", self, _lit(o))
    def __and__(self, o): return And(self, o)
    def __or__(self, o): return Or(self, o)
    def __invert__(self): return Not(self)
    def __hash__(self):  # Exprs used as dict keys in planners
        return id(self)

    def alias(self, name: str) -> "Aliased":
        """Name this expression in a SharkFrame select/agg list."""
        return Aliased(name, self)


def _lit(v) -> "Expr":
    return v if isinstance(v, Expr) else Lit(v)


@dataclasses.dataclass(eq=False)
class Aliased:
    """An (output name, expression) pair produced by `Expr.alias()`.

    Not an Expr itself: it is only meaningful in a SharkFrame select/agg
    list (or a GROUP BY key), where the name becomes the output column."""
    name: str
    expr: "Expr"

    def __repr__(self): return f"{self.expr} AS {self.name}"


@dataclasses.dataclass(eq=False)
class Col(Expr):
    name: str

    def _collect(self, out: List[str]) -> None:
        out.append(self.name)

    def __repr__(self): return self.name


@dataclasses.dataclass(eq=False)
class Lit(Expr):
    value: Any

    def __repr__(self): return repr(self.value)


@dataclasses.dataclass(eq=False)
class BinOp(Expr):
    op: str  # + - * / %
    left: Expr
    right: Expr

    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} {self.op} {self.right})"


@dataclasses.dataclass(eq=False)
class Cmp(Expr):
    op: str  # = != < <= > >=
    left: Expr
    right: Expr

    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} {self.op} {self.right})"


@dataclasses.dataclass(eq=False)
class And(Expr):
    left: Expr
    right: Expr
    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} AND {self.right})"


@dataclasses.dataclass(eq=False)
class Or(Expr):
    left: Expr
    right: Expr
    def children(self): return (self.left, self.right)
    def __repr__(self): return f"({self.left} OR {self.right})"


@dataclasses.dataclass(eq=False)
class Not(Expr):
    child: Expr
    def children(self): return (self.child,)
    def __repr__(self): return f"(NOT {self.child})"


@dataclasses.dataclass(eq=False)
class Func(Expr):
    """Scalar function call.  Numeric: ABS, FLOOR, CEIL, SQRT, LOG, EXP.
    String (dictionary-evaluated): SUBSTR, LOWER, UPPER, LENGTH."""
    name: str
    args: Tuple[Expr, ...]

    def children(self): return self.args
    def __repr__(self):
        return f"{self.name}({', '.join(map(repr, self.args))})"


@dataclasses.dataclass(eq=False)
class InList(Expr):
    child: Expr
    values: Tuple[Any, ...]
    def children(self): return (self.child,)
    def __repr__(self): return f"({self.child} IN {self.values})"


@dataclasses.dataclass(eq=False)
class Between(Expr):
    child: Expr
    lo: Any
    hi: Any
    def children(self): return (self.child,)
    def __repr__(self): return f"({self.child} BETWEEN {self.lo} AND {self.hi})"


STRING_FUNCS = {"SUBSTR", "LOWER", "UPPER", "CONCAT"}
NUMERIC_FUNCS = {"ABS", "FLOOR", "CEIL", "SQRT", "LOG", "EXP", "LENGTH", "YEAR"}


def infer_dtype(e: Expr, schema: Schema) -> DType:
    if isinstance(e, Col):
        return schema.dtype(e.name)
    if isinstance(e, Lit):
        v = e.value
        if isinstance(v, bool):
            return DType.BOOL
        if isinstance(v, (int, np.integer)):
            return DType.INT64
        if isinstance(v, (float, np.floating)):
            return DType.FLOAT64
        return DType.STRING
    if isinstance(e, BinOp):
        lt, rt = infer_dtype(e.left, schema), infer_dtype(e.right, schema)
        if e.op == "/":
            return DType.FLOAT64
        return common_dtype(lt, rt)
    if isinstance(e, (Cmp, And, Or, Not, InList, Between)):
        return DType.BOOL
    if isinstance(e, Func):
        if e.name in STRING_FUNCS:
            return DType.STRING
        if e.name == "LENGTH" or e.name == "YEAR":
            return DType.INT32
        return DType.FLOAT64
    raise TypeError(type(e))


# ---------------------------------------------------------------------------
# Evaluation context: per-partition columns as (array, optional string dict)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnVal:
    """Evaluated column value: either numeric array, or (codes, dictionary)."""
    arr: Any                       # np/jnp array (codes for strings)
    sdict: Optional[np.ndarray] = None  # sorted str dict when string-typed
    sorted_dict: bool = True       # codes order-preserving w.r.t. strings?

    @property
    def is_string(self) -> bool:
        return self.sdict is not None

    def decoded(self) -> np.ndarray:
        if self.sdict is None:
            return np.asarray(self.arr)
        return self.sdict[np.asarray(self.arr)]


class Evaluator:
    """Compiles/evaluates an Expr against a partition context.

    `xp` is numpy or jax.numpy: the same tree evaluates eagerly on host or
    traces into a jaxpr inside a jitted partition kernel.  Dictionary lookups
    for string literals happen host-side (they depend only on the partition's
    dictionary, not on row data), so the traced function stays numeric.
    """

    def __init__(self, ctx: Dict[str, ColumnVal], xp=np):
        self.ctx = ctx
        self.xp = xp

    def eval(self, e: Expr) -> ColumnVal:
        xp = self.xp
        if isinstance(e, Col):
            if e.name not in self.ctx:
                raise KeyError(f"unbound column {e.name!r}")
            return self.ctx[e.name]
        if isinstance(e, Lit):
            return ColumnVal(e.value)
        if isinstance(e, BinOp):
            l, r = self.eval(e.left), self.eval(e.right)
            a, b = l.arr, r.arr
            if e.op == "+": out = a + b
            elif e.op == "-": out = a - b
            elif e.op == "*": out = a * b
            elif e.op == "/":
                out = xp.asarray(a, dtype=np.float64) / b if not np.isscalar(a) else a / xp.asarray(b, dtype=np.float64)
            elif e.op == "%": out = a % b
            else: raise ValueError(e.op)
            return ColumnVal(out)
        if isinstance(e, Cmp):
            return self._cmp(e)
        if isinstance(e, And):
            return ColumnVal(self.eval(e.left).arr & self.eval(e.right).arr)
        if isinstance(e, Or):
            return ColumnVal(self.eval(e.left).arr | self.eval(e.right).arr)
        if isinstance(e, Not):
            # logical_not, NOT `~`: Python scalar bools invert bitwise
            # (~True == -2), which hypothesis caught on degenerate predicates
            return ColumnVal(xp.logical_not(self.eval(e.child).arr))
        if isinstance(e, InList):
            c = self.eval(e.child)
            if c.is_string:
                mask = None
                for v in e.values:
                    m = self._string_eq(c, str(v))
                    mask = m if mask is None else (mask | m)
                return ColumnVal(mask)
            mask = None
            for v in e.values:
                m = c.arr == v
                mask = m if mask is None else (mask | m)
            return ColumnVal(mask)
        if isinstance(e, Between):
            c = self.eval(e.child)
            if c.is_string:
                lo = self._string_bound(c, str(e.lo), "ge")
                hi = self._string_bound(c, str(e.hi), "le")
                return ColumnVal(lo & hi)
            return ColumnVal((c.arr >= e.lo) & (c.arr <= e.hi))
        if isinstance(e, Func):
            return self._func(e)
        raise TypeError(type(e))

    # -- string machinery ---------------------------------------------------

    def _string_eq(self, c: ColumnVal, v: str):
        assert c.sdict is not None
        if c.sorted_dict:
            i = int(np.searchsorted(c.sdict, v))
            if i < len(c.sdict) and c.sdict[i] == v:
                return c.arr == i
            return self.xp.zeros_like(c.arr, dtype=bool)
        hits = np.flatnonzero(c.sdict == v)
        if len(hits) == 0:
            return self.xp.zeros_like(c.arr, dtype=bool)
        mask = None
        for i in hits.tolist():
            m = c.arr == i
            mask = m if mask is None else (mask | m)
        return mask

    def _string_bound(self, c: ColumnVal, v: str, kind: str):
        """Order comparison against a literal via the sorted dictionary."""
        assert c.sdict is not None
        if not c.sorted_dict:
            # re-sort: map codes through rank of dict
            order = np.argsort(c.sdict)
            rank = np.empty(len(c.sdict), np.int32)
            rank[order] = np.arange(len(c.sdict), dtype=np.int32)
            codes = self.xp.asarray(rank)[c.arr]
            sdict = c.sdict[order]
            c = ColumnVal(codes, sdict, True)
        lo_i = int(np.searchsorted(c.sdict, v, side="left"))
        ri = int(np.searchsorted(c.sdict, v, side="right"))
        if kind == "lt": return c.arr < lo_i
        if kind == "le": return c.arr < ri
        if kind == "gt": return c.arr >= ri
        if kind == "ge": return c.arr >= lo_i
        raise ValueError(kind)

    def _cmp(self, e: Cmp) -> ColumnVal:
        l, r = self.eval(e.left), self.eval(e.right)
        # string vs literal
        if l.is_string and not r.is_string and isinstance(r.arr, str):
            v = r.arr
            if e.op == "=": return ColumnVal(self._string_eq(l, v))
            if e.op == "!=": return ColumnVal(~self._string_eq(l, v))
            kind = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge"}[e.op]
            return ColumnVal(self._string_bound(l, v, kind))
        if r.is_string and not l.is_string and isinstance(l.arr, str):
            flip = {"=": "=", "!=": "!=", "<": ">", "<=": ">=", ">": "<", ">=": "<="}
            return self._cmp(Cmp(flip[e.op], e.right, e.left))
        if l.is_string and r.is_string:
            # decode both (host path only) — rare in our workloads
            a, b = l.decoded(), r.decoded()
        else:
            a, b = l.arr, r.arr
        if e.op == "=": return ColumnVal(a == b)
        if e.op == "!=": return ColumnVal(a != b)
        if e.op == "<": return ColumnVal(a < b)
        if e.op == "<=": return ColumnVal(a <= b)
        if e.op == ">": return ColumnVal(a > b)
        if e.op == ">=": return ColumnVal(a >= b)
        raise ValueError(e.op)

    def _func(self, e: Func) -> ColumnVal:
        xp = self.xp
        if e.name in STRING_FUNCS:
            c = self.eval(e.args[0])
            assert c.is_string, f"{e.name} needs a string column"
            d = c.sdict
            if e.name == "SUBSTR":
                start = int(_const(e.args[1])) - 1  # SQL is 1-based
                ln = int(_const(e.args[2]))
                nd = np.array([s[start:start + ln] for s in d])
            elif e.name == "LOWER":
                nd = np.char.lower(d)
            elif e.name == "UPPER":
                nd = np.char.upper(d)
            else:
                raise NotImplementedError(e.name)
            # transformed dictionary is generally neither unique nor sorted
            return ColumnVal(c.arr, nd, sorted_dict=False)
        if e.name == "LENGTH":
            c = self.eval(e.args[0])
            assert c.is_string
            lens = np.char.str_len(c.sdict).astype(np.int32)
            return ColumnVal(xp.asarray(lens)[c.arr])
        c = self.eval(e.args[0])
        a = c.arr
        if e.name == "ABS": return ColumnVal(xp.abs(a))
        if e.name == "SQRT": return ColumnVal(xp.sqrt(a))
        if e.name == "LOG": return ColumnVal(xp.log(a))
        if e.name == "EXP": return ColumnVal(xp.exp(a))
        if e.name == "FLOOR": return ColumnVal(xp.floor(a))
        if e.name == "CEIL": return ColumnVal(xp.ceil(a))
        if e.name == "YEAR":
            # DATE is days-since-epoch; approximate Hive YEAR()
            return ColumnVal((a // 365.2425 + 1970).astype(np.int32) if xp is np
                             else (a // 365.2425 + 1970).astype(np.int32))
        raise NotImplementedError(e.name)


def _const(e: Expr):
    assert isinstance(e, Lit), f"expected literal, got {e}"
    return e.value


def evaluate(e: Expr, ctx: Dict[str, ColumnVal], xp=np) -> ColumnVal:
    return Evaluator(ctx, xp).eval(e)


# ---------------------------------------------------------------------------
# Predicate normalization helpers used by map pruning and pushdown
# ---------------------------------------------------------------------------


def rewrite_expr(e: Expr, fn: Callable[[Expr], Optional[Expr]]) -> Expr:
    """Generic top-down expression rewrite: `fn(node)` returns a replacement
    subtree (recursion stops there) or None to keep the node, in which case
    it is shallow-copied and its children rewritten.  The single walker for
    every rewriter (predicate pushdown substitution, HAVING resolution, ...)
    so Expr attribute conventions live in one place."""
    out = fn(e)
    if out is not None:
        return out
    import copy
    c = copy.copy(e)
    for attr in ("left", "right"):
        if hasattr(c, attr):
            setattr(c, attr, rewrite_expr(getattr(c, attr), fn))
    if hasattr(c, "child") and isinstance(getattr(c, "child"), Expr):
        c.child = rewrite_expr(c.child, fn)
    if hasattr(c, "args"):
        c.args = tuple(rewrite_expr(x, fn) for x in c.args)
    return c


def split_conjuncts(e: Optional[Expr]) -> List[Expr]:
    if e is None:
        return []
    if isinstance(e, And):
        return split_conjuncts(e.left) + split_conjuncts(e.right)
    return [e]


def conjoin(exprs: Sequence[Expr]) -> Optional[Expr]:
    out: Optional[Expr] = None
    for e in exprs:
        out = e if out is None else And(out, e)
    return out
