"""Map pruning (paper §3.5).

Shark's memory store piggybacks statistics collection on data loading: per
partition, the range of each column and the distinct-value set for enum
columns.  At query time the master evaluates the query's predicate against
every partition's stats and *does not launch tasks* for partitions that
provably contain no matching row.  On the real warehouse trace this cut data
scanned by ~30x; 3277 of 3833 sampled queries had prunable predicates.

`may_match` is deliberately conservative: it returns False only when the
stats *refute* the predicate.  Anything it cannot reason about returns True
(scan the partition).
"""

from __future__ import annotations

from typing import Dict, Optional

from .columnar import ColumnStats
from .expr import (And, Between, Cmp, Col, Expr, Func, InList, Lit, Not, Or)


def _col_lit(e: Cmp):
    """Normalize Cmp to (col, op, literal) if it has that shape."""
    if isinstance(e.left, Col) and isinstance(e.right, Lit):
        return e.left.name, e.op, e.right.value
    if isinstance(e.right, Col) and isinstance(e.left, Lit):
        flip = {"=": "=", "!=": "!=", "<": ">", "<=": ">=", ">": "<", ">=": "<="}
        return e.right.name, flip[e.op], e.left.value
    return None


def may_match(pred: Optional[Expr], stats: Dict[str, ColumnStats]) -> bool:
    """Could any row of a partition with these stats satisfy `pred`?"""
    if pred is None:
        return True
    if isinstance(pred, And):
        return may_match(pred.left, stats) and may_match(pred.right, stats)
    if isinstance(pred, Or):
        return may_match(pred.left, stats) or may_match(pred.right, stats)
    if isinstance(pred, Not):
        inner = pred.child
        # only refute NOT(col = v) when the partition is constant v
        if isinstance(inner, Cmp):
            norm = _col_lit(inner)
            if norm is not None:
                col, op, v = norm
                st = stats.get(col)
                if st is not None and op == "=" and st.distinct is not None \
                        and st.distinct == frozenset({_as_stat_value(v)}):
                    return False
        return True
    if isinstance(pred, Between):
        if isinstance(pred.child, Col):
            st = stats.get(pred.child.name)
            if st is not None and _is_number(pred.lo) and _is_number(pred.hi):
                return st.may_satisfy_range(pred.lo, pred.hi)
        return True
    if isinstance(pred, InList):
        if isinstance(pred.child, Col):
            st = stats.get(pred.child.name)
            if st is not None:
                return any(_value_possible(st, v) for v in pred.values)
        return True
    if isinstance(pred, Cmp):
        norm = _col_lit(pred)
        if norm is None:
            return True
        col, op, v = norm
        st = stats.get(col)
        if st is None:
            return True
        if op == "=":
            return _value_possible(st, v)
        if op == "!=":
            # refute only if partition is constant v
            if st.distinct is not None and st.distinct == frozenset({_as_stat_value(v)}):
                return False
            return True
        if not _is_number(v):
            # string range compares: refutable via distinct set only
            if st.distinct is not None:
                import numpy as np
                vals = list(st.distinct)
                if op == "<":
                    return any(x < v for x in vals)
                if op == "<=":
                    return any(x <= v for x in vals)
                if op == ">":
                    return any(x > v for x in vals)
                if op == ">=":
                    return any(x >= v for x in vals)
            return True
        if op == "<":
            return st.min is None or st.min < v
        if op == "<=":
            return st.min is None or st.min <= v
        if op == ">":
            return st.max is None or st.max > v
        if op == ">=":
            return st.max is None or st.max >= v
    return True


def _is_number(v) -> bool:
    import numpy as np
    return isinstance(v, (int, float, np.integer, np.floating)) and not isinstance(v, bool)


def _as_stat_value(v):
    return float(v) if _is_number(v) else v


def _value_possible(st: ColumnStats, v) -> bool:
    if st.distinct is not None:
        return v in st.distinct or _as_stat_value(v) in st.distinct
    if _is_number(v):
        return st.may_satisfy_range(v, v)
    return True
