"""Whole-stage compilation v2 (DESIGN.md §14).

A StageRunner drives ONE map stage — scan→filter→project→radix_partition→
map-side aggregate — as a single traced program per partition, without
returning to host between the compiled segment and the shuffle:

  * the segment + partial aggregate run through the SegmentRunner's routed
    backends (Pallas colscan / groupby_mxu / fused jit), exactly as the
    segment-at-a-time path would;
  * the bucket assignment (the SAME partitioner closure the scheduler would
    call) and the per-bucket slicing (the scheduler's exact stable-argsort /
    searchsorted / take code, via `split_bucket_pieces`) run inside the map
    task, so the task hands the scheduler a `BucketedBatch` of finished
    shuffle pieces — byte-identical to the blocks the seam-by-seam path
    produces, including under lineage recovery (tasks are deterministic);
  * sort/limit stages ship their single-reducer output as a zero-copy
    one-piece BucketedBatch — no host re-assembly copy for pass-through
    columns (the BENCH_exec_engine "transfer-bound" seam).

Fallback ladder (any rung keeps results identical):
  1. PDE gate (`decide_stage_fusion`): numpy backend, decoded exchange,
     `stage_fusion="off"`, or a partition under the row threshold → the
     unfused segment-at-a-time path;
  2. the routed segment itself picks the numpy oracle (tiny partition or
     ExprCompileError fallback) → the plain batch is returned and the
     scheduler applies the legacy partition/slice seam;
  3. anything downstream (pipelined reduce failure, worker death) falls
     back to pull-based reduces over the same shuffle blocks.

Fusion is physical-layer only: `explain()` and `plan_fingerprint` never see
it (asserted by the §14 test tier).
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .batch import PartitionBatch
from .pde import PDEConfig, decide_segment_backend, decide_stage_fusion
from .plan import AggSpec
from .shuffle import BucketedBatch, split_bucket_pieces


class StageRunner:
    """Fused map-stage driver wrapping one SegmentRunner (physical.py)."""

    def __init__(self, runner, partitioner: Callable, num_buckets: int,
                 mode: str, cfg: PDEConfig, topk=None):
        self.runner = runner
        self.partitioner = partitioner
        self.num_buckets = num_buckets
        self.mode = mode                     # "on" | "force"
        self.cfg = cfg
        # (lane columns, query weights) when the sort stage's key is a
        # dot-product similarity score (physical._match_topk): eligible
        # partitions replace the host lexsort with the Pallas
        # topk_similarity kernel (DESIGN.md §15.3)
        self.topk = topk

    def _gate(self, num_rows: int) -> bool:
        d = decide_stage_fusion(num_rows, self.mode, self.runner.backend,
                                "coded", self.cfg)
        return d.route == "whole-stage"

    # -- aggregate stages ----------------------------------------------------

    def run_aggregate_stage(self, batch: PartitionBatch,
                            group_cols: Sequence[str],
                            aggs: Sequence[AggSpec]):
        """Segment + partial aggregate + bucketing, one stage program.
        Returns a BucketedBatch of finished shuffle pieces, or a plain
        batch when a fallback rung kept the host seam."""
        if not self._gate(batch.num_rows):
            return self.runner.run_aggregate(batch, group_cols, aggs)
        out, route = self.runner._aggregate_routed(
            batch, group_cols, aggs, fused=True,
            force_compiled=(self.mode == "force"))
        if route == "numpy":
            return out          # oracle fallback: scheduler applies the seam
        bucket_of = self.partitioner(out)
        return BucketedBatch(
            split_bucket_pieces(out, bucket_of, self.num_buckets))

    # -- sort / limit stages (single-reducer boundaries) ---------------------

    def run_sort_stage(self, batch: PartitionBatch,
                       keys: List[Tuple[str, bool]],
                       limit: Optional[int]):
        """Segment + per-partition top-k; the sorted prefix ships as one
        zero-copy piece (single reducer) — no host-assembly copy.

        Similarity-scored stages (self.topk set) route eligible partitions
        to the Pallas topk_similarity kernel: the tiled dot-product +
        running top-k selects the same rows, same order, as the lexsort
        oracle (ties broken by row index, both paths)."""
        if not self._gate(batch.num_rows):
            b = self.runner.run(batch)
            return b.take(self._sort_limit_indices(b, keys, limit))
        b, route = self.runner.run_routed(batch, fused=True)
        b = b.take(self._sort_limit_indices(b, keys, limit))
        if route == "numpy":
            return b
        return BucketedBatch([b])

    def _sort_limit_indices(self, b: PartitionBatch,
                            keys: List[Tuple[str, bool]],
                            limit: Optional[int]) -> np.ndarray:
        from .physical import _sort_indices
        if self.topk is not None and limit is not None and b.num_rows:
            idx = self._topk_kernel_indices(b, limit)
            if idx is not None:
                return idx
        idx = _sort_indices(b, keys)
        if limit is not None:
            idx = idx[:limit]
        return idx

    def _topk_kernel_indices(self, b: PartitionBatch,
                             k: int) -> Optional[np.ndarray]:
        """Row indices of the top-k similarity candidates via the Pallas
        kernel, or None when the PDE routes this partition elsewhere."""
        from ..kernels.ops import on_tpu
        d = decide_segment_backend(b.num_rows, "topk_similarity", None,
                                   on_tpu(), self.cfg)
        if d.route != "topk_similarity":
            return None
        lanes, weights = self.topk
        cols = [b.col(n) for n in lanes]
        if any(c.is_string for c in cols):
            return None
        from ..kernels import ops
        x = np.stack([np.asarray(c.arr) for c in cols], axis=1)
        _scores, idx = ops.topk_similarity(x, weights, k)
        self.runner._note_route("topk_similarity")
        return np.asarray(idx)

    def run_limit_stage(self, batch: PartitionBatch, n: int):
        """Segment + head(n), shipped as one zero-copy piece: surviving
        columns stay encoded end-to-end into the shuffle block — the
        pass-through seam fix (ISSUE 8 satellite)."""
        if not self._gate(batch.num_rows):
            return self.runner.run(batch).head(n)
        b, route = self.runner.run_routed(batch, fused=True)
        b = b.head(n)
        if route == "numpy":
            return b
        return BucketedBatch([b])
