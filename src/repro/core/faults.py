"""Unified fault-injection engine (DESIGN.md §16) — chaos as a subsystem.

The repo grew five independent failure surfaces (worker death, shuffle
loss, spill corruption, mesh device loss, fleet replica loss), each poked
by hand-rolled monkeypatching in its own chaos test.  This module makes
injection a first-class, *deterministic* engine:

  * `FaultSpec` — one arming rule for one site: kind, probability, fire
    count cap, and an after-N warmup (skip the first N passes);
  * `FaultSchedule` — a seeded set of specs whose probabilistic decisions
    derive from sha256 over `(seed, site, ordinal)`: the same seed against
    the same pass sequence trips identically, on any host;
  * `ChaosEngine` — per-site ordinal counters + the trip log.  Each
    instrumented seam calls `engine.fire(site)` once per pass; a non-None
    `FaultTrip` back means "inject here, this kind, now".  Installable on a
    `SharkContext`, `SharkSession`, `SharkServer`, or `SharkFleet` via
    `install()` (duck-typed walk of the layers each owns).

Fault sites (the seams today's chaos tests poked by hand):

    task.body       scheduler task body start  -> worker death
    shuffle.fetch   BlockManager.fetch_shuffle -> map-output loss
    spill.read      StorageManager fault_in / fault_shuffle -> lost/corrupt
    spill.write     StorageManager evict / spill_shuffle -> write lost
    mesh.dispatch   MeshContext.fire_dispatch  -> DeviceLost
    fleet.submit    SharkFleet._submit_on      -> replica death at submit
    fleet.poll      FleetHandle.result poll    -> replica death mid-query
    memory.enforce  MemoryManager.enforce      -> simulated memory pressure

Every trip is logged as `(site, ordinal, kind)`; `ExecMetrics.fault_trips`
carries the per-query delta.  `FaultSchedule.replay(trips)` rebuilds an
exact schedule from a trip log — rerun the same workload under the replay
schedule and the same passes trip the same faults, the exact-repro
debugging loop.

Injection is NEVER allowed to be a correctness event: each seam only fires
when the layer can recover (a kill keeps >=1 survivor; spill loss requires
lineage), so a chaos run must produce byte-identical results to the
fault-free run — which is precisely what tests/test_chaos_storm.py asserts.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple


class FaultTrip(NamedTuple):
    site: str
    ordinal: int
    kind: str


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One arming rule.  `p` is the per-pass fire probability (1.0 =
    always), `count` caps total fires (None = unlimited), `after` skips the
    first N passes of the site (warmup — e.g. 'kill a worker on the 3rd
    task, not the 1st')."""
    site: str
    kind: str = "fault"
    p: float = 1.0
    count: Optional[int] = None
    after: int = 0


class FaultSchedule:
    """Deterministic PRNG over (site, ordinal): seeded mode draws a uniform
    from sha256(f"{seed}:{site}:{ordinal}:{spec_idx}") per spec, so a
    schedule is a pure function of (seed, specs) — no RNG state, no
    host-order dependence.  Exact mode (`replay`) fires precisely the
    (site, ordinal) -> kind pairs of a previous run's trip log."""

    def __init__(self, seed: int = 0,
                 specs: Sequence[FaultSpec] = (),
                 exact: Optional[Dict[Tuple[str, int], str]] = None):
        self.seed = int(seed)
        self.specs = tuple(specs)
        self.exact = dict(exact) if exact is not None else None

    @classmethod
    def replay(cls, trips: Sequence[Tuple[str, int, str]]) -> "FaultSchedule":
        """Rebuild an exact schedule from a trip log (`ChaosEngine.trips`
        or `ExecMetrics.fault_trips`): the round-trip contract is that
        pumping the same pass sequence through an engine under the replayed
        schedule yields an identical trip log."""
        return cls(exact={(t[0], t[1]): t[2] for t in trips})

    def _uniform(self, site: str, ordinal: int, idx: int) -> float:
        h = hashlib.sha256(
            f"{self.seed}:{site}:{ordinal}:{idx}".encode()).digest()
        return int.from_bytes(h[:8], "big") / float(1 << 64)

    def fault_at(self, site: str, ordinal: int,
                 fired: Dict[int, int]) -> Optional[Tuple[Optional[int], str]]:
        """Decide one pass: returns (spec_index, kind) to fire, else None.
        `fired` maps spec index -> fires so far (the engine owns it; exact
        mode returns index None — replay needs no count bookkeeping)."""
        if self.exact is not None:
            kind = self.exact.get((site, ordinal))
            return (None, kind) if kind is not None else None
        for idx, spec in enumerate(self.specs):
            if spec.site != site or ordinal < spec.after:
                continue
            if spec.count is not None and fired.get(idx, 0) >= spec.count:
                continue
            if spec.p >= 1.0 or self._uniform(site, ordinal, idx) < spec.p:
                return idx, spec.kind
        return None


class ChaosEngine:
    """Per-site pass counters + trip log around one FaultSchedule.

    Thread-safe: seams fire from scheduler pool threads, reduce threads,
    and fleet pollers concurrently.  Ordinals count *passes* (every fire()
    call advances the site's ordinal whether or not a fault trips), so a
    spec's `after`/`p` are expressed in the site's own event time."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self.lock = threading.Lock()
        self._ordinals: Dict[str, int] = {}
        self._fired: Dict[int, int] = {}
        self.trips: List[FaultTrip] = []
        self._installed: List[object] = []

    # -- the seam API ---------------------------------------------------------

    def fire(self, site: str) -> Optional[FaultTrip]:
        """One pass of `site`: advance its ordinal, consult the schedule,
        log and return the trip when a fault arms (else None)."""
        with self.lock:
            ordinal = self._ordinals.get(site, 0)
            self._ordinals[site] = ordinal + 1
            hit = self.schedule.fault_at(site, ordinal, self._fired)
            if hit is None:
                return None
            idx, kind = hit
            if idx is not None:
                self._fired[idx] = self._fired.get(idx, 0) + 1
            trip = FaultTrip(site, ordinal, kind)
            self.trips.append(trip)
            return trip

    # -- observation ----------------------------------------------------------

    def trip_count(self) -> int:
        with self.lock:
            return len(self.trips)

    def trips_since(self, n: int) -> List[FaultTrip]:
        with self.lock:
            return list(self.trips[n:])

    def stats(self) -> Dict[str, object]:
        with self.lock:
            by_site: Dict[str, int] = {}
            for t in self.trips:
                by_site[t.site] = by_site.get(t.site, 0) + 1
            return {"trips": len(self.trips), "by_site": by_site,
                    "passes": dict(self._ordinals)}

    # -- installation ---------------------------------------------------------

    def install(self, target) -> "ChaosEngine":
        """Attach this engine to every seam `target` owns (duck-typed):

        * SharkFleet  -> the fleet itself (fleet.submit / fleet.poll) plus
                         every replica server;
        * SharkServer / SharkSession -> its SharkContext, MemoryManager,
                         StorageManager, and mesh (when present);
        * SharkContext -> the scheduler's task bodies and the BlockManager
                         (plus any storage already attached to it).

        Installing over a previous engine replaces it (the storm test
        installs a fresh engine per seed on one long-lived server)."""
        self._installed.append(target)
        if hasattr(target, "replicas") and hasattr(target, "kill_replica"):
            target.chaos = self
            for r in target.replicas:
                self.install(r.server)
            return self
        ctx = getattr(target, "ctx", None)
        if ctx is not None and ctx is not target:
            target.chaos = self
            for attr in ("memory", "storage"):
                obj = getattr(target, attr, None)
                if obj is not None:
                    obj.chaos = self
            mesh = None
            exec_kw = getattr(target, "_exec_kw", None)
            if exec_kw:
                mesh = exec_kw.get("mesh")
            if mesh is None:
                mesh = getattr(getattr(target, "executor", None), "mesh", None)
            if mesh is not None:
                mesh.chaos = self
            self.install(ctx)
            return self
        # SharkContext (or anything exposing a block_manager)
        target.chaos = self
        bm = getattr(target, "block_manager", None)
        if bm is not None:
            bm.chaos = self
            storage = getattr(bm, "shuffle_storage", None)
            if storage is not None:
                storage.chaos = self
        return self

    def uninstall(self) -> None:
        """Detach from everything `install` touched (reverse walk)."""
        for target in self._installed:
            for obj in _chaos_holders(target):
                if getattr(obj, "chaos", None) is self:
                    obj.chaos = None
        self._installed.clear()


def _chaos_holders(target) -> List[object]:
    out = [target]
    for attr in ("memory", "storage", "ctx", "block_manager"):
        obj = getattr(target, attr, None)
        if obj is not None and obj is not target:
            out.append(obj)
    bm = getattr(target, "block_manager", None)
    if bm is not None and getattr(bm, "shuffle_storage", None) is not None:
        out.append(bm.shuffle_storage)
    exec_kw = getattr(target, "_exec_kw", None)
    if exec_kw and exec_kw.get("mesh") is not None:
        out.append(exec_kw["mesh"])
    mesh = getattr(getattr(target, "executor", None), "mesh", None)
    if mesh is not None:
        out.append(mesh)
    return out
