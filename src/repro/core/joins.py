"""Join algorithms (paper §3.1.1, Figure 4).

Two communication patterns:
  * shuffle join — both inputs hash-partitioned by key; each reducer joins
    corresponding partitions with a *local* algorithm chosen from runtime
    statistics (build hash over the small side; symmetric if both large);
  * map (broadcast) join — the small input is broadcast to all nodes and
    joined against each partition of the large input, skipping the shuffle.

PDE selects between them at run time from observed input sizes (§3.1.1); the
co-partitioned case (§3.4) degenerates to a zip of corresponding partitions.

The local algorithm is sort/searchsorted-based (vectorized "hash join" —
numpy has no cheap per-row hash table; sorted probe is its vector analogue,
and on TPU the probe compiles to gathers).  `_match_pairs` is the
interpreted oracle; `CompiledProbe` lowers the same sort/searchsorted/expand
pipeline into two cached jitted XLA programs (DESIGN.md §11) with
power-of-two padding so re-traces stay bounded — the reduce-side router
(physical.ReduceRunner) picks between them per bucket group.

String join keys never materialize strings: both sides' dictionary codes are
remapped into the union of the two (small) dictionaries and the probe runs
on int codes — the join-side half of the dictionary-preserving exchange.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .batch import PartitionBatch, merge_string_dicts
from .expr import ColumnVal, next_pow2 as _next_pow2

Matcher = Callable[[np.ndarray, np.ndarray], Tuple[np.ndarray, np.ndarray]]


def _match_pairs(lkeys: np.ndarray, rkeys: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Equi-join row index pairs (vectorized, duplicate-correct).

    Sorts the build side once, probes with searchsorted, expands duplicate
    ranges with repeat arithmetic.  The semantic oracle for CompiledProbe:
    both must emit the same pairs in the same order."""
    order = np.argsort(rkeys, kind="stable")
    rs = rkeys[order]
    lo = np.searchsorted(rs, lkeys, side="left")
    hi = np.searchsorted(rs, lkeys, side="right")
    counts = hi - lo
    lidx = np.repeat(np.arange(len(lkeys)), counts)
    if len(lidx) == 0:
        return lidx, lidx.copy()
    # offsets within each left row's match range
    starts = np.repeat(lo, counts)
    cum = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(len(lidx)) - np.repeat(cum, counts)
    ridx = order[starts + within]
    return lidx, ridx


# ---------------------------------------------------------------------------
# Compiled probe: the sort/searchsorted join lowered through jax.jit.
#
# The match is data-dependent in its OUTPUT size only, so it splits into two
# statically-shaped programs: phase 1 (sort + bound search + per-row match
# counts) and phase 2 (pair expansion into a padded output).  Inputs and the
# pair count are padded to powers of two so each program re-traces O(log n)
# times per dtype, mirroring the _PLAN_CACHE discipline of expr.compile_expr.
# ---------------------------------------------------------------------------


class CompiledProbe:
    """`_match_pairs` compiled: same pairs, same order, via two cached
    jitted XLA programs.  Instances are cheap; the jitted functions are
    shared process-wide."""

    _fns: Dict[str, Tuple] = {}
    _lock = threading.Lock()

    @classmethod
    def _get_fns(cls) -> Tuple:
        with cls._lock:
            fns = cls._fns.get("fns")
            if fns is not None:
                return fns
            import functools

            import jax
            import jax.numpy as jnp

            @jax.jit
            def phase1(lk, rk, n_l, n_r):
                order = jnp.argsort(rk, stable=True)
                rs = rk[order]
                lo = jnp.searchsorted(rs, lk, side="left")
                # rk padding sorts after every real key (max-value sentinel,
                # appended, stable sort) — clamping `hi` to n_r excludes it
                # even when real keys equal the sentinel value
                hi = jnp.minimum(jnp.searchsorted(rs, lk, side="right"), n_r)
                valid = jnp.arange(lk.shape[0]) < n_l
                counts = jnp.where(valid, jnp.maximum(hi - lo, 0), 0)
                return order, lo, counts

            @functools.partial(jax.jit, static_argnames=("total_p",))
            def phase2(order, lo, counts, total_p):
                n = lo.shape[0]
                lidx = jnp.repeat(jnp.arange(n), counts,
                                  total_repeat_length=total_p)
                starts = jnp.repeat(lo, counts, total_repeat_length=total_p)
                cum = jnp.concatenate(
                    [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
                within = (jnp.arange(total_p)
                          - jnp.repeat(cum, counts,
                                       total_repeat_length=total_p))
                gather = jnp.clip(starts + within, 0, order.shape[0] - 1)
                return lidx, order[gather]

            fns = (phase1, phase2)
            cls._fns["fns"] = fns
            return fns

    def __call__(self, lkeys: np.ndarray, rkeys: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        n_l, n_r = len(lkeys), len(rkeys)
        if n_l == 0 or n_r == 0:
            empty = np.zeros(0, np.int64)
            return empty, empty.copy()
        from .expr import _x64
        phase1, phase2 = self._get_fns()
        dt = np.result_type(lkeys.dtype, rkeys.dtype)
        if dt.kind in ("U", "S", "O", "b"):
            # bool has no iinfo sentinel either — callers fall back to the
            # numpy oracle on TypeError
            raise TypeError("CompiledProbe takes numeric/code keys")
        if dt.kind == "f" and (np.isnan(lkeys).any() or np.isnan(rkeys).any()):
            # NaN sorts AFTER the +inf pad sentinel, breaking the invariant
            # that padding occupies the sorted tail (the hi-clamp would
            # admit pad rows) — same hazard code_space() guards against for
            # NaN dictionaries.  Callers fall back to the numpy oracle.
            raise TypeError("CompiledProbe cannot pad NaN float keys")
        sentinel = (np.array(np.inf, dt) if dt.kind == "f"
                    else np.array(np.iinfo(dt).max, dt))
        lp, rp = _next_pow2(n_l), _next_pow2(n_r)
        lk = np.full(lp, sentinel, dt)
        lk[:n_l] = lkeys
        rk = np.full(rp, sentinel, dt)
        rk[:n_r] = rkeys
        with _x64():
            order, lo, counts = phase1(lk, rk, n_l, n_r)
            counts = np.asarray(counts)
            total = int(counts.sum())
            if total == 0:
                empty = np.zeros(0, np.int64)
                return empty, empty.copy()
            lidx, ridx = phase2(order, lo, counts, _next_pow2(total))
        return (np.asarray(lidx[:total], dtype=np.int64),
                np.asarray(ridx[:total], dtype=np.int64))


_COMPILED_PROBE = CompiledProbe()


def compile_probe() -> CompiledProbe:
    """The process-wide compiled matcher (jitted programs are shared)."""
    return _COMPILED_PROBE


# ---------------------------------------------------------------------------
# Key extraction — decode-free for dictionary-coded strings
# ---------------------------------------------------------------------------


def _key_arrays(lbatch: PartitionBatch, rbatch: PartitionBatch,
                lkey: str, rkey: str) -> Tuple[np.ndarray, np.ndarray]:
    """Join keys comparable across the two sides.  String keys stay codes:
    both sides remap into the union of their (small) dictionaries, so no row
    ever materializes a string."""
    import time

    from .batch import EXCHANGE_TIMERS
    t0 = time.perf_counter()
    lv, rv = lbatch.col(lkey), rbatch.col(rkey)
    if lv.is_string and rv.is_string:
        _, (lmap, rmap) = merge_string_dicts([lv.sdict, rv.sdict])
        out = (lmap.astype(np.int64)[np.asarray(lv.arr)],
               rmap.astype(np.int64)[np.asarray(rv.arr)])
        EXCHANGE_TIMERS["hash"] += time.perf_counter() - t0
        return out
    lk = lv.decoded() if lv.is_string else np.asarray(lv.arr)
    rk = rv.decoded() if rv.is_string else np.asarray(rv.arr)
    EXCHANGE_TIMERS["hash"] += time.perf_counter() - t0
    return lk, rk


def _key_array(batch: PartitionBatch, key: str) -> np.ndarray:
    """Single-side key materialization (legacy helper, kept for callers
    outside the two-sided join path)."""
    v = batch.col(key)
    return v.decoded() if v.is_string else np.asarray(v.arr)


def _combine(lbatch: PartitionBatch, lidx: np.ndarray,
             rbatch: PartitionBatch, ridx: np.ndarray,
             rsuffix: str = "_r") -> PartitionBatch:
    out: Dict[str, ColumnVal] = {}
    for n, v in lbatch.cols.items():
        out[n] = ColumnVal(np.asarray(v.arr)[lidx], v.sdict, v.sorted_dict)
    for n, v in rbatch.cols.items():
        name = n if n not in out else n + rsuffix
        out[name] = ColumnVal(np.asarray(v.arr)[ridx], v.sdict, v.sorted_dict)
    return PartitionBatch(out)


def _null_pad_right(out: PartitionBatch, lbatch: PartitionBatch,
                    rbatch: PartitionBatch, n_match: int,
                    n_miss: int) -> PartitionBatch:
    """NULL emulation for the unmatched tail of a left join: right-side
    numeric columns zero, right-side STRING columns get the reserved null
    code — the empty string joins the (sorted) dictionary and miss rows
    remap to it, matching the zero-partition pad_right path.  Without this,
    string miss rows silently kept whatever row the pad gather hit."""
    if n_miss == 0:
        return out
    for n, v in rbatch.cols.items():
        name = n if n not in lbatch.cols else n + "_r"
        cv = out.cols[name]
        if cv.is_string:
            base = cv.sdict if cv.sdict.size else np.zeros(0, np.str_)
            nd = np.unique(np.concatenate(
                [base, np.array([""], dtype=base.dtype if base.size
                                else np.str_)]))
            remap = np.searchsorted(nd, base).astype(np.int32)
            null_code = np.int32(np.searchsorted(nd, ""))
            codes = np.empty(n_match + n_miss, np.int32)
            codes[:n_match] = remap[np.asarray(cv.arr)[:n_match]]
            codes[n_match:] = null_code
            out.cols[name] = ColumnVal(codes, nd, True)
            continue
        arr = np.asarray(cv.arr).copy()
        if np.issubdtype(arr.dtype, np.number):
            arr[n_match:] = 0
        elif arr.dtype.kind in ("U", "S"):
            arr[n_match:] = ""   # raw strings (legacy decoded exchange)
        out.cols[name] = ColumnVal(arr, cv.sdict, cv.sorted_dict)
    return out


def join_local(lbatch: PartitionBatch, rbatch: PartitionBatch,
               lkey: str, rkey: str, how: str = "inner",
               matcher: Optional[Matcher] = None) -> PartitionBatch:
    """Local join of two co-located partitions.

    Mirrors the paper's reducer policy: probe from the larger side into the
    sorted smaller side (building over the small input); the symmetric case
    falls out naturally since sorted probe is order-symmetric.  `matcher`
    selects the pair-matching implementation (`_match_pairs` oracle by
    default, `CompiledProbe` when the reduce router picks the jit route)."""
    match = matcher if matcher is not None else _match_pairs
    lk, rk = _key_arrays(lbatch, rbatch, lkey, rkey)
    if how == "inner":
        if len(rk) <= len(lk):
            lidx, ridx = match(lk, rk)
        else:
            ridx, lidx = match(rk, lk)
        return _combine(lbatch, lidx, rbatch, ridx)
    if how == "left":
        lidx, ridx = match(lk, rk)
        matched = np.zeros(len(lk), bool)
        matched[lidx] = True
        miss = np.flatnonzero(~matched)
        if len(rk) == 0:
            # no right rows at all: emit left rows + null-padded right cols
            out = _combine(lbatch, miss,
                           PartitionBatch.empty_like(rbatch),
                           np.zeros(0, np.int64))
            for n, v in rbatch.cols.items():
                name = n if n not in lbatch.cols else n + "_r"
                cv = out.cols[name]
                if cv.is_string:
                    out.cols[name] = ColumnVal(
                        np.zeros(len(miss), np.int32),
                        np.array([""], np.str_), True)
                else:
                    out.cols[name] = ColumnVal(
                        np.zeros(len(miss), np.asarray(v.arr).dtype))
            return out
        all_l = np.concatenate([lidx, miss])
        # right side for misses: gather row 0, then rewrite to NULL
        # emulation (zeros / reserved null code) below
        pad = np.zeros(len(miss), np.int64)
        all_r = np.concatenate([ridx, pad])
        out = _combine(lbatch, all_l, rbatch, all_r)
        return _null_pad_right(out, lbatch, rbatch, len(lidx), len(miss))
    raise NotImplementedError(how)


def broadcast_join(part: PartitionBatch, small: PartitionBatch,
                   part_key: str, small_key: str,
                   how: str = "inner",
                   matcher: Optional[Matcher] = None) -> PartitionBatch:
    """Map join: `small` is the broadcast table (already collected to the
    master and shipped to every task)."""
    return join_local(part, small, part_key, small_key, how, matcher=matcher)
