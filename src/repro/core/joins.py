"""Join algorithms (paper §3.1.1, Figure 4).

Two communication patterns:
  * shuffle join — both inputs hash-partitioned by key; each reducer joins
    corresponding partitions with a *local* algorithm chosen from runtime
    statistics (build hash over the small side; symmetric if both large);
  * map (broadcast) join — the small input is broadcast to all nodes and
    joined against each partition of the large input, skipping the shuffle.

PDE selects between them at run time from observed input sizes (§3.1.1); the
co-partitioned case (§3.4) degenerates to a zip of corresponding partitions.

The local algorithm is sort/searchsorted-based (vectorized "hash join" —
numpy has no cheap per-row hash table; sorted probe is its vector analogue,
and on TPU the probe compiles to gathers).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .batch import PartitionBatch
from .expr import ColumnVal


def _match_pairs(lkeys: np.ndarray, rkeys: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Equi-join row index pairs (vectorized, duplicate-correct).

    Sorts the build side once, probes with searchsorted, expands duplicate
    ranges with repeat arithmetic."""
    order = np.argsort(rkeys, kind="stable")
    rs = rkeys[order]
    lo = np.searchsorted(rs, lkeys, side="left")
    hi = np.searchsorted(rs, lkeys, side="right")
    counts = hi - lo
    lidx = np.repeat(np.arange(len(lkeys)), counts)
    if len(lidx) == 0:
        return lidx, lidx.copy()
    # offsets within each left row's match range
    starts = np.repeat(lo, counts)
    cum = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(len(lidx)) - np.repeat(cum, counts)
    ridx = order[starts + within]
    return lidx, ridx


def _key_array(batch: PartitionBatch, key: str) -> np.ndarray:
    """Join keys must compare across partitions: decode strings."""
    v = batch.col(key)
    return v.decoded() if v.is_string else np.asarray(v.arr)


def _combine(lbatch: PartitionBatch, lidx: np.ndarray,
             rbatch: PartitionBatch, ridx: np.ndarray,
             rsuffix: str = "_r") -> PartitionBatch:
    out: Dict[str, ColumnVal] = {}
    for n, v in lbatch.cols.items():
        out[n] = ColumnVal(np.asarray(v.arr)[lidx], v.sdict, v.sorted_dict)
    for n, v in rbatch.cols.items():
        name = n if n not in out else n + rsuffix
        out[name] = ColumnVal(np.asarray(v.arr)[ridx], v.sdict, v.sorted_dict)
    return PartitionBatch(out)


def join_local(lbatch: PartitionBatch, rbatch: PartitionBatch,
               lkey: str, rkey: str, how: str = "inner") -> PartitionBatch:
    """Local join of two co-located partitions.

    Mirrors the paper's reducer policy: probe from the larger side into the
    sorted smaller side (building over the small input); the symmetric case
    falls out naturally since sorted probe is order-symmetric."""
    lk, rk = _key_array(lbatch, lkey), _key_array(rbatch, rkey)
    if how == "inner":
        if len(rk) <= len(lk):
            lidx, ridx = _match_pairs(lk, rk)
        else:
            ridx, lidx = _match_pairs(rk, lk)
        return _combine(lbatch, lidx, rbatch, ridx)
    if how == "left":
        lidx, ridx = _match_pairs(lk, rk)
        matched = np.zeros(len(lk), bool)
        matched[lidx] = True
        miss = np.flatnonzero(~matched)
        all_l = np.concatenate([lidx, miss])
        # right side for misses: gather row 0 then mask to null-ish zeros
        pad = np.zeros(len(miss), np.int64)
        all_r = np.concatenate([ridx, pad])
        out = _combine(lbatch, all_l, rbatch, all_r)
        # NULL emulation: zero out right columns for miss rows
        for n, v in rbatch.cols.items():
            name = n if n not in lbatch.cols else n + "_r"
            arr = np.asarray(out.cols[name].arr).copy()
            if len(miss) and np.issubdtype(arr.dtype, np.number):
                arr[len(lidx):] = 0
            out.cols[name] = ColumnVal(arr, out.cols[name].sdict,
                                       out.cols[name].sorted_dict)
        return out
    raise NotImplementedError(how)


def broadcast_join(part: PartitionBatch, small: PartitionBatch,
                   part_key: str, small_key: str,
                   how: str = "inner") -> PartitionBatch:
    """Map join: `small` is the broadcast table (already collected to the
    master and shipped to every task)."""
    return join_local(part, small, part_key, small_key, how)
