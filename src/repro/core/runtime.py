"""Fault-tolerant task scheduler (paper §2.3, §2.4, §3.1, §5, §7).

This is the "cluster" layer: logical workers with block stores, per-partition
tasks, memory-based shuffle, lineage recovery, speculative execution, and the
stage-by-stage execution hooks that Partial DAG Execution needs.

Fault-tolerance guarantees reproduced (paper §2.3):
  1. loss of any set of workers is tolerated — lost tasks re-execute and lost
     RDD partitions / shuffle outputs recompute from lineage, mid-query;
  2. recovery is parallelized across surviving workers;
  3. deterministic tasks allow speculative backup copies for stragglers;
  4. the same machinery covers SQL and ML stages (they share one lineage
     graph).

The scheduler executes *stages* delimited by shuffle boundaries.  Map stages
materialize their output in worker memory (memory-based shuffle, §5) while
collecting PDE statistics; the master aggregates those and may re-plan before
launching the next stage (§3.1) — the caller drives this via
`run_map_stage` / `run_result_stage`.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .batch import PartitionBatch
from .rdd import (RDD, ShuffleDependency, ShuffledRDD, TaskContext)
from .resilience import (ResiliencePolicy, ShuffleWaitTimeout, WorkerHealth,
                         describe_counters)
from .stats import Accumulator, StageStats, TaskStats

_stage_counter = itertools.count()


class FetchFailed(Exception):
    """A reduce task could not fetch some map outputs (worker lost them)."""

    def __init__(self, shuffle_id: int, missing_maps: List[int]):
        super().__init__(f"shuffle {shuffle_id} missing maps {missing_maps}")
        self.shuffle_id = shuffle_id
        self.missing_maps = missing_maps


class WorkerLost(Exception):
    pass


class BlockManager:
    """Cluster-wide registry of materialized blocks and which worker holds
    them.  Killing a worker drops every block it holds — cached partitions
    AND shuffle map outputs — exactly the failure surface of the paper.

    Byte accounting is unified: every block's size is tracked on insert so a
    `MemoryManager` (src/repro/server/memory.py) can enforce a cache budget
    with partition-granular LRU eviction.  Cached-partition reads record
    hit/miss so the recompute-from-lineage fallback (paper §3.2) is
    observable; `memory_manager`, when attached, is notified on every put
    (budget enforcement) and miss (recompute detection)."""

    def __init__(self):
        self.lock = threading.RLock()
        # failure-handling knobs (set by SharkContext; None = defaults)
        self.policy: Optional[ResiliencePolicy] = None
        # fault-injection engine (faults.ChaosEngine), when installed
        self.chaos = None
        # pipelined reduces block on this until their input pieces land
        # (put_shuffle notifies; DESIGN.md §14)
        self.shuffle_cond = threading.Condition(self.lock)
        # ("part", rdd_id, split) -> (worker, batch)
        # ("shuf", shuffle_id, map_split, bucket) -> (worker, batch)
        self.blocks: Dict[Tuple, Tuple[int, PartitionBatch]] = {}
        self.by_worker: Dict[int, Set[Tuple]] = {}
        self.sizes: Dict[Tuple, int] = {}
        self.total_bytes = 0
        self.part_bytes = 0  # cached-partition subset of total_bytes
        # LRU order over cached-partition keys only (shuffle blocks are
        # lifecycle-managed per query, not by recency)
        self.part_lru: "Dict[Tuple, None]" = {}
        self.part_hits = 0
        self.part_misses = 0
        # shuffles already released by drop_shuffle: straggler/speculative
        # task attempts finishing late must not resurrect their blocks
        self.released_shuffles: Set[int] = set()
        self.memory_manager = None  # attached by server.MemoryManager
        # shuffle blocks moved to the storage tier under memory pressure:
        # key -> SpillRef.  A spilled block leaves worker memory (and its
        # worker's block set — the segment is server-local disk, so worker
        # loss does not take it down); fetch_shuffle faults it back in, and
        # a lost/corrupt segment degrades to FetchFailed -> lineage
        # recompute, never a wrong answer.
        self.spilled_shuffle: Dict[Tuple, Any] = {}
        self.shuffle_storage = None  # attached by MemoryManager.attach_storage
        self.shuffle_spill_faults = 0
        self.shuffle_spill_lost = 0

    def _put_locked(self, key: Tuple, worker: int,
                    batch: PartitionBatch) -> None:
        # caller holds self.lock; must NOT call the memory manager (it takes
        # its own lock and calls back into us — see _put for the ordering)
        prev = self.sizes.get(key)
        if prev is not None:
            self.total_bytes -= prev
        nbytes = int(batch.nbytes)
        self.blocks[key] = (worker, batch)
        self.by_worker.setdefault(worker, set()).add(key)
        self.sizes[key] = nbytes
        self.total_bytes += nbytes
        if key[0] == "part":
            if prev is not None:
                self.part_bytes -= prev
            self.part_bytes += nbytes
            self.part_lru.pop(key, None)
            self.part_lru[key] = None  # most-recently-used at the end

    def _put(self, key: Tuple, worker: int, batch: PartitionBatch) -> None:
        with self.lock:
            self._put_locked(key, worker, batch)
            mm = self.memory_manager
        if mm is not None:
            mm.on_put(key)

    def put_partition(self, rdd_id: int, split: int, batch: PartitionBatch,
                      worker: int) -> None:
        self._put(("part", rdd_id, split), worker, batch)

    def get_partition(self, rdd_id: int, split: int) -> Optional[PartitionBatch]:
        key = ("part", rdd_id, split)
        mm = None
        with self.lock:
            hit = self.blocks.get(key)
            if hit is not None:
                self.part_hits += 1
                self.part_lru.pop(key, None)
                self.part_lru[key] = None
                return hit[1]
            self.part_misses += 1
            mm = self.memory_manager
        if mm is not None:
            mm.on_miss(key)
        return None

    def drop_block(self, key: Tuple) -> int:
        """Evict one block; returns bytes freed (0 if absent)."""
        with self.lock:
            hit = self.blocks.pop(key, None)
            if hit is None:
                return 0
            worker = hit[0]
            self.by_worker.get(worker, set()).discard(key)
            self.part_lru.pop(key, None)
            nbytes = self.sizes.pop(key, 0)
            self.total_bytes -= nbytes
            if key[0] == "part":
                self.part_bytes -= nbytes
            return nbytes

    def drop_shuffle(self, shuffle_id: int) -> int:
        """Release all map output of a finished shuffle — in-memory blocks
        AND spilled segments; returns bytes freed.  The release is sticky:
        later writes for this shuffle (straggler / speculative attempts
        outliving their query) are dropped on arrival."""
        with self.lock:
            self.released_shuffles.add(shuffle_id)
            keys = [k for k in self.blocks
                    if k[0] == "shuf" and k[1] == shuffle_id]
            spilled = [k for k in self.spilled_shuffle if k[1] == shuffle_id]
            storage = self.shuffle_storage
            for k in spilled:
                ref = self.spilled_shuffle.pop(k)
                if storage is not None:
                    storage.forget_shuffle(ref)
        return sum(self.drop_block(k) for k in keys)

    def lru_partition_keys(self) -> List[Tuple]:
        """Cached-partition keys, least-recently-used first."""
        with self.lock:
            return list(self.part_lru)

    def put_shuffle(self, shuffle_id: int, map_split: int, bucket: int,
                    batch: PartitionBatch, worker: int) -> None:
        with self.lock:
            if shuffle_id in self.released_shuffles:
                return  # late straggler write for a finished query
            # the released-check and the insert must be one atomic step: a
            # drop_shuffle between them would let this block leak forever
            self._put_locked(("shuf", shuffle_id, map_split, bucket),
                             worker, batch)
            self.shuffle_cond.notify_all()
            mm = self.memory_manager
        if mm is not None:
            mm.on_put(("shuf", shuffle_id, map_split, bucket))

    def wait_shuffle(self, shuffle_id: int, maps: Sequence[int],
                     buckets: Sequence[int], timeout: Optional[float] = None,
                     cancel: Optional[threading.Event] = None) -> bool:
        """Block until every (map, bucket) piece in `maps`×`buckets` is
        present (in memory or spilled); True on success, False on cancel.
        The timeout defaults to the ResiliencePolicy's
        `shuffle_wait_timeout_s` and expiry raises a typed
        `ShuffleWaitTimeout` carrying the shuffle id and the map splits
        still missing (the seed returned a bare False, indistinguishable
        from cancellation and naming nothing).  Availability is checked
        BEFORE cancellation so a waiter racing the map stage's completion
        signal still wins when its pieces already landed."""
        if timeout is None:
            pol = self.policy
            timeout = (pol.shuffle_wait_timeout_s if pol is not None
                       else ResiliencePolicy.shuffle_wait_timeout_s)
        deadline = time.monotonic() + timeout

        def _have(m: int, b: int) -> bool:
            return (("shuf", shuffle_id, m, b) in self.blocks
                    or ("shuf", shuffle_id, m, b) in self.spilled_shuffle)

        with self.lock:
            while True:
                if all(_have(m, b) for m in maps for b in buckets):
                    return True
                if cancel is not None and cancel.is_set():
                    return False
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    missing = sorted({m for m in maps
                                      if any(not _have(m, b)
                                             for b in buckets)})
                    raise ShuffleWaitTimeout(shuffle_id, missing, timeout)
                self.shuffle_cond.wait(min(remaining, 0.05))

    def has_map_output(self, shuffle_id: int, map_split: int) -> bool:
        with self.lock:
            return any(k[0] == "shuf" and k[1] == shuffle_id
                       and k[2] == map_split
                       for k in (*self.blocks, *self.spilled_shuffle))

    def spill_shuffle_block(self, key: Tuple) -> int:
        """Move one shuffle block from worker memory to the storage tier;
        returns resident bytes freed (0 when no storage is attached, the
        block is gone, or it is already spilled).  Called by the
        MemoryManager's working-set rung — shuffle output obeys the budget
        like everything else once a spill tier exists."""
        with self.lock:
            storage = self.shuffle_storage
            if storage is None:
                return 0
            hit = self.blocks.get(key)
            if hit is None:
                return 0
            if key in self.spilled_shuffle:
                # a deterministic recompute re-created a block whose segment
                # is still live: the bytes on disk are identical, just
                # release the memory copy
                return self.drop_block(key)
            ref = storage.spill_shuffle(key, hit[1])
            if ref is None:
                return 0
            self.spilled_shuffle[key] = ref
            return self.drop_block(key)

    def shuffle_spill_candidates(self) -> List[Tuple]:
        """Resident (non-spilled) shuffle block keys, largest first — the
        eviction order for the working-set rung."""
        with self.lock:
            keys = [k for k in self.blocks if k[0] == "shuf"]
            return sorted(keys, key=lambda k: -self.sizes.get(k, 0))

    def fetch_shuffle(self, shuffle_id: int, num_maps: int,
                      buckets: Sequence[int],
                      maps: Optional[Sequence[int]] = None
                      ) -> List[PartitionBatch]:
        """All pieces of `buckets` from every map task (or the subset in
        `maps` — used by skew-split reducers, each of which owns a disjoint
        stripe of map outputs); FetchFailed lists the missing map splits so
        the scheduler can recompute exactly those.

        Pieces are zero-copy views of the stored blocks, returned in
        deterministic (map, bucket) order: the reduce task sizes its output
        once from the piece offsets and assembles each column with a single
        preallocated concat (`PartitionBatch.concat`).  The block format is
        dictionary-preserving (DESIGN.md §11): a string column travels as
        (int32 codes, partition-local dictionary) — the dictionary rides in
        the block as the column's header — and the reduce side unifies
        dictionaries with a vectorized merge-remap instead of decoding.
        Recomputed-from-lineage blocks carry byte-identical dictionaries
        because map tasks are deterministic."""
        chaos = self.chaos
        if chaos is not None:
            trip = chaos.fire("shuffle.fetch")
            if trip is not None:
                # lose one present map split's blocks for this shuffle: the
                # scan below reports it missing -> FetchFailed -> the
                # scheduler recomputes exactly that map task from lineage
                with self.lock:
                    present = sorted({k[2] for k in self.blocks
                                      if k[0] == "shuf"
                                      and k[1] == shuffle_id})
                if present:
                    victim = present[trip.ordinal % len(present)]
                    with self.lock:
                        doomed = [k for k in self.blocks
                                  if k[0] == "shuf" and k[1] == shuffle_id
                                  and k[2] == victim]
                    for k in doomed:
                        self.drop_block(k)
        pieces, missing = [], set()
        with self.lock:
            for m in (range(num_maps) if maps is None else maps):
                for b in buckets:
                    key = ("shuf", shuffle_id, m, b)
                    hit = self.blocks.get(key)
                    if hit is not None:
                        pieces.append(hit[1])
                        continue
                    ref = self.spilled_shuffle.get(key)
                    if ref is not None and self.shuffle_storage is not None:
                        # spilled to the storage tier: fault the segment
                        # back in (checksum-verified).  A lost or corrupt
                        # segment degrades to a missing map output and the
                        # scheduler recomputes it from lineage.
                        batch = self.shuffle_storage.fault_shuffle(ref)
                        if batch is not None:
                            self.shuffle_spill_faults += 1
                            pieces.append(batch)
                            continue
                        self.shuffle_spill_lost += 1
                        self.spilled_shuffle.pop(key, None)
                    missing.add(m)
        if missing:
            raise FetchFailed(shuffle_id, sorted(missing))
        return pieces

    def drop_worker(self, worker: int) -> int:
        with self.lock:
            keys = self.by_worker.pop(worker, set())
            for k in keys:
                self.blocks.pop(k, None)
                self.part_lru.pop(k, None)
                nbytes = self.sizes.pop(k, 0)
                self.total_bytes -= nbytes
                if k[0] == "part":
                    self.part_bytes -= nbytes
            return len(keys)

    def nbytes(self) -> int:
        with self.lock:
            return self.total_bytes


@dataclasses.dataclass
class TaskRecord:
    split: int
    attempt: int
    worker: int
    started: float
    future: Optional[Future] = None
    speculative: bool = False


class Scheduler:
    """Master: assigns tasks to alive workers, retries on failure, launches
    speculative backups, and rebuilds lost shuffle output from lineage."""

    def __init__(self, ctx: "SharkContext", num_workers: int = 8,
                 max_threads: int = 8, speculation: bool = True,
                 speculation_multiplier: float = 4.0,
                 speculation_quantile: float = 0.5,
                 max_stage_retries: int = 6,
                 task_launch_overhead_s: float = 0.0,
                 policy: Optional[ResiliencePolicy] = None):
        self.ctx = ctx
        self.num_workers = num_workers
        self.alive: Set[int] = set(range(num_workers))
        self.max_threads = max_threads
        self.pool = ThreadPoolExecutor(max_workers=max_threads)
        self.speculation = speculation
        self.speculation_multiplier = speculation_multiplier
        self.speculation_quantile = speculation_quantile
        if policy is None:
            policy = ResiliencePolicy(max_stage_retries=max_stage_retries)
        self.policy = policy
        # kept as a plain attribute: external layers (ml.trainer, the
        # broadcast fetch in physical.py) read it directly
        self.max_stage_retries = policy.max_stage_retries
        self.task_launch_overhead_s = task_launch_overhead_s
        self.health = WorkerHealth(policy)
        self.lock = threading.RLock()
        self._rr = itertools.count()
        # metrics
        self.tasks_launched = 0
        self.tasks_speculated = 0
        self.tasks_recomputed = 0
        # resilience event counters (policy decisions, DESIGN.md §16)
        self.resilience_counters: Dict[str, int] = {
            "retries": 0, "backoffs": 0, "app_probes": 0,
            "fast_fails": 0, "reaps": 0}
        self.stage_stats: Dict[int, StageStats] = {}
        # pipelined-scheduling event log (DESIGN.md §14): monotonically
        # sequenced (seq, kind, shuffle_id, detail) tuples — the test
        # probe that reduce tasks observably start before the map stage
        # drains.  Bounded: trimmed from the front when it grows large.
        self.stage_events: List[Tuple[int, str, int, Any]] = []
        self._event_seq = itertools.count()

    def _log_event(self, kind: str, shuffle_id: int, detail: Any = None
                   ) -> None:
        with self.lock:
            self.stage_events.append(
                (next(self._event_seq), kind, shuffle_id, detail))
            if len(self.stage_events) > 4096:
                del self.stage_events[:2048]

    # -- cluster membership --------------------------------------------------

    def kill_worker(self, worker: int) -> int:
        """Simulate a node failure: the worker leaves and all its blocks
        (cached partitions + shuffle outputs) vanish."""
        with self.lock:
            self.alive.discard(worker)
        self.health.forget(worker)
        return self.ctx.block_manager.drop_worker(worker)

    def add_worker(self) -> int:
        """Elasticity (§7.2): a new worker joins and immediately receives
        pending work."""
        with self.lock:
            w = self.num_workers
            self.num_workers += 1
            self.alive.add(w)
            return w

    def _pick_worker(self, exclude: Optional[Set[int]] = None) -> int:
        quarantined = self.health.excluded()
        with self.lock:
            avoid = [w for w in sorted(self.alive)
                     if not exclude or w not in exclude]
            # quarantined workers are skipped until their probation probe is
            # due; an empty healthy pool falls back to the full one (a task
            # on a flaky worker beats no task at all)
            pool = [w for w in avoid if w not in quarantined] or avoid
            if not pool:
                pool = sorted(self.alive)
            if not pool:
                raise RuntimeError("no alive workers")
            return pool[next(self._rr) % len(pool)]

    # -- generic stage runner with retry + speculation ------------------------

    def _run_tasks(self, stage_id: int, splits: Sequence[int],
                   run_one: Callable[[int, TaskContext], Any]) -> Dict[int, Any]:
        """Run one task per split under the ResiliencePolicy; returns
        split -> result.  `run_one` must be deterministic and idempotent.

        Failure handling (DESIGN.md §16):
          * retryable infrastructure faults (policy.is_retryable) retry on
            another worker with deterministic exponential backoff, up to
            `max_task_attempts`; each failure scores against the worker's
            health and may quarantine it from `_pick_worker`;
          * deterministic application errors fail FAST: after at most
            `app_error_probes` cross-worker probes the ORIGINAL exception
            is re-raised (the seed retried any exception to the attempt
            cap, surfacing app bugs late with mangled context);
          * with `task_deadline_s` set, a task running past the deadline is
            reaped: its future is abandoned (a late result is never
            observed; late shuffle writes hit the exactly-once released-
            shuffle guard) and the split relaunches elsewhere — even when
            ZERO tasks have completed, the case duration-based speculation
            structurally cannot cover (the seed deadlocked forever here).
        """
        policy = self.policy
        results: Dict[int, Any] = {}
        pending: Set[int] = set(splits)
        durations: List[float] = []
        attempt_counter: Dict[int, int] = {s: 0 for s in splits}
        infra_failures: Dict[int, int] = {s: 0 for s in splits}
        app_probes: Dict[int, int] = {s: 0 for s in splits}
        first_app_error: Dict[int, BaseException] = {}
        # (due_time, split, exclude): backoff-delayed resubmits
        delayed: List[Tuple[float, int, Set[int]]] = []

        def submit(split: int, exclude: Optional[Set[int]] = None,
                   speculative: bool = False) -> TaskRecord:
            worker = self._pick_worker(exclude)
            tc = TaskContext(worker, stage_id, split,
                             attempt_counter[split])
            attempt_counter[split] += 1
            rec = TaskRecord(split, tc.attempt, worker, time.monotonic(),
                             speculative=speculative)

            def body():
                if self.task_launch_overhead_s:
                    time.sleep(self.task_launch_overhead_s)
                with self.lock:
                    if worker not in self.alive:
                        raise WorkerLost(f"worker {worker} is dead")
                chaos = getattr(self.ctx, "chaos", None)
                if chaos is not None:
                    trip = chaos.fire("task.body")
                    if trip is not None:
                        # chaos worker death: the node vanishes (all its
                        # blocks drop) and a fresh one joins — the exact
                        # surface the hand-rolled chaos tests poked
                        self.kill_worker(worker)
                        self.add_worker()
                        raise WorkerLost(
                            f"worker {worker} killed by chaos "
                            f"({trip.site}#{trip.ordinal})")
                out = run_one(split, tc)
                with self.lock:
                    if worker not in self.alive:
                        # results computed on a dead worker are discarded
                        raise WorkerLost(f"worker {worker} died mid-task")
                return out

            with self.lock:
                self.tasks_launched += 1
                if speculative:
                    self.tasks_speculated += 1
            rec.future = self.pool.submit(body)
            return rec

        def resubmit(split: int, exclude: Set[int]) -> None:
            """Retry with the policy's deterministic backoff schedule."""
            delay = policy.backoff(infra_failures[split])
            if delay > 0.0:
                with self.lock:
                    self.resilience_counters["backoffs"] += 1
                delayed.append((time.monotonic() + delay, split,
                                set(exclude)))
            else:
                running[split].append(submit(split, exclude=exclude))

        running: Dict[int, List[TaskRecord]] = {}
        for s in splits:
            running[s] = [submit(s)]

        while pending:
            now = time.monotonic()
            if delayed:
                due = [d for d in delayed if d[0] <= now]
                if due:
                    delayed[:] = [d for d in delayed if d[0] > now]
                    for _, split, exclude in due:
                        if split in pending:
                            running[split].append(
                                submit(split, exclude=exclude))
            all_futs = {rec.future: (s, rec)
                        for s, recs in running.items() for rec in recs
                        if rec.future is not None and s in pending}
            if not all_futs:
                if delayed:
                    # every in-flight attempt is backing off; sleep to the
                    # nearest due time instead of spinning
                    nearest = min(d[0] for d in delayed)
                    time.sleep(min(0.05, max(0.0, nearest - now)))
                    continue
                raise RuntimeError("scheduler deadlock: no running tasks")
            done, _ = wait(list(all_futs), timeout=0.05,
                           return_when=FIRST_COMPLETED)
            now = time.monotonic()
            for fut in done:
                split, rec = all_futs[fut]
                if split not in pending:
                    continue
                try:
                    res = fut.result()
                except FetchFailed:
                    raise  # stage-level recovery (lineage) handled above us
                except Exception as exc:
                    # Clear the handled future FIRST — it would otherwise be
                    # re-observed as "done" on every poll iteration while the
                    # retry waits for a pool thread, spawning a retry per
                    # poll until the attempt cap kills the whole stage.
                    rec.future = None
                    self.health.record_failure(rec.worker)
                    if policy.is_retryable(exc):
                        infra_failures[split] += 1
                        with self.lock:
                            self.resilience_counters["retries"] += 1
                        if attempt_counter[split] > policy.max_task_attempts:
                            raise
                        resubmit(split, {rec.worker})
                    elif app_probes[split] < policy.app_error_probes:
                        # deterministic app error?  one cross-worker probe
                        # tells a poison partition from a poison worker
                        first_app_error.setdefault(split, exc)
                        app_probes[split] += 1
                        with self.lock:
                            self.resilience_counters["app_probes"] += 1
                        running[split].append(
                            submit(split, exclude={rec.worker}))
                    else:
                        with self.lock:
                            self.resilience_counters["fast_fails"] += 1
                        raise first_app_error.get(split, exc)
                    continue
                self.health.record_success(rec.worker)
                results[split] = res
                pending.discard(split)
                durations.append(now - rec.started)
            # hung-task reaper: abandon any attempt past the deadline and
            # relaunch the split elsewhere (policy.task_deadline_s)
            if policy.task_deadline_s is not None and pending:
                for split in list(pending):
                    for rec in list(running[split]):
                        if (rec.future is None
                                or now - rec.started
                                <= policy.task_deadline_s):
                            continue
                        rec.future = None       # late result never observed
                        self.health.record_failure(rec.worker)
                        infra_failures[split] += 1
                        with self.lock:
                            self.resilience_counters["reaps"] += 1
                        if attempt_counter[split] > policy.max_task_attempts:
                            raise RuntimeError(
                                f"task {split} exceeded its "
                                f"{policy.task_deadline_s}s deadline "
                                f"{attempt_counter[split]} times")
                        resubmit(split, {rec.worker})
            # speculation: if a task runs far beyond the median of completed
            # tasks, launch a backup copy on another worker (§2.3 item 3)
            if self.speculation and durations and pending:
                frac_done = len(durations) / max(len(splits), 1)
                if frac_done >= self.speculation_quantile:
                    med = float(np.median(durations))
                    threshold = max(self.speculation_multiplier * med, 0.05)
                    for split in list(pending):
                        recs = running[split]
                        if any(r.speculative for r in recs):
                            continue
                        oldest = min(r.started for r in recs)
                        if now - oldest > threshold:
                            workers = {r.worker for r in recs}
                            running[split].append(
                                submit(split, exclude=workers,
                                       speculative=True))
        return results

    # -- map stages (shuffle writes + PDE statistics) -------------------------

    def run_map_stage(self, dep: ShuffleDependency) -> StageStats:
        """Materialize the map side of a shuffle in worker memory, gathering
        PDE statistics while doing so.  Returns the aggregated stats the
        optimizer uses to re-plan the downstream DAG (§3.1).

        Recovers from lost UPSTREAM shuffle output mid-stage: when the map
        tasks themselves read a parent shuffle (e.g. the sort boundary above
        an aggregation) and a worker died since that shuffle materialized,
        the missing parent map outputs recompute from lineage and the stage
        retries — the same policy run_result_stage applies (§2.3)."""
        for retry in range(self.max_stage_retries):
            try:
                return self._run_map_stage_attempt(dep)
            except FetchFailed as ff:
                self._recover_lineage(dep.parent, ff)
        raise RuntimeError("exceeded max stage retries (map stage)")

    def _recover_lineage(self, rdd: "RDD", ff: FetchFailed) -> None:
        """Recompute the map outputs `ff` reported missing; when the
        recovery tasks themselves hit a lost shuffle further up the chain,
        recover that one first, then CLIMB BACK DOWN and finish the
        original recovery — a stack of pending levels, so one call repairs
        a whole multi-level chain instead of burning one outer stage retry
        per level.  Bounded walk: the lineage DAG is finite; the budget
        covers a chain of max_stage_retries levels each re-lost a few
        times."""
        pending = [ff]
        for _ in range(self.max_stage_retries * 4):
            cur = pending[-1]
            dep = _find_shuffle_dep(rdd, cur.shuffle_id)
            if dep is None:
                raise cur
            try:
                self._recover_map_outputs(dep, cur.missing_maps)
            except FetchFailed as deeper:
                pending.append(deeper)
                continue
            pending.pop()
            if not pending:
                return
        raise ff

    def _map_output_pieces(self, dep: ShuffleDependency,
                           batch) -> List[PartitionBatch]:
        """Per-bucket pieces of one map task's output.  A fused stage
        program (DESIGN.md §14) hands back a BucketedBatch — already
        partitioned and combined inside the task's single traced program —
        whose pieces ship as-is; otherwise the scheduler applies the legacy
        partition→slice→combine seam.  Shared by the map attempt AND
        lineage recovery, so recomputation climbs through fused stages and
        re-derives byte-identical blocks (tasks are deterministic)."""
        from .shuffle import BucketedBatch
        if isinstance(batch, BucketedBatch):
            return batch.pieces
        from .shuffle import split_bucket_pieces
        bucket_of = dep.partitioner(batch)
        pieces = split_bucket_pieces(batch, bucket_of, dep.num_buckets)
        if dep.map_side_combine is not None:
            pieces = [dep.map_side_combine(p) for p in pieces]
        return pieces

    def _run_map_stage_attempt(self, dep: ShuffleDependency) -> StageStats:
        stage_id = next(_stage_counter)
        parent = dep.parent
        stats = StageStats(stage_id)
        stats_lock = threading.Lock()

        def run_one(split: int, tc: TaskContext):
            batch = parent.iterator(split, tc)
            accs = dep.accumulators()
            pieces = self._map_output_pieces(dep, batch)
            for b, piece in enumerate(pieces):
                for acc in accs:
                    acc.update(b, piece)
                self.ctx.block_manager.put_shuffle(
                    dep.shuffle_id, split, b, piece, tc.worker_id)
            self._log_event("map-done", dep.shuffle_id, split)
            ts = TaskStats(split, stage_id,
                           {a.name: a.payload() for a in accs})
            with stats_lock:
                stats.add(ts)
            return True

        self._run_tasks(stage_id, range(parent.num_partitions), run_one)
        self.stage_stats[stage_id] = stats
        return stats

    def _recover_map_outputs(self, dep: ShuffleDependency,
                             missing: List[int]) -> None:
        """Lineage recovery: recompute only the lost map tasks, in parallel
        across surviving workers (§2.3 items 1–2)."""
        stage_id = next(_stage_counter)
        parent = dep.parent

        def run_one(split: int, tc: TaskContext):
            batch = parent.iterator(split, tc)
            for b, piece in enumerate(self._map_output_pieces(dep, batch)):
                self.ctx.block_manager.put_shuffle(
                    dep.shuffle_id, split, b, piece, tc.worker_id)
            return True

        with self.lock:
            self.tasks_recomputed += len(missing)
        self._run_tasks(stage_id, missing, run_one)

    # -- pipelined map→reduce overlap (DESIGN.md §14) -------------------------

    def run_map_stage_pipelined(self, dep: ShuffleDependency,
                                groups: Sequence[Sequence[int]],
                                reduce_fn: Callable[[int, List[PartitionBatch]],
                                                    Any]
                                ) -> Tuple[StageStats, Dict[int, Any]]:
        """Run the map stage while reduce tasks start as soon as their input
        pieces land, overlapping shuffle fetch with upstream compute.

        `groups[r]` lists the buckets reduce split `r` consumes;
        `reduce_fn(split, pieces)` must be deterministic — pieces arrive in
        the same (map, bucket) order `fetch_shuffle` would return.  Returns
        (stats, precomputed): reduce splits whose pipelined attempt failed
        (worker death mid-stage, fetch races) are simply absent from
        `precomputed` and recompute on the standard pull path — the
        pipeline is an overlap optimization, never a correctness
        dependency.  The map stage itself runs via `self.run_map_stage`
        so chaos-test interceptions (and lineage retries) apply
        unchanged."""
        done = threading.Event()
        results: Dict[int, Any] = {}
        rlock = threading.Lock()
        threads = [
            threading.Thread(
                target=self._pipelined_reduce,
                args=(dep, r, list(buckets), reduce_fn, done, results, rlock),
                daemon=True)
            for r, buckets in enumerate(groups)]
        for t in threads:
            t.start()
        try:
            stats = self.run_map_stage(dep)
        finally:
            done.set()
        for t in threads:
            t.join(timeout=10.0)
        return stats, dict(results)

    def _pipelined_reduce(self, dep: ShuffleDependency, split: int,
                          buckets: List[int], reduce_fn, cancel, results,
                          rlock) -> None:
        num_maps = dep.parent.num_partitions
        bm = self.ctx.block_manager
        pieces: List[PartitionBatch] = []
        try:
            # In-order per-map waiting keeps piece order identical to the
            # pull path's fetch_shuffle and makes the event log
            # deterministic under a straggler on a later map split.
            for m in range(num_maps):
                if not bm.wait_shuffle(dep.shuffle_id, [m], buckets,
                                       cancel=cancel):
                    return
                pieces.extend(bm.fetch_shuffle(
                    dep.shuffle_id, num_maps, buckets, maps=[m]))
                if m == 0:
                    self._log_event("reduce-fetch", dep.shuffle_id, split)
            self._log_event("reduce-start", dep.shuffle_id, split)
            out = reduce_fn(split, pieces)
        except Exception:
            return  # fall back to the pull path (deterministic parity)
        with rlock:
            results[split] = out
        self._log_event("reduce-done", dep.shuffle_id, split)

    # -- result stages --------------------------------------------------------

    def run_result_stage(self, rdd: RDD) -> List[PartitionBatch]:
        """Compute the final RDD's partitions, transparently recovering from
        lost shuffle outputs mid-query via lineage recompute."""
        for retry in range(self.max_stage_retries):
            stage_id = next(_stage_counter)
            try:
                results = self._run_tasks(
                    stage_id, range(rdd.num_partitions),
                    lambda split, tc: rdd.iterator(split, tc))
                return [results[i] for i in range(rdd.num_partitions)]
            except FetchFailed as ff:
                self._recover_lineage(rdd, ff)
        raise RuntimeError("exceeded max stage retries")

    def run_job(self, rdd: RDD) -> List[PartitionBatch]:
        """Run all ancestor map stages (in lineage order), then the result
        stage.  This is the non-PDE path; PDE drives stages itself."""
        for dep in _all_shuffle_deps(rdd):
            if not self._map_outputs_complete(dep):
                self.run_map_stage(dep)
        return self.run_result_stage(rdd)

    def _map_outputs_complete(self, dep: ShuffleDependency) -> bool:
        return all(self.ctx.block_manager.has_map_output(dep.shuffle_id, m)
                   for m in range(dep.parent.num_partitions))

    # -- resilience reporting (DESIGN.md §16) ---------------------------------

    def resilience_stats(self) -> Dict[str, int]:
        with self.lock:
            out = dict(self.resilience_counters)
        out.update(self.health.stats())
        return out

    def describe_resilience(self) -> str:
        """explain()-adjacent one-stop report of every policy decision this
        scheduler took: counters, worker health, and the policy knobs."""
        with self.lock:
            counters = {k: v for k, v in self.resilience_counters.items()
                        if v}
        return describe_counters(counters, self.health, self.policy)


def _all_shuffle_deps(rdd: RDD, out: Optional[List[ShuffleDependency]] = None,
                      seen: Optional[Set[int]] = None) -> List[ShuffleDependency]:
    out = out if out is not None else []
    seen = seen if seen is not None else set()
    if rdd.id in seen:
        return out
    seen.add(rdd.id)
    for d in rdd.deps:
        _all_shuffle_deps(d.parent, out, seen)
        if isinstance(d, ShuffleDependency):
            out.append(d)
    return out


def _find_shuffle_dep(rdd: RDD, shuffle_id: int) -> Optional[ShuffleDependency]:
    for dep in _all_shuffle_deps(rdd):
        if dep.shuffle_id == shuffle_id:
            return dep
    return None


class SharkContext:
    """The cluster handle: block manager + scheduler + RDD constructors."""

    def __init__(self, num_workers: int = 8, max_threads: int = 8,
                 speculation: bool = True,
                 task_launch_overhead_s: float = 0.0,
                 policy: Optional[ResiliencePolicy] = None):
        self.block_manager = BlockManager()
        self.scheduler = Scheduler(
            self, num_workers=num_workers, max_threads=max_threads,
            speculation=speculation,
            task_launch_overhead_s=task_launch_overhead_s,
            policy=policy)
        # one policy object governs the context's layers; the BlockManager
        # reads it for shuffle-wait timeouts
        self.policy = self.scheduler.policy
        self.block_manager.policy = self.policy
        # fault-injection engine (faults.ChaosEngine.install sets this)
        self.chaos = None

    def parallelize(self, batches: List[PartitionBatch]):
        from .rdd import ParallelCollectionRDD
        return ParallelCollectionRDD(self, batches)

    def scan(self, table, columns=None, selected=None):
        from .rdd import TableScanRDD
        return TableScanRDD(self, table, columns, selected)

    def shutdown(self):
        self.scheduler.pool.shutdown(wait=False)
