"""SharkSession — the user-facing entry point (paper §2, §4.1; DESIGN.md §7).

    sess = SharkSession(num_workers=8)
    sess.create_table("logs", schema, data)          # load into memory store
    res = sess.sql("SELECT pageURL, pageRank FROM rankings WHERE pageRank > 100")
    top = sess.table("rankings").filter(col("pageRank") > 100)   # fluent

Both query surfaces return a `SharkFrame` over the same logical plan:
`sql()` executes eagerly (back-compat — the frame doubles as the old
ExecResult) unless `lazy=True`; `table()` starts a lazy fluent chain.
Either way `.to_rdd()` hands the *query plan as an RDD* rather than
collected rows: ML invokes distributed computation over it (Listing 1 of
the paper), the whole pipeline shares one lineage graph, and recovery
spans SQL and ML.

A session can also *attach to a shared SharkServer* (DESIGN.md §6) instead
of owning a private context:

    srv = SharkServer(cache_budget_bytes=64 << 20)
    sess = SharkSession(server=srv, client_id="dash", weight=4.0)
    sess.sql("...")                 # fair-scheduled on the server pool
    h = sess.submit("...")          # async QueryHandle

Attached sessions share the server's catalog, block store, memory budget,
and result cache; queries — SQL text or frames, which submit their *bound
plan* — route through the server's admission-controlled scheduler, while
plan/explain/to_rdd still work locally against the shared catalog (same
lineage graph, same workers).
"""

from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .catalog import Catalog, ExternalSource
from .columnar import Table, from_arrays
from .batch import PartitionBatch
from .frame import SharkFrame
from .pde import PDEConfig
from .physical import ExecResult, Executor
from .plan import Node, explain, optimize
from .rdd import RDD
from .runtime import SharkContext
from .sql import Binder, CreateStmt, SelectStmt, parse
from .types import Schema


class SharkSession:
    def __init__(self, num_workers: int = 8, max_threads: int = 8,
                 enable_pde: bool = True, enable_map_pruning: bool = True,
                 default_partitions: int = 8,
                 default_shuffle_buckets: int = 64,
                 pde_config: Optional[PDEConfig] = None,
                 speculation: bool = True,
                 task_launch_overhead_s: float = 0.0,
                 server=None, client_id: Optional[str] = None,
                 weight: float = 1.0, backend: str = "compiled",
                 exchange: str = "coded", mesh=None,
                 stage_fusion: str = "on", resilience=None):
        self.server = server
        if server is not None:
            # attached mode: share the server's runtime + catalog; queries
            # route through its fair scheduler (see module docstring)
            self.ctx = server.ctx
            self.catalog = server.catalog
            self.default_partitions = server.default_partitions
            self.executor = server.make_executor()
            self.client_id = client_id or f"session-{id(self):x}"
            server.register_client(self.client_id, weight)
            return
        self.client_id = client_id or "local"
        self.ctx = SharkContext(num_workers=num_workers,
                                max_threads=max_threads,
                                speculation=speculation,
                                task_launch_overhead_s=task_launch_overhead_s,
                                policy=resilience)
        self.catalog = Catalog()
        self.default_partitions = default_partitions
        self.executor = Executor(
            self.ctx, self.catalog, pde_config or PDEConfig(),
            enable_pde=enable_pde, enable_map_pruning=enable_map_pruning,
            default_shuffle_buckets=default_shuffle_buckets,
            backend=backend, exchange=exchange, mesh=mesh,
            stage_fusion=stage_fusion)

    # -- data loading ---------------------------------------------------------

    def create_table(self, name: str, schema: Schema,
                     data: Dict[str, np.ndarray],
                     num_partitions: Optional[int] = None,
                     distribute_by: Optional[str] = None) -> Table:
        """Distributed load into the columnar memory store (§3.3)."""
        table = from_arrays(name, schema, data,
                            num_partitions or self.default_partitions,
                            distribute_by)
        self.catalog.register_table(table)
        return table

    def register_external(self, src: ExternalSource) -> None:
        self.catalog.register_external(src)

    # -- query construction / execution -----------------------------------------

    def table(self, name: str) -> SharkFrame:
        """Start a fluent SharkFrame query over a catalog table."""
        return SharkFrame.table(self, name)

    def plan(self, sql: str) -> Node:
        stmt = parse(sql)
        if isinstance(stmt, CreateStmt):
            stmt = stmt.select
        return Binder(self.catalog).bind(stmt)

    def explain(self, sql: str) -> str:
        node = optimize(self.plan(sql), self.catalog)
        return explain(node)

    def sql(self, sql: str, lazy: bool = False) -> SharkFrame:
        """Parse + bind `sql` into a SharkFrame — text queries and fluent
        queries are the same object from bind onward.  By default the frame
        is executed eagerly (the historical contract: `sql()` returned a
        finished result); pass `lazy=True` to defer execution, e.g. to
        extend the plan or hand it to ML via `.to_rdd()`."""
        stmt = parse(sql)
        if isinstance(stmt, CreateStmt):
            if self.server is not None:
                result = self.server.submit(
                    sql, client=self.client_id).result()
            else:
                result = self._create_table_as(stmt)
            node = Binder(self.catalog).bind(stmt.select)
            return SharkFrame(self, node, result=result)
        node = Binder(self.catalog).bind(stmt)
        frame = SharkFrame(self, node)
        if not lazy:
            frame.collect()
        return frame

    def submit(self, query: Union[str, Node], block: bool = True,
               timeout: Optional[float] = None):
        """Async submission of SQL text or a bound logical plan — attached
        sessions only; returns a QueryHandle."""
        if self.server is None:
            raise RuntimeError(
                "submit() needs a server-attached session; use sql()")
        return self.server.submit(query, client=self.client_id, block=block,
                                  timeout=timeout)

    def sql_np(self, sql: str) -> Dict[str, np.ndarray]:
        return self.sql(sql).to_numpy()

    def sql2rdd(self, sql: str) -> Tuple[RDD, List[str]]:
        """Deprecated shim over `sess.sql(sql, lazy=True).to_rdd()`.

        Returns the query plan as a lazy TableRDD plus its column names
        (paper §4.1).  The frame path registers the RDD's shuffle map
        outputs on this session's executor, so `release_shuffles()` /
        `shutdown()` frees them — a server-attached session cannot silently
        leak shared-store memory."""
        warnings.warn(
            "sql2rdd() is deprecated; use sess.sql(query, lazy=True)"
            ".to_rdd() or a fluent sess.table(...) chain",
            DeprecationWarning, stacklevel=2)
        stmt = parse(sql)
        assert isinstance(stmt, SelectStmt), "sql2rdd takes a SELECT"
        frame = SharkFrame(self, Binder(self.catalog).bind(stmt))
        return frame.to_rdd(), frame.columns

    # -- CTAS / caching ---------------------------------------------------------

    def _create_table_as(self, stmt: CreateStmt) -> ExecResult:
        return create_table_as(self.executor, self.catalog, stmt,
                               self.default_partitions)

    def metrics(self):
        return self.executor.metrics

    def scheduler_metrics(self) -> Dict[str, int]:
        s = self.ctx.scheduler
        return {"tasks_launched": s.tasks_launched,
                "tasks_speculated": s.tasks_speculated,
                "tasks_recomputed": s.tasks_recomputed}

    def describe_resilience(self) -> str:
        return self.ctx.scheduler.describe_resilience()

    def release_shuffles(self):
        """Drop shuffle map outputs created by this session's executor
        (sql2rdd compilations).  Any RDD previously returned by sql2rdd must
        not be collect()ed again afterwards without re-running the query."""
        for shuffle_id in self.executor.created_shuffles:
            self.ctx.block_manager.drop_shuffle(shuffle_id)
        self.executor.created_shuffles.clear()

    def shutdown(self):
        if self.server is not None:
            # the shared context belongs to the server, but this session's
            # sql2rdd shuffle outputs must not outlive it in the shared store
            self.release_shuffles()
            return
        self.ctx.shutdown()


def create_table_as(executor: Executor, catalog: Catalog, stmt: CreateStmt,
                    default_partitions: int) -> ExecResult:
    """CREATE TABLE ... AS SELECT: execute, re-partition, register.  The
    catalog registration bumps the table's version (epoch), which
    invalidates dependent result-cache entries on the server tier."""
    sel = stmt.select
    node = Binder(catalog).bind(sel)
    result = executor.execute(node)
    num_parts = default_partitions
    distribute = sel.distribute_by
    if "copartition" in stmt.properties:
        other = catalog.get(stmt.properties["copartition"])
        num_parts = other.num_partitions
    if distribute is None and "copartition" in stmt.properties:
        raise ValueError("copartition requires DISTRIBUTE BY")
    # shark.cache => keep in the memory store (all our tables are
    # in-memory; uncached CTAS still registers but could be spilled)
    register_result_as_table(catalog, stmt.name, result, num_parts,
                             distribute)
    return result


def register_result_as_table(catalog: Catalog, name: str, result: ExecResult,
                             num_partitions: int,
                             distribute_by: Optional[str]) -> Table:
    """Re-partition a query result into the columnar store and register it
    (shared by CTAS and `SharkFrame.cache()`)."""
    merged = PartitionBatch.concat(result.batches)
    data = merged.decoded()
    schema = _infer_schema(data, result.schema_names)
    table = from_arrays(name, schema, data, num_partitions, distribute_by)
    catalog.register_table(table)
    return table


def _infer_schema(data: Dict[str, np.ndarray], names: List[str]) -> Schema:
    from .types import DType, Field
    fields = []
    for n in names:
        v = np.asarray(data[n])
        if v.dtype.kind in ("U", "S", "O"):
            dt = DType.STRING
        elif v.dtype.kind == "b":
            dt = DType.BOOL
        elif v.dtype.kind == "f":
            dt = DType.FLOAT64 if v.dtype.itemsize == 8 else DType.FLOAT32
        elif v.dtype.itemsize <= 4:
            dt = DType.INT32
        else:
            dt = DType.INT64
        fields.append(Field(n, dt))
    return Schema(tuple(fields))
