"""SharkSession — the user-facing entry point (paper §2, §4.1).

    sess = SharkSession(num_workers=8)
    sess.create_table("logs", schema, data)          # load into memory store
    res = sess.sql("SELECT pageURL, pageRank FROM rankings WHERE pageRank > 100")
    rdd, names = sess.sql2rdd("SELECT * FROM users")  # feed ML directly

`sql2rdd` returns the *query plan as an RDD* rather than collected rows:
callers invoke distributed computation over it (Listing 1 of the paper), the
whole pipeline shares one lineage graph, and recovery spans SQL and ML.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .catalog import Catalog, ExternalSource
from .columnar import Table, from_arrays
from .batch import PartitionBatch
from .pde import PDEConfig
from .physical import ExecResult, Executor
from .plan import Node, explain, optimize
from .rdd import RDD
from .runtime import SharkContext
from .sql import Binder, CreateStmt, SelectStmt, parse
from .types import Schema


class SharkSession:
    def __init__(self, num_workers: int = 8, max_threads: int = 8,
                 enable_pde: bool = True, enable_map_pruning: bool = True,
                 default_partitions: int = 8,
                 default_shuffle_buckets: int = 64,
                 pde_config: Optional[PDEConfig] = None,
                 speculation: bool = True,
                 task_launch_overhead_s: float = 0.0):
        self.ctx = SharkContext(num_workers=num_workers,
                                max_threads=max_threads,
                                speculation=speculation,
                                task_launch_overhead_s=task_launch_overhead_s)
        self.catalog = Catalog()
        self.default_partitions = default_partitions
        self.executor = Executor(
            self.ctx, self.catalog, pde_config or PDEConfig(),
            enable_pde=enable_pde, enable_map_pruning=enable_map_pruning,
            default_shuffle_buckets=default_shuffle_buckets)

    # -- data loading ---------------------------------------------------------

    def create_table(self, name: str, schema: Schema,
                     data: Dict[str, np.ndarray],
                     num_partitions: Optional[int] = None,
                     distribute_by: Optional[str] = None) -> Table:
        """Distributed load into the columnar memory store (§3.3)."""
        table = from_arrays(name, schema, data,
                            num_partitions or self.default_partitions,
                            distribute_by)
        self.catalog.register_table(table)
        return table

    def register_external(self, src: ExternalSource) -> None:
        self.catalog.register_external(src)

    # -- query execution --------------------------------------------------------

    def plan(self, sql: str) -> Node:
        stmt = parse(sql)
        if isinstance(stmt, CreateStmt):
            stmt = stmt.select
        return Binder(self.catalog).bind(stmt)

    def explain(self, sql: str) -> str:
        node = optimize(self.plan(sql), self.catalog)
        return explain(node)

    def sql(self, sql: str) -> ExecResult:
        stmt = parse(sql)
        if isinstance(stmt, CreateStmt):
            return self._create_table_as(stmt)
        node = Binder(self.catalog).bind(stmt)
        return self.executor.execute(node)

    def sql_np(self, sql: str) -> Dict[str, np.ndarray]:
        return self.sql(sql).to_numpy()

    def sql2rdd(self, sql: str) -> Tuple[RDD, List[str]]:
        """Return the query result as a TableRDD (paper §4.1): the final
        narrow stage is left lazy so downstream ML extends the same lineage
        graph; upstream shuffle stages have already been PDE-planned."""
        stmt = parse(sql)
        assert isinstance(stmt, SelectStmt), "sql2rdd takes a SELECT"
        node = Binder(self.catalog).bind(stmt)
        from .plan import optimize as opt
        node = opt(node, self.catalog)
        compiled = self.executor._compile(node)
        return compiled.rdd, compiled.names

    # -- CTAS / caching ---------------------------------------------------------

    def _create_table_as(self, stmt: CreateStmt) -> ExecResult:
        sel = stmt.select
        node = Binder(self.catalog).bind(sel)
        result = self.executor.execute(node)
        merged = PartitionBatch.concat(result.batches)
        data = merged.decoded()
        schema = _infer_schema(data, result.schema_names)
        num_parts = self.default_partitions
        distribute = sel.distribute_by
        if "copartition" in stmt.properties:
            other = self.catalog.get(stmt.properties["copartition"])
            num_parts = other.num_partitions
        if distribute is None and "copartition" in stmt.properties:
            raise ValueError("copartition requires DISTRIBUTE BY")
        table = from_arrays(stmt.name, schema, data, num_parts, distribute)
        # shark.cache => keep in the memory store (all our tables are
        # in-memory; uncached CTAS still registers but could be spilled)
        self.catalog.register_table(table)
        return result

    def metrics(self):
        return self.executor.metrics

    def scheduler_metrics(self) -> Dict[str, int]:
        s = self.ctx.scheduler
        return {"tasks_launched": s.tasks_launched,
                "tasks_speculated": s.tasks_speculated,
                "tasks_recomputed": s.tasks_recomputed}

    def shutdown(self):
        self.ctx.shutdown()


def _infer_schema(data: Dict[str, np.ndarray], names: List[str]) -> Schema:
    from .types import DType, Field
    fields = []
    for n in names:
        v = np.asarray(data[n])
        if v.dtype.kind in ("U", "S", "O"):
            dt = DType.STRING
        elif v.dtype.kind == "b":
            dt = DType.BOOL
        elif v.dtype.kind == "f":
            dt = DType.FLOAT64 if v.dtype.itemsize == 8 else DType.FLOAT32
        elif v.dtype.itemsize <= 4:
            dt = DType.INT32
        else:
            dt = DType.INT64
        fields.append(Field(n, dt))
    return Schema(tuple(fields))
