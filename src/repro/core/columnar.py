"""Columnar memory store (paper §3.2, §3.3, §3.5).

A cached table is a list of `Partition`s; each partition stores one
`ColumnBlock` per column: a single contiguous array per column (the paper's
"each column creates only one JVM object"), compressed per-partition, plus
piggybacked statistics collected during the load task:

  * min / max range of each column,
  * the distinct-value set when small (enum columns),
  * row count and encoded byte size.

These stats flow back to the master and drive *map pruning*: the master never
launches scan tasks for partitions whose stats refute the query predicate.

String columns are dictionary-encoded at load; the engine computes on int32
codes and only materializes strings at the result boundary.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from .compression import Encoded, Encoding, decode_np, encode, recompress
from .types import DType, Field, Schema

ENUM_DISTINCT_LIMIT = 64  # paper: keep distinct values "if the number is small"


@dataclasses.dataclass
class ColumnStats:
    """Per-partition, per-column statistics piggybacked on data loading."""
    min: Optional[float] = None
    max: Optional[float] = None
    distinct: Optional[frozenset] = None   # only when |distinct| small
    count: int = 0
    nbytes: int = 0
    null_count: int = 0

    def may_satisfy_range(self, lo: Optional[float], hi: Optional[float]) -> bool:
        """Could any row of this partition fall inside [lo, hi]?"""
        if self.count == 0:
            return False
        if lo is not None and self.max is not None and self.max < lo:
            return False
        if hi is not None and self.min is not None and self.min > hi:
            return False
        return True

    def may_contain(self, value) -> bool:
        if self.distinct is not None:
            return value in self.distinct
        return self.may_satisfy_range(value, value)


@dataclasses.dataclass
class ColumnBlock:
    field: Field
    enc: Encoded
    stats: ColumnStats
    # For STRING columns: the partition-local string dictionary; values()
    # returns int32 codes into it.
    str_dict: Optional[np.ndarray] = None

    def values(self) -> np.ndarray:
        """Raw stored values (int32 dictionary codes for STRING columns)."""
        return decode_np(self.enc)

    @property
    def encoding(self) -> Encoding:
        return self.enc.encoding

    def code_space(self):
        """Encoded-aware access for the compiled execution path: when this
        block's *stored values* are DICT-encoded, return (codes, sorted
        dictionary) so predicates can be evaluated on int32 codes without
        decoding — `np.unique` dictionaries are sorted and unique, so code
        order is value order and range/equality predicates translate to
        code-bound compares.  Returns None for other encodings (their
        streams are not order-preserving code streams) and for float
        dictionaries containing NaN: np.unique sorts NaN to the tail, so a
        code-bound `>=` would include NaN rows that every value-space
        comparison excludes."""
        if self.enc.encoding != Encoding.DICT:
            return None
        d = self.enc.dictionary
        if d.dtype.kind == "f" and len(d) and np.isnan(d[-1]):
            return None
        return self.enc.codes, d

    def frame_space(self):
        """Encoded-aware access for frame-of-reference blocks: (codes, bias)
        where `value = code + bias` exactly (integer columns only), so the
        code stream is order-preserving and range/equality predicates
        translate to code-bound compares on the narrow resident lane —
        the FOR twin of `code_space()` (DESIGN.md §12).  None for every
        other encoding."""
        enc = self.enc
        if enc.encoding != Encoding.FOR or self.str_dict is not None:
            return None
        return enc.codes, enc.bias

    def run_space(self):
        """Encoded-aware access for RLE blocks: (run_values, run_lengths) in
        stored-value space, for run-level predicate/aggregate evaluation
        without expanding the runs.  None for every other encoding."""
        enc = self.enc
        if enc.encoding != Encoding.RLE:
            return None
        return enc.run_values, enc.run_lengths

    def pack_space(self):
        """Encoded-aware access for bit-packed blocks:
        (words, bit_width, bias, n) where the uint32 words hold
        `value - bias` lanes at `bit_width` bits.  Like FOR, the biased code
        stream is order-preserving, so range/equality predicates translate
        to code bounds host-side and the scan unpacks + compares the narrow
        lanes without ever widening to the logical dtype — the BITPACK twin
        of `frame_space()` (DESIGN.md §12).  None for every other encoding
        and for dictionary-string blocks (their code order is dictionary
        order, not value order of the packed lane)."""
        enc = self.enc
        if enc.encoding != Encoding.BITPACK or self.str_dict is not None:
            return None
        return enc.words, enc.bit_width, enc.bias, enc.n

    def recompress(self) -> int:
        """Adaptive WARM-tier recompression (pressure hook): re-encode with
        the scheme `choose_recompression` picks from run-length/span/NDV
        signals; keeps the block only if strictly smaller.  Returns bytes
        freed (encoded delta plus any decoded cache released)."""
        old = self.enc
        pre_decoded = old.decoded_nbytes
        new = recompress(old)
        old.drop_decoded()
        new.drop_decoded()
        freed = pre_decoded
        if new is not old:
            freed += old.nbytes - new.nbytes
            self.enc = new
            self.stats.nbytes = new.nbytes
        return freed

    def drop_decoded(self) -> int:
        return self.enc.drop_decoded()

    def decoded(self) -> np.ndarray:
        """Logical values: maps codes through the partition-local string
        dictionary.  Used at shuffle/join/result boundaries where values must
        compare consistently across partitions."""
        v = decode_np(self.enc)
        if self.str_dict is not None:
            return self.str_dict[v]
        return v

    @property
    def n(self) -> int:
        return self.enc.n

    @property
    def nbytes(self) -> int:
        base = self.enc.nbytes
        if self.str_dict is not None:
            base += self.str_dict.nbytes
        return base


def _make_stats(values: np.ndarray, nbytes: int,
                logical: Optional[np.ndarray] = None) -> ColumnStats:
    n = len(values)
    if n == 0:
        return ColumnStats(count=0, nbytes=nbytes)
    src = logical if logical is not None else values
    uniq = np.unique(src[: 65536])
    distinct = frozenset(uniq.tolist()) if len(uniq) <= ENUM_DISTINCT_LIMIT else None
    if src.dtype.kind in ("U", "S", "O"):
        # string column: range stats are lexicographic on the logical values
        return ColumnStats(min=None, max=None, distinct=distinct, count=n,
                           nbytes=nbytes)
    return ColumnStats(
        min=float(src.min()), max=float(src.max()),
        distinct=distinct, count=n, nbytes=nbytes)


def make_block(field: Field, values: np.ndarray,
               encoding: Optional[Encoding] = None) -> ColumnBlock:
    """One data-loading task's work for one column: marshal to columnar form,
    pick a compression scheme locally, collect stats (paper §3.3, §3.5)."""
    str_dict = None
    logical = None
    if field.dtype == DType.STRING and values.dtype.kind in ("U", "S", "O"):
        logical = np.asarray(values, dtype=np.str_)
        str_dict, codes = np.unique(logical, return_inverse=True)
        values = codes.astype(np.int32)
    values = np.asarray(values, dtype=field.dtype.np_dtype)
    enc = encode(values, encoding)
    return ColumnBlock(field, enc, _make_stats(values, enc.nbytes, logical),
                       str_dict)


# Monotonic access clock for the storage tier's coldest-first spill policy
# (DESIGN.md §12): the scan path stamps partitions on every read.
_ACCESS_CLOCK = itertools.count(1)


class Partition:
    """One horizontal slice of a table, held in the memory store.

    Storage-tier states (DESIGN.md §12): a partition is *resident* (HOT with
    decoded caches, WARM once recompressed/caches dropped) or *cold* — its
    column blocks spilled to disk (or dropped outright) by the server's
    StorageManager under memory pressure.  `columns` faults a cold partition
    back in transparently: spill-file read first, recompute-from-lineage on
    a lost or corrupt file.  Stats are snapshotted at build time so map
    pruning and byte accounting never fault a cold partition."""

    def __init__(self, index: int, columns: Dict[str, ColumnBlock]):
        self.index = index
        self._columns: Optional[Dict[str, ColumnBlock]] = columns
        self._stats = {n: b.stats for n, b in columns.items()}
        self._num_rows = next(iter(columns.values())).n if columns else 0
        self.last_access = 0        # _ACCESS_CLOCK stamp (0 = never scanned)
        # cold-tier bookkeeping, owned by storage.StorageManager
        self.spill_ref = None       # storage.SpillRef while cold-on-disk
        self.storage = None         # StorageManager once it ever evicted us
        self.lineage: Optional[Callable[[], Dict[str, ColumnBlock]]] = None

    # -- tier state -----------------------------------------------------------

    @property
    def resident(self) -> bool:
        return self._columns is not None

    @property
    def columns(self) -> Dict[str, ColumnBlock]:
        if self._columns is None:
            self.storage.fault_in(self)
        return self._columns

    def touch(self) -> None:
        self.last_access = next(_ACCESS_CLOCK)

    def release_columns(self) -> int:
        """Go cold: drop the resident column blocks (the StorageManager has
        already serialized them if this is a spill, not a drop).  Returns
        resident bytes freed (encoded + decoded caches)."""
        if self._columns is None:
            return 0
        freed = sum(b.nbytes + b.enc.decoded_nbytes
                    for b in self._columns.values())
        self._columns = None
        return freed

    def restore_columns(self, columns: Dict[str, ColumnBlock]) -> None:
        self._columns = columns
        self._stats = {n: b.stats for n, b in columns.items()}

    # -- sizes / stats (never fault) -----------------------------------------

    @property
    def num_rows(self) -> int:
        return self._num_rows

    @property
    def nbytes(self) -> int:
        """Logical encoded size: the last known resident footprint while
        cold (size hints must not fault a spilled partition back in)."""
        if self._columns is None:
            return sum(s.nbytes for s in self._stats.values())
        return sum(b.nbytes for b in self._columns.values())

    @property
    def resident_nbytes(self) -> int:
        """Encoded bytes actually held in memory (0 while cold)."""
        if self._columns is None:
            return 0
        return sum(b.nbytes for b in self._columns.values())

    def column(self, name: str) -> ColumnBlock:
        return self.columns[name]

    def drop_decoded(self) -> int:
        """Release all memoized decode caches in this partition."""
        if self._columns is None:
            return 0
        return sum(b.drop_decoded() for b in self._columns.values())

    def recompress(self) -> int:
        """WARM transition: adaptively recompress every resident block;
        returns bytes freed."""
        if self._columns is None:
            return 0
        return sum(b.recompress() for b in self._columns.values())

    @property
    def decoded_cache_nbytes(self) -> int:
        if self._columns is None:
            return 0
        return sum(b.enc.decoded_nbytes for b in self._columns.values())

    def arrays(self, names: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
        cols = self.columns
        names = names if names is not None else list(cols)
        return {n: cols[n].values() for n in names}

    def decoded_arrays(self, names: Optional[Sequence[str]] = None
                       ) -> Dict[str, np.ndarray]:
        cols = self.columns
        names = names if names is not None else list(cols)
        return {n: cols[n].decoded() for n in names}

    def stats(self) -> Dict[str, ColumnStats]:
        return dict(self._stats)


def build_partition(index: int, schema: Schema,
                    data: Dict[str, np.ndarray]) -> Partition:
    cols = {f.name: make_block(f, data[f.name]) for f in schema.fields}
    ns = {b.n for b in cols.values()}
    assert len(ns) <= 1, f"ragged partition: {ns}"
    return Partition(index, cols)


@dataclasses.dataclass
class Table:
    """A cached, partitioned, columnar table (shark.cache=true semantics)."""
    name: str
    schema: Schema
    partitions: List[Partition]
    # Co-partitioning metadata (§3.4): set when the table was DISTRIBUTE'd BY
    # a key; two tables sharing (key-column, num_partitions) join shuffle-free.
    distribute_key: Optional[str] = None
    # Vector analytics metadata (DESIGN.md §15.3): embedding name -> its
    # fixed-width float lane columns ("emb" -> ["emb_0", "emb_1", ...]).
    # Lanes are ordinary FLOAT32 columns — they prune, compress, and project
    # like any other — the mapping just lets `similarity_join` resolve a
    # logical vector column back to its lanes.
    embeddings: Dict[str, List[str]] = dataclasses.field(default_factory=dict)

    @property
    def num_rows(self) -> int:
        return sum(p.num_rows for p in self.partitions)

    @property
    def num_partitions(self) -> int:
        return len(self.partitions)

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.partitions)

    def drop_decoded(self) -> int:
        """Release every partition's memoized decode cache (MemoryManager
        pressure hook): bytes freed."""
        return sum(p.drop_decoded() for p in self.partitions)

    @property
    def decoded_cache_nbytes(self) -> int:
        return sum(p.decoded_cache_nbytes for p in self.partitions)

    @property
    def resident_nbytes(self) -> int:
        """Encoded bytes currently held in memory (cold partitions count 0)."""
        return sum(p.resident_nbytes for p in self.partitions)

    def column_np(self, name: str) -> np.ndarray:
        """Materialize a full column, logically decoded (testing / results)."""
        parts = [p.columns[name].decoded() for p in self.partitions]
        return np.concatenate(parts) if parts else np.zeros(0)

    def to_dict(self) -> Dict[str, np.ndarray]:
        return {n: self.column_np(n) for n in self.schema.names}

    def co_partitioned_with(self, other: "Table", key_self: str,
                            key_other: str) -> bool:
        return (self.distribute_key == key_self
                and other.distribute_key == key_other
                and self.num_partitions == other.num_partitions
                and self.num_partitions > 0)


def hash_key_values(values: np.ndarray) -> np.ndarray:
    """Deterministic int64 hash of key values, identical across the whole
    engine so DISTRIBUTE BY tables and shuffle buckets align (§3.4).
    Strings hash via crc32 of each *distinct* value (vectorized through the
    dictionary); numerics hash by value."""
    import zlib
    v = np.asarray(values)
    if v.dtype.kind in ("U", "S", "O"):
        uniq, inv = np.unique(v.astype(np.str_), return_inverse=True)
        hd = np.array([zlib.crc32(s.encode()) for s in uniq.tolist()],
                      dtype=np.int64)
        return hd[inv]
    if v.dtype.kind == "f":
        return v.astype(np.int64)
    return v.astype(np.int64)


def hash_partition_arrays(key: np.ndarray, num_partitions: int) -> np.ndarray:
    """Deterministic hash partitioning used by DISTRIBUTE BY and shuffles.

    Must be identical everywhere so co-partitioned tables align (§3.4)."""
    k = hash_key_values(key)
    h = k.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    h ^= h >> np.uint64(29)
    return (h % np.uint64(num_partitions)).astype(np.int32)


def from_arrays(name: str, schema: Schema, data: Dict[str, np.ndarray],
                num_partitions: int = 8,
                distribute_by: Optional[str] = None) -> Table:
    """Distributed data loading (§3.3): split rows into partitions, each
    'load task' builds its columnar blocks independently.

    Embedding columns (DESIGN.md §15.3): a data key that is NOT in the
    schema and holds a 2-D float array `(rows, width)` is an embedding —
    it explodes into `width` FLOAT32 lane columns `{key}_{i}` appended to
    the schema, and the lane mapping is recorded on `Table.embeddings` so
    `similarity_join` can resolve the vector back to its lanes."""
    n = len(next(iter(data.values()))) if data else 0
    embeddings: Dict[str, List[str]] = {}
    schema_names = set(schema.names)
    extra_fields: List[Field] = []
    for key in list(data):
        if key in schema_names:
            continue
        v = np.asarray(data[key])
        if v.ndim != 2:
            continue        # non-schema 1-D keys stay ignored (legacy)
        lanes = [f"{key}_{i}" for i in range(v.shape[1])]
        clash = [l for l in lanes if l in schema_names or l in data]
        if clash:
            raise ValueError(
                f"from_arrays: embedding {key!r} lane column(s) "
                f"{clash} collide with existing columns")
        for i, lane in enumerate(lanes):
            data[lane] = np.ascontiguousarray(v[:, i], dtype=np.float32)
            extra_fields.append(Field(lane, DType.FLOAT32))
        embeddings[key] = lanes
        del data[key]
    if extra_fields:
        schema = Schema(schema.fields + tuple(extra_fields))
    # STRING columns: encode to global codes first so DISTRIBUTE BY and joins
    # on strings hash consistently across partitions.
    norm: Dict[str, np.ndarray] = {}
    for f in schema.fields:
        v = np.asarray(data[f.name])
        norm[f.name] = v
    if distribute_by is not None:
        keyv = norm[distribute_by]
        pids = hash_partition_arrays(np.asarray(keyv), num_partitions)
        order = np.argsort(pids, kind="stable")
        bounds = np.searchsorted(pids[order], np.arange(num_partitions + 1))
        parts = []
        for i in range(num_partitions):
            sel = order[bounds[i]: bounds[i + 1]]
            parts.append(build_partition(
                i, schema, {k: v[sel] for k, v in norm.items()}))
        return Table(name, schema, parts, distribute_key=distribute_by,
                     embeddings=embeddings)
    # round-robin contiguous split
    edges = np.linspace(0, n, num_partitions + 1, dtype=np.int64)
    parts = []
    for i in range(num_partitions):
        lo, hi = int(edges[i]), int(edges[i + 1])
        parts.append(build_partition(
            i, schema, {k: v[lo:hi] for k, v in norm.items()}))
    return Table(name, schema, parts, embeddings=embeddings)
