"""Logical query plans and rule-based optimization (paper §2.4).

Shark parses HiveQL into an AST, builds a logical plan, applies basic logical
optimization (predicate pushdown), then — unlike Hive, which emits MapReduce
stages — applies additional rule-based optimizations (e.g. pushing LIMIT down
to individual partitions) and emits a physical plan of RDD transformations.

We reproduce that pipeline: `optimize()` runs predicate pushdown, filter
merging, column pruning, and limit pushdown; `physical.compile_plan` then
turns the tree into an RDD lineage graph whose shuffle boundaries are the PDE
re-optimization points.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple

from .expr import (And, Col, Expr, Func, Lit, conjoin, infer_dtype,
                   split_conjuncts)
from .types import DType, Field, Schema


class AggFunc(enum.Enum):
    SUM = "sum"
    COUNT = "count"
    AVG = "avg"
    MIN = "min"
    MAX = "max"
    COUNT_DISTINCT = "count_distinct"


@dataclasses.dataclass(eq=False)
class AggSpec:
    out_name: str
    func: AggFunc
    arg: Optional[Expr]  # None for COUNT(*)

    def __repr__(self):
        a = "*" if self.arg is None else repr(self.arg)
        return f"{self.func.value}({a}) AS {self.out_name}"


class Node:
    def children(self) -> Sequence["Node"]:
        return ()

    def schema(self, catalog) -> Schema:
        raise NotImplementedError


@dataclasses.dataclass(eq=False)
class ScanNode(Node):
    table: str

    def schema(self, catalog) -> Schema:
        return catalog.get(self.table).schema

    def __repr__(self): return f"Scan({self.table})"


@dataclasses.dataclass(eq=False)
class FilterNode(Node):
    child: Node
    pred: Expr

    def children(self): return (self.child,)
    def schema(self, catalog): return self.child.schema(catalog)
    def __repr__(self): return f"Filter({self.pred})"


@dataclasses.dataclass(eq=False)
class ProjectNode(Node):
    child: Node
    exprs: List[Tuple[str, Expr]]  # (output name, expression)

    def children(self): return (self.child,)

    def schema(self, catalog) -> Schema:
        base = self.child.schema(catalog)
        return Schema(tuple(Field(n, infer_dtype(e, base)) for n, e in self.exprs))

    def __repr__(self):
        return "Project(" + ", ".join(f"{e} AS {n}" for n, e in self.exprs) + ")"


@dataclasses.dataclass(eq=False)
class AggregateNode(Node):
    child: Node
    group_by: List[str]          # column names (pre-projected if exprs)
    aggs: List[AggSpec]

    def children(self): return (self.child,)

    def schema(self, catalog) -> Schema:
        base = self.child.schema(catalog)
        fields = [base.field(g) for g in self.group_by]
        for a in self.aggs:
            if a.func == AggFunc.COUNT or a.func == AggFunc.COUNT_DISTINCT:
                dt = DType.INT64
            elif a.func == AggFunc.AVG:
                dt = DType.FLOAT64
            elif a.arg is not None:
                dt = infer_dtype(a.arg, base)
                if a.func == AggFunc.SUM and dt in (DType.INT32,):
                    dt = DType.INT64
            else:
                dt = DType.INT64
            fields.append(Field(a.out_name, dt))
        return Schema(tuple(fields))

    def __repr__(self):
        return f"Aggregate(by={self.group_by}, aggs={self.aggs})"


class JoinStrategy(enum.Enum):
    AUTO = "auto"            # decided at run time by PDE (§3.1.1)
    SHUFFLE = "shuffle"
    BROADCAST = "broadcast"  # map join
    COPARTITION = "copartition"


@dataclasses.dataclass(eq=False)
class JoinNode(Node):
    left: Node
    right: Node
    left_key: str
    right_key: str
    how: str = "inner"
    strategy: JoinStrategy = JoinStrategy.AUTO

    def children(self): return (self.left, self.right)

    def schema(self, catalog) -> Schema:
        return self.left.schema(catalog).concat(self.right.schema(catalog))

    def __repr__(self):
        return (f"Join({self.left_key}={self.right_key}, {self.how}, "
                f"{self.strategy.value})")


@dataclasses.dataclass(eq=False)
class SortNode(Node):
    child: Node
    keys: List[Tuple[str, bool]]  # (column, descending)

    def children(self): return (self.child,)
    def schema(self, catalog): return self.child.schema(catalog)
    def __repr__(self): return f"Sort({self.keys})"


@dataclasses.dataclass(eq=False)
class LimitNode(Node):
    child: Node
    n: int
    # set by the optimizer: per-partition pre-limit pushed below the collect
    pushed: bool = False

    def children(self): return (self.child,)
    def schema(self, catalog): return self.child.schema(catalog)
    def __repr__(self): return f"Limit({self.n}, pushed={self.pushed})"


# ---------------------------------------------------------------------------
# Rule-based optimizer
# ---------------------------------------------------------------------------


def optimize(node: Node, catalog) -> Node:
    node = push_down_filters(node, catalog)
    node = merge_filters(node)
    node = order_joins(node, catalog)
    node = push_down_limits(node)
    return node


def _substitute(e: Expr, mapping: Dict[str, Expr]) -> Expr:
    """Rewrite column refs through a projection (for pushdown)."""
    from .expr import rewrite_expr
    return rewrite_expr(
        e, lambda n: mapping.get(n.name, n) if isinstance(n, Col) else None)


def push_down_filters(node: Node, catalog=None) -> Node:
    """Predicate pushdown: move filters below projects and into join sides.

    With a catalog, scan schemas resolve exactly, so WHERE conjuncts of an
    N-way join descend all the way onto the individual scans — which is what
    feeds map pruning (§3.5) and the "likely small side" prior (§6.3.2).
    Pushing into the non-preserved side of an outer join is unsound (it
    would turn NULL-padded rows into dropped rows), so only the preserved
    left side receives pushdowns there."""
    if isinstance(node, FilterNode):
        child = node.child
        if isinstance(child, ProjectNode):
            mapping = {n: e for n, e in child.exprs}
            # only push if every referenced output column maps to a pure expr
            if all(c in mapping for c in node.pred.columns()):
                new_pred = _substitute(node.pred, mapping)
                return push_down_filters(
                    ProjectNode(FilterNode(child.child, new_pred),
                                child.exprs), catalog)
        if isinstance(child, FilterNode):
            merged = FilterNode(child.child, And(child.pred, node.pred))
            return push_down_filters(merged, catalog)
        if isinstance(child, JoinNode):
            l_schema_cols = set(_available_columns(child.left, catalog))
            r_schema_cols = set(_available_columns(child.right, catalog))
            keep, left_preds, right_preds = [], [], []
            for c in split_conjuncts(node.pred):
                cols = set(c.columns())
                if cols <= l_schema_cols:
                    left_preds.append(c)
                elif cols <= r_schema_cols and child.how == "inner":
                    right_preds.append(c)
                else:
                    keep.append(c)
            new_left = child.left
            new_right = child.right
            if left_preds:
                new_left = FilterNode(new_left, conjoin(left_preds))
            if right_preds:
                new_right = FilterNode(new_right, conjoin(right_preds))
            new_join = JoinNode(push_down_filters(new_left, catalog),
                                push_down_filters(new_right, catalog),
                                child.left_key, child.right_key, child.how,
                                child.strategy)
            if keep:
                return FilterNode(new_join, conjoin(keep))
            return new_join
        return FilterNode(push_down_filters(child, catalog), node.pred)
    # generic recursion
    for attr in ("child", "left", "right"):
        if hasattr(node, attr):
            setattr(node, attr, push_down_filters(getattr(node, attr),
                                                  catalog))
    return node


def _available_columns(node: Node, catalog=None) -> List[str]:
    if isinstance(node, ScanNode):
        if catalog is not None:
            try:
                return list(catalog.schema(node.table).names)
            except KeyError:
                pass
        return ["*"]  # unknown without catalog; "*" matches nothing
    if isinstance(node, ProjectNode):
        return [n for n, _ in node.exprs]
    if isinstance(node, AggregateNode):
        return node.group_by + [a.out_name for a in node.aggs]
    cols: List[str] = []
    for ch in node.children():
        cols.extend(_available_columns(ch, catalog))
    return cols


def merge_filters(node: Node) -> Node:
    if isinstance(node, FilterNode) and isinstance(node.child, FilterNode):
        return merge_filters(
            FilterNode(node.child.child, And(node.child.pred, node.pred)))
    for attr in ("child", "left", "right"):
        if hasattr(node, attr):
            setattr(node, attr, merge_filters(getattr(node, attr)))
    return node


def push_down_limits(node: Node) -> Node:
    """Paper §2.4: push LIMIT down to individual partitions.  Each partition
    task emits at most n rows; the collect stage applies the final limit."""
    if isinstance(node, LimitNode):
        child = node.child
        if isinstance(child, (ScanNode, FilterNode, ProjectNode)):
            node.pushed = True
    for attr in ("child", "left", "right"):
        if hasattr(node, attr):
            setattr(node, attr, push_down_limits(getattr(node, attr)))
    return node


# ---------------------------------------------------------------------------
# Cost-based join ordering (left-deep, smallest-relation-first with
# co-partition awareness).  PDE then re-plans every boundary at run time from
# observed map-output sizes — this pass only picks the *initial* shape.
# ---------------------------------------------------------------------------

def _broadcast_prior_bytes() -> float:
    """The default PDE broadcast threshold, as the static prior for 'this
    side is probably cheap to move'.  The runtime decision uses observed
    sizes against the session's actual PDEConfig; the ordering pass only
    needs the right order of magnitude."""
    from .pde import PDEConfig
    return PDEConfig().broadcast_threshold_bytes


def estimate_relation(node: Node, catalog) -> "RelEstimate":
    """Pre-execution (rows, bytes) estimate of a plan subtree, from catalog
    and piggybacked partition statistics (core/stats.py)."""
    from .stats import (RelEstimate, predicate_selectivity,
                        surviving_partition_fraction)
    if isinstance(node, ScanNode):
        t = catalog.get(node.table)
        return RelEstimate(float(t.num_rows), float(t.nbytes), t)
    if isinstance(node, FilterNode):
        base = estimate_relation(node.child, catalog)
        sel = predicate_selectivity(node.pred)
        if base.table is not None:
            # partition-stat refutation gives a hard upper bound on survivors
            sel = min(sel, surviving_partition_fraction(base.table, node.pred))
        return dataclasses.replace(base, rows=base.rows * sel,
                                   nbytes=base.nbytes * sel)
    if isinstance(node, ProjectNode):
        base = estimate_relation(node.child, catalog)
        return dataclasses.replace(base, table=None)
    if isinstance(node, LimitNode):
        base = estimate_relation(node.child, catalog)
        rows = min(base.rows, float(node.n))
        frac = rows / base.rows if base.rows > 0 else 1.0
        return dataclasses.replace(base, rows=rows, nbytes=base.nbytes * frac,
                                   table=None)
    if isinstance(node, AggregateNode):
        base = estimate_relation(node.child, catalog)
        rows = max(1.0, base.rows ** 0.5)  # grouping collapses cardinality
        return dataclasses.replace(base, rows=rows,
                                   nbytes=base.nbytes * rows / max(base.rows, 1.0),
                                   table=None)
    if isinstance(node, JoinNode):
        return _estimate_join(node, catalog)[0]
    if isinstance(node, SortNode):
        base = estimate_relation(node.child, catalog)
        return dataclasses.replace(base, table=None)
    # unknown node: sum children
    rows = nbytes = 0.0
    for ch in node.children():
        e = estimate_relation(ch, catalog)
        rows += e.rows
        nbytes += e.nbytes
    return RelEstimate(rows, nbytes)


def _join_key_ndv(est, key: str) -> float:
    """Distinct-value estimate of a join key within one relation."""
    from .stats import table_column_ndv
    if est.table is not None:
        ndv = table_column_ndv(est.table, key)
        if ndv is not None:
            return float(max(ndv, 1))
    return max(est.rows, 1.0)


def _estimate_join(node: "JoinNode", catalog):
    """(output RelEstimate, boundary cost in bytes moved) for one join."""
    from .stats import RelEstimate
    l = estimate_relation(node.left, catalog)
    r = estimate_relation(node.right, catalog)
    ndv = max(_join_key_ndv(l, node.left_key), _join_key_ndv(r, node.right_key))
    out_rows = max(1.0, l.rows * r.rows / ndv)
    out_bytes = out_rows * (l.bytes_per_row + r.bytes_per_row)
    cost = _boundary_cost(node, l, r)
    return RelEstimate(out_rows, out_bytes), cost


def _boundary_cost(node: "JoinNode", l, r) -> float:
    """Estimated bytes moved across this shuffle boundary under the runtime
    strategies PDE can pick: zip (co-partitioned) ≈ 0, broadcast = small
    side only, shuffle = both sides."""
    if (l.table is not None and r.table is not None
            and l.table.co_partitioned_with(r.table, node.left_key,
                                            node.right_key)):
        return 0.0
    small = min(l.nbytes, r.nbytes)
    if small <= _broadcast_prior_bytes():
        return small
    return l.nbytes + r.nbytes


def estimate_plan_cost(node: Node, catalog) -> float:
    """Total estimated bytes moved across all join boundaries of a plan —
    the objective the join-ordering pass minimizes (and what the property
    test compares across join orders)."""
    total = 0.0
    if isinstance(node, JoinNode):
        _, cost = _estimate_join(node, catalog)
        total += cost
    for ch in node.children():
        total += estimate_plan_cost(ch, catalog)
    return total


def _flatten_join_chain(node: Node):
    """Flatten a tree of inner AUTO joins into (relations, edges); each edge
    is (left_key, right_key) from one JoinNode.  Non-join subtrees (scans,
    filtered scans, aggregates, outer joins, forced strategies) stay opaque
    relations."""
    rels: List[Node] = []
    edges: List[Tuple[str, str]] = []

    def walk(n: Node):
        if (isinstance(n, JoinNode) and n.how == "inner"
                and n.strategy == JoinStrategy.AUTO):
            walk(n.left)
            walk(n.right)
            edges.append((n.left_key, n.right_key))
        else:
            rels.append(n)

    walk(node)
    return rels, edges


def order_joins(node: Node, catalog) -> Node:
    """Cost-based initial join ordering: rebuild chains of ≥3 inner-joined
    relations as a left-deep tree, greedily attaching the cheapest next
    relation (smallest estimated size; co-partitioned pairs first since
    they join shuffle-free, §3.4).

    Conservative by design: bails out (returning the tree unchanged) on
    outer joins, planner-forced strategies, ambiguous key ownership, or
    duplicate column names across relations — the runtime PDE still
    re-optimizes every boundary of an un-reordered plan."""
    if isinstance(node, JoinNode):
        reordered = _try_reorder(node, catalog)
        if reordered is not None:
            # _try_reorder already ordered each opaque relation's subtree;
            # recursing into the freshly built spine would only re-derive it
            return reordered
    for attr in ("child", "left", "right"):
        if hasattr(node, attr):
            setattr(node, attr, order_joins(getattr(node, attr), catalog))
    return node


def _try_reorder(root: "JoinNode", catalog) -> Optional[Node]:
    rels, edges = _flatten_join_chain(root)
    if len(rels) < 3 or len(edges) != len(rels) - 1:
        return None
    # order any nested join chains inside the opaque relations now — the
    # caller will not descend into a successfully rebuilt spine
    rels = [order_joins(r, catalog) for r in rels]
    # schemas + global column uniqueness (join output flattens columns with
    # positional _r suffixing — reordering under duplicates would rename)
    schemas: List[set] = []
    seen: set = set()
    for r in rels:
        try:
            names = set(r.schema(catalog).names)
        except Exception:
            return None
        if seen & names:
            return None
        seen |= names
        schemas.append(names)

    def owner(col: str) -> Optional[int]:
        hits = [i for i, s in enumerate(schemas) if col in s]
        return hits[0] if len(hits) == 1 else None

    adj: Dict[int, List[Tuple[int, str, str]]] = {i: [] for i in range(len(rels))}
    for lk, rk in edges:
        a, b = owner(lk), owner(rk)
        if a is None or b is None or a == b:
            return None
        adj[a].append((b, lk, rk))
        adj[b].append((a, rk, lk))

    ests = [estimate_relation(r, catalog) for r in rels]

    def attach_cost(tree_est, cand_est, tree_is_scan_pair=None) -> float:
        if tree_is_scan_pair is not None:
            lk, rk = tree_is_scan_pair
            if (tree_est.table is not None and cand_est.table is not None
                    and tree_est.table.co_partitioned_with(
                        cand_est.table, lk, rk)):
                return 0.0
        small = min(tree_est.nbytes, cand_est.nbytes)
        if small <= _broadcast_prior_bytes():
            return small
        return tree_est.nbytes + cand_est.nbytes

    # start: the connected pair with the cheapest first boundary, breaking
    # ties toward smaller combined size (smallest-relation-first)
    best = None
    for a in range(len(rels)):
        for b, lk, rk in adj[a]:
            if a >= b:
                continue
            cost = attach_cost(ests[a], ests[b], (lk, rk))
            key = (cost, ests[a].nbytes + ests[b].nbytes, a, b)
            if best is None or key < best[0]:
                best = (key, a, b, lk, rk)
    if best is None:
        return None
    _, a, b, lk, rk = best
    # the smaller relation leads (build side of the first boundary)
    if ests[b].nbytes < ests[a].nbytes:
        a, b, lk, rk = b, a, rk, lk

    placed = {a, b}
    tree: Node = JoinNode(rels[a], rels[b], lk, rk, "inner")
    tree_est, _ = _estimate_join(tree, catalog)
    while len(placed) < len(rels):
        cand = None
        for p in placed:
            for q, pk, qk in adj[p]:
                if q in placed:
                    continue
                cost = attach_cost(tree_est, ests[q])
                key = (cost, ests[q].nbytes, q)
                if cand is None or key < cand[0]:
                    cand = (key, q, pk, qk)
        if cand is None:
            return None  # disconnected (cross join): keep original order
        _, q, pk, qk = cand
        tree = JoinNode(tree, rels[q], pk, qk, "inner")
        tree_est, _ = _estimate_join(tree, catalog)
        placed.add(q)
    return tree


def required_columns(node: Node, catalog, want: Optional[set] = None) -> Dict[str, set]:
    """Column pruning analysis: per base table, which columns are needed.
    The physical scan only decodes these blocks (columnar advantage)."""
    out: Dict[str, set] = {}

    def walk(n: Node, needed: Optional[set]):
        if isinstance(n, ScanNode):
            schema = n.schema(catalog)
            cols = set(schema.names) if needed is None else (needed & set(schema.names))
            out.setdefault(n.table, set()).update(cols)
            return
        if isinstance(n, FilterNode):
            sub = None if needed is None else needed | set(n.pred.columns())
            walk(n.child, sub)
            return
        if isinstance(n, ProjectNode):
            sub: set = set()
            for name, e in n.exprs:
                if needed is None or name in needed:
                    sub.update(e.columns())
            walk(n.child, sub)
            return
        if isinstance(n, AggregateNode):
            sub = set(n.group_by)
            for a in n.aggs:
                if a.arg is not None:
                    sub.update(a.arg.columns())
            walk(n.child, sub)
            return
        if isinstance(n, JoinNode):
            lcols = set(_schema_names_safe(n.left, catalog))
            rcols = set(_schema_names_safe(n.right, catalog))
            need = needed
            lneed = None if need is None else ((need & lcols) | {n.left_key})
            rneed = None if need is None else ((need & rcols) | {n.right_key})
            walk(n.left, lneed)
            walk(n.right, rneed)
            return
        if isinstance(n, SortNode):
            sub = None if needed is None else needed | {k for k, _ in n.keys}
            walk(n.child, sub)
            return
        for ch in n.children():
            walk(ch, needed)

    walk(node, want)
    return out


def _schema_names_safe(node: Node, catalog) -> Tuple[str, ...]:
    try:
        return node.schema(catalog).names
    except Exception:
        return ()


# ---------------------------------------------------------------------------
# Pipeline segmentation (paper §2.4 narrow-chain pipelining + §5 compiled
# evaluators).  A *physical-layer* pass: the logical plan, explain() output
# and plan fingerprints are untouched — segmentation only describes how the
# executor will run a maximal scan→filter→project chain, namely as ONE
# compiled columnar function per partition instead of one interpreted
# operator at a time.  Filters and projections are folded into scan-column
# terms by substituting column references through intervening projections
# (the same rewrite predicate pushdown uses), so the segment is fully
# described by (scan, one conjunctive predicate, one output projection).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PipelineSegment:
    """One maximal narrow chain over a scan, in scan-column terms."""
    scan: ScanNode
    pred: Optional[Expr]                        # conjunction, or None
    exprs: Optional[List[Tuple[str, Expr]]]     # None = all scan columns
    depth: int = 0                              # logical operators folded

    def output_names(self, catalog) -> List[str]:
        if self.exprs is None:
            return list(self.scan.schema(catalog).names)
        return [n for n, _ in self.exprs]


def fold_pipeline(node: Node) -> Optional[PipelineSegment]:
    """Fold a scan→filter→project chain into a PipelineSegment, or None if
    `node` is not such a chain (joins, aggregates, sorts, limits and other
    blocking/wide operators terminate the chain)."""
    if isinstance(node, ScanNode):
        return PipelineSegment(node, None, None, 0)
    if isinstance(node, FilterNode):
        seg = fold_pipeline(node.child)
        if seg is None:
            return None
        pred = node.pred
        if seg.exprs is not None:
            mapping = {n: e for n, e in seg.exprs}
            if not all(c in mapping for c in pred.columns()):
                return None
            pred = _substitute(pred, mapping)
        merged = pred if seg.pred is None else And(seg.pred, pred)
        return dataclasses.replace(seg, pred=merged, depth=seg.depth + 1)
    if isinstance(node, ProjectNode):
        seg = fold_pipeline(node.child)
        if seg is None:
            return None
        if seg.exprs is None:
            exprs = list(node.exprs)
        else:
            mapping = {n: e for n, e in seg.exprs}
            if not all(c in mapping
                       for _, e in node.exprs for c in e.columns()):
                return None
            exprs = [(n, _substitute(e, mapping)) for n, e in node.exprs]
        return dataclasses.replace(seg, exprs=exprs, depth=seg.depth + 1)
    return None


# ---------------------------------------------------------------------------
# Whole-stage programs (DESIGN.md §14).  One step past PipelineSegment: the
# entire MAP STAGE of a blocking operator — the narrow segment chained into
# its consumer's map-side work (partial aggregation, per-partition top-k, or
# the pushed-down limit) and into the exchange's radix bucketing — described
# as one unit so the executor can run it as ONE traced program per partition
# with no host seam before the shuffle.  Still physical-layer only: the
# logical plan, explain() and plan fingerprints never see stage folding.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StageProgram:
    """One whole map stage: a PipelineSegment plus the blocking consumer
    whose map-side work fuses behind it."""
    segment: PipelineSegment
    consumer: str                               # aggregate | sort | limit
    group_cols: List[str] = dataclasses.field(default_factory=list)
    aggs: List["AggSpec"] = dataclasses.field(default_factory=list)
    sort_keys: List[Tuple[str, bool]] = dataclasses.field(
        default_factory=list)
    limit: Optional[int] = None


def fold_stage(node: Node) -> Optional[StageProgram]:
    """Fold a blocking operator over a narrow chain into a StageProgram, or
    None when the operator's input is not a foldable scan chain (joins and
    other wide inputs keep the segment-at-a-time path)."""
    if isinstance(node, AggregateNode):
        seg = fold_pipeline(node.child)
        if seg is None:
            return None
        return StageProgram(seg, "aggregate", list(node.group_by),
                            list(node.aggs))
    if isinstance(node, SortNode):
        seg = fold_pipeline(node.child)
        if seg is None:
            return None
        return StageProgram(seg, "sort", sort_keys=list(node.keys))
    if isinstance(node, LimitNode):
        if isinstance(node.child, SortNode):
            prog = fold_stage(node.child)
            if prog is None:
                return None
            return dataclasses.replace(prog, limit=node.n)
        seg = fold_pipeline(node.child)
        if seg is None:
            return None
        return StageProgram(seg, "limit", limit=node.n)
    return None


def explain(node: Node, indent: int = 0) -> str:
    pad = "  " * indent
    lines = [pad + repr(node)]
    for ch in node.children():
        lines.append(explain(ch, indent + 1))
    return "\n".join(lines)
