"""Logical query plans and rule-based optimization (paper §2.4).

Shark parses HiveQL into an AST, builds a logical plan, applies basic logical
optimization (predicate pushdown), then — unlike Hive, which emits MapReduce
stages — applies additional rule-based optimizations (e.g. pushing LIMIT down
to individual partitions) and emits a physical plan of RDD transformations.

We reproduce that pipeline: `optimize()` runs predicate pushdown, filter
merging, column pruning, and limit pushdown; `physical.compile_plan` then
turns the tree into an RDD lineage graph whose shuffle boundaries are the PDE
re-optimization points.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple

from .expr import (And, Col, Expr, Func, Lit, conjoin, infer_dtype,
                   split_conjuncts)
from .types import DType, Field, Schema


class AggFunc(enum.Enum):
    SUM = "sum"
    COUNT = "count"
    AVG = "avg"
    MIN = "min"
    MAX = "max"
    COUNT_DISTINCT = "count_distinct"


@dataclasses.dataclass(eq=False)
class AggSpec:
    out_name: str
    func: AggFunc
    arg: Optional[Expr]  # None for COUNT(*)

    def __repr__(self):
        a = "*" if self.arg is None else repr(self.arg)
        return f"{self.func.value}({a}) AS {self.out_name}"


class Node:
    def children(self) -> Sequence["Node"]:
        return ()

    def schema(self, catalog) -> Schema:
        raise NotImplementedError


@dataclasses.dataclass(eq=False)
class ScanNode(Node):
    table: str

    def schema(self, catalog) -> Schema:
        return catalog.get(self.table).schema

    def __repr__(self): return f"Scan({self.table})"


@dataclasses.dataclass(eq=False)
class FilterNode(Node):
    child: Node
    pred: Expr

    def children(self): return (self.child,)
    def schema(self, catalog): return self.child.schema(catalog)
    def __repr__(self): return f"Filter({self.pred})"


@dataclasses.dataclass(eq=False)
class ProjectNode(Node):
    child: Node
    exprs: List[Tuple[str, Expr]]  # (output name, expression)

    def children(self): return (self.child,)

    def schema(self, catalog) -> Schema:
        base = self.child.schema(catalog)
        return Schema(tuple(Field(n, infer_dtype(e, base)) for n, e in self.exprs))

    def __repr__(self):
        return "Project(" + ", ".join(f"{e} AS {n}" for n, e in self.exprs) + ")"


@dataclasses.dataclass(eq=False)
class AggregateNode(Node):
    child: Node
    group_by: List[str]          # column names (pre-projected if exprs)
    aggs: List[AggSpec]

    def children(self): return (self.child,)

    def schema(self, catalog) -> Schema:
        base = self.child.schema(catalog)
        fields = [base.field(g) for g in self.group_by]
        for a in self.aggs:
            if a.func == AggFunc.COUNT or a.func == AggFunc.COUNT_DISTINCT:
                dt = DType.INT64
            elif a.func == AggFunc.AVG:
                dt = DType.FLOAT64
            elif a.arg is not None:
                dt = infer_dtype(a.arg, base)
                if a.func == AggFunc.SUM and dt in (DType.INT32,):
                    dt = DType.INT64
            else:
                dt = DType.INT64
            fields.append(Field(a.out_name, dt))
        return Schema(tuple(fields))

    def __repr__(self):
        return f"Aggregate(by={self.group_by}, aggs={self.aggs})"


class JoinStrategy(enum.Enum):
    AUTO = "auto"            # decided at run time by PDE (§3.1.1)
    SHUFFLE = "shuffle"
    BROADCAST = "broadcast"  # map join
    COPARTITION = "copartition"


@dataclasses.dataclass(eq=False)
class JoinNode(Node):
    left: Node
    right: Node
    left_key: str
    right_key: str
    how: str = "inner"
    strategy: JoinStrategy = JoinStrategy.AUTO

    def children(self): return (self.left, self.right)

    def schema(self, catalog) -> Schema:
        return self.left.schema(catalog).concat(self.right.schema(catalog))

    def __repr__(self):
        return (f"Join({self.left_key}={self.right_key}, {self.how}, "
                f"{self.strategy.value})")


@dataclasses.dataclass(eq=False)
class SortNode(Node):
    child: Node
    keys: List[Tuple[str, bool]]  # (column, descending)

    def children(self): return (self.child,)
    def schema(self, catalog): return self.child.schema(catalog)
    def __repr__(self): return f"Sort({self.keys})"


@dataclasses.dataclass(eq=False)
class LimitNode(Node):
    child: Node
    n: int
    # set by the optimizer: per-partition pre-limit pushed below the collect
    pushed: bool = False

    def children(self): return (self.child,)
    def schema(self, catalog): return self.child.schema(catalog)
    def __repr__(self): return f"Limit({self.n}, pushed={self.pushed})"


# ---------------------------------------------------------------------------
# Rule-based optimizer
# ---------------------------------------------------------------------------


def optimize(node: Node, catalog) -> Node:
    node = push_down_filters(node)
    node = merge_filters(node)
    node = push_down_limits(node)
    return node


def _substitute(e: Expr, mapping: Dict[str, Expr]) -> Expr:
    """Rewrite column refs through a projection (for pushdown)."""
    from .expr import rewrite_expr
    return rewrite_expr(
        e, lambda n: mapping.get(n.name, n) if isinstance(n, Col) else None)


def push_down_filters(node: Node) -> Node:
    """Predicate pushdown: move filters below projects and into join sides."""
    if isinstance(node, FilterNode):
        child = node.child
        if isinstance(child, ProjectNode):
            mapping = {n: e for n, e in child.exprs}
            # only push if every referenced output column maps to a pure expr
            if all(c in mapping for c in node.pred.columns()):
                new_pred = _substitute(node.pred, mapping)
                return push_down_filters(
                    ProjectNode(FilterNode(child.child, new_pred), child.exprs))
        if isinstance(child, FilterNode):
            merged = FilterNode(child.child, And(child.pred, node.pred))
            return push_down_filters(merged)
        if isinstance(child, JoinNode):
            l_schema_cols = set(_available_columns(child.left))
            r_schema_cols = set(_available_columns(child.right))
            keep, left_preds, right_preds = [], [], []
            for c in split_conjuncts(node.pred):
                cols = set(c.columns())
                if cols <= l_schema_cols:
                    left_preds.append(c)
                elif cols <= r_schema_cols:
                    right_preds.append(c)
                else:
                    keep.append(c)
            new_left = child.left
            new_right = child.right
            if left_preds:
                new_left = FilterNode(new_left, conjoin(left_preds))
            if right_preds:
                new_right = FilterNode(new_right, conjoin(right_preds))
            new_join = JoinNode(push_down_filters(new_left),
                                push_down_filters(new_right),
                                child.left_key, child.right_key, child.how,
                                child.strategy)
            if keep:
                return FilterNode(new_join, conjoin(keep))
            return new_join
        return FilterNode(push_down_filters(child), node.pred)
    # generic recursion
    for attr in ("child", "left", "right"):
        if hasattr(node, attr):
            setattr(node, attr, push_down_filters(getattr(node, attr)))
    return node


def _available_columns(node: Node) -> List[str]:
    if isinstance(node, ScanNode):
        return ["*"]  # unknown without catalog; resolved later
    if isinstance(node, ProjectNode):
        return [n for n, _ in node.exprs]
    if isinstance(node, AggregateNode):
        return node.group_by + [a.out_name for a in node.aggs]
    cols: List[str] = []
    for ch in node.children():
        cols.extend(_available_columns(ch))
    return cols


def merge_filters(node: Node) -> Node:
    if isinstance(node, FilterNode) and isinstance(node.child, FilterNode):
        return merge_filters(
            FilterNode(node.child.child, And(node.child.pred, node.pred)))
    for attr in ("child", "left", "right"):
        if hasattr(node, attr):
            setattr(node, attr, merge_filters(getattr(node, attr)))
    return node


def push_down_limits(node: Node) -> Node:
    """Paper §2.4: push LIMIT down to individual partitions.  Each partition
    task emits at most n rows; the collect stage applies the final limit."""
    if isinstance(node, LimitNode):
        child = node.child
        if isinstance(child, (ScanNode, FilterNode, ProjectNode)):
            node.pushed = True
    for attr in ("child", "left", "right"):
        if hasattr(node, attr):
            setattr(node, attr, push_down_limits(getattr(node, attr)))
    return node


def required_columns(node: Node, catalog, want: Optional[set] = None) -> Dict[str, set]:
    """Column pruning analysis: per base table, which columns are needed.
    The physical scan only decodes these blocks (columnar advantage)."""
    out: Dict[str, set] = {}

    def walk(n: Node, needed: Optional[set]):
        if isinstance(n, ScanNode):
            schema = n.schema(catalog)
            cols = set(schema.names) if needed is None else (needed & set(schema.names))
            out.setdefault(n.table, set()).update(cols)
            return
        if isinstance(n, FilterNode):
            sub = None if needed is None else needed | set(n.pred.columns())
            walk(n.child, sub)
            return
        if isinstance(n, ProjectNode):
            sub: set = set()
            for name, e in n.exprs:
                if needed is None or name in needed:
                    sub.update(e.columns())
            walk(n.child, sub)
            return
        if isinstance(n, AggregateNode):
            sub = set(n.group_by)
            for a in n.aggs:
                if a.arg is not None:
                    sub.update(a.arg.columns())
            walk(n.child, sub)
            return
        if isinstance(n, JoinNode):
            lcols = set(_schema_names_safe(n.left, catalog))
            rcols = set(_schema_names_safe(n.right, catalog))
            need = needed
            lneed = None if need is None else ((need & lcols) | {n.left_key})
            rneed = None if need is None else ((need & rcols) | {n.right_key})
            walk(n.left, lneed)
            walk(n.right, rneed)
            return
        if isinstance(n, SortNode):
            sub = None if needed is None else needed | {k for k, _ in n.keys}
            walk(n.child, sub)
            return
        for ch in n.children():
            walk(ch, needed)

    walk(node, want)
    return out


def _schema_names_safe(node: Node, catalog) -> Tuple[str, ...]:
    try:
        return node.schema(catalog).names
    except Exception:
        return ()


def explain(node: Node, indent: int = 0) -> str:
    pad = "  " * indent
    lines = [pad + repr(node)]
    for ch in node.children():
        lines.append(explain(ch, indent + 1))
    return "\n".join(lines)
