"""Shark core: columnar SQL engine with RDD lineage fault tolerance and
Partial DAG Execution, reproduced in JAX (see DESIGN.md)."""

from .types import DType, Field, Schema
from .columnar import Table, from_arrays
from .expr import (And, Between, BinOp, Cmp, Col, Expr, Func, InList, Lit,
                   Not, Or)
from .plan import (AggFunc, AggregateNode, AggSpec, FilterNode, JoinNode,
                   JoinStrategy, LimitNode, ProjectNode, ScanNode, SortNode)
from .session import SharkSession
from .runtime import SharkContext

__all__ = [
    "DType", "Field", "Schema", "Table", "from_arrays",
    "And", "Between", "BinOp", "Cmp", "Col", "Expr", "Func", "InList", "Lit",
    "Not", "Or",
    "AggFunc", "AggregateNode", "AggSpec", "FilterNode", "JoinNode",
    "JoinStrategy", "LimitNode", "ProjectNode", "ScanNode", "SortNode",
    "SharkSession", "SharkContext",
]
