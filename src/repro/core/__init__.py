"""Shark core: columnar SQL engine with RDD lineage fault tolerance and
Partial DAG Execution, reproduced in JAX (see DESIGN.md)."""

from .types import DType, Field, Schema
from .columnar import Table, from_arrays
from .expr import (Aliased, And, Between, BinOp, Cmp, Col, Expr, Func,
                   InList, Lit, Not, Or)
from .plan import (AggFunc, AggregateNode, AggSpec, FilterNode, JoinNode,
                   JoinStrategy, LimitNode, ProjectNode, ScanNode, SortNode)
from .frame import FrameBindError, GroupedFrame, SharkFrame
from .functions import (abs_, avg, ceil, col, count, count_distinct, exp,
                        floor, length, lit, log, lower, max_, min_, sqrt,
                        substr, sum_, upper, year)
from .session import SharkSession
from .runtime import SharkContext
from .resilience import (CircuitBreaker, ResiliencePolicy,
                         ShuffleWaitTimeout, WorkerHealth)
from .faults import ChaosEngine, FaultSchedule, FaultSpec, FaultTrip

__all__ = [
    "DType", "Field", "Schema", "Table", "from_arrays",
    "Aliased", "And", "Between", "BinOp", "Cmp", "Col", "Expr", "Func",
    "InList", "Lit", "Not", "Or",
    "AggFunc", "AggregateNode", "AggSpec", "FilterNode", "JoinNode",
    "JoinStrategy", "LimitNode", "ProjectNode", "ScanNode", "SortNode",
    "SharkFrame", "GroupedFrame", "FrameBindError",
    "col", "lit", "sum_", "avg", "min_", "max_", "count", "count_distinct",
    "substr", "lower", "upper", "length", "abs_", "sqrt", "log", "exp",
    "floor", "ceil", "year",
    "SharkSession", "SharkContext",
    "ResiliencePolicy", "ShuffleWaitTimeout", "WorkerHealth",
    "CircuitBreaker",
    "ChaosEngine", "FaultSchedule", "FaultSpec", "FaultTrip",
]
