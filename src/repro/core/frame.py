"""SharkFrame — the lazy, composable query surface (DESIGN.md §7).

The paper's headline claim (§4.1) is that SQL and iterative ML share one
engine, one lineage graph, and one memory store.  SharkFrame makes that
composition first-class: a frame is an immutable handle on a logical `Node`
tree — the *same* trees the SQL binder emits — built fluently:

    top = (sess.table("rankings")
               .filter(col("pageRank") > 100)
               .join(sess.table("uservisits"), on=("pageURL", "destURL"))
               .group_by(col("destURL"))
               .agg(sum_(col("adRevenue")).alias("rev"))
               .order_by("rev", desc=True)
               .limit(10))
    top.to_numpy()

Because both surfaces share `bind_aggregate` (core/sql.py) and the same
rule-based `optimize()`, a frame query and its SQL-text twin optimize to
byte-identical plans: one `plan_fingerprint`, one server result-cache
entry, the same PDE re-optimization points.  Terminal actions:

    .collect()    -> ExecResult (admission-controlled + fair-scheduled when
                     the session is attached to a SharkServer: the bound
                     plan itself is submitted, not query text)
    .to_numpy()   -> dict of column arrays
    .to_rdd()     -> the plan as a lazy TableRDD (Listing 1's escape hatch;
                     shuffle outputs are registered with the session for
                     release via release_shuffles())
    .to_features()-> dense feature-matrix RDD for ml/ (one lineage graph)
    .cache(name)  -> materialize + register as a table (CTAS equivalent)
    .explain()    -> optimized-plan string

Every constructor validates eagerly against the catalog schema and raises
`FrameBindError` naming the frame operation and the offending column —
never a raw binder KeyError.
"""

from __future__ import annotations

import copy
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .expr import Aliased, BinOp, Col, Expr, Lit, rewrite_expr
from .plan import (AggFunc, AggregateNode, FilterNode, JoinNode, LimitNode,
                   Node, ProjectNode, ScanNode, SortNode,
                   explain as explain_plan, optimize)
from .sql import _AggExpr, _auto_name, _contains_agg, bind_aggregate
from .types import Schema

__all__ = ["SharkFrame", "GroupedFrame", "FrameBindError"]


class FrameBindError(ValueError):
    """A frame operation referenced a column or table that does not exist
    (raised eagerly, at construction — not at execution)."""


def _unalias(item) -> Tuple[Optional[str], Expr]:
    """(alias-or-None, expr) from an Expr, Aliased, or bare column name."""
    if isinstance(item, Aliased):
        return item.name, item.expr
    if isinstance(item, str):
        return None, Col(item)
    if isinstance(item, Expr):
        return None, item
    raise TypeError(f"expected a column name, Expr, or .alias()ed Expr; "
                    f"got {type(item).__name__}")


class SharkFrame:
    """Immutable lazy relational query; every operator returns a new frame
    over an extended logical plan.  See the module docstring."""

    def __init__(self, session, node: Node, result=None):
        self._session = session
        self._node = node
        self._result = result          # memoized ExecResult
        self._schema: Optional[Schema] = None

    # -- construction -------------------------------------------------------

    @classmethod
    def table(cls, session, name: str) -> "SharkFrame":
        if not session.catalog.exists(name):
            known = sorted(session.catalog.tables())
            raise FrameBindError(
                f"SharkSession.table(): unknown table {name!r}"
                + (f"; known tables: {', '.join(known)}" if known else ""))
        return cls(session, ScanNode(name))

    def _derive(self, node: Node) -> "SharkFrame":
        return SharkFrame(self._session, node)

    # -- schema -------------------------------------------------------------

    @property
    def schema(self) -> Schema:
        if self._schema is None:
            self._schema = self._node.schema(self._session.catalog)
        return self._schema

    @property
    def columns(self) -> List[str]:
        return list(self.schema.names)

    def _check_columns(self, cols: Sequence[str], op: str) -> None:
        avail = self.columns
        for c in cols:
            if c not in avail:
                raise FrameBindError(
                    f"SharkFrame.{op}(): unknown column {c!r}; "
                    f"available columns: {', '.join(avail)}")

    # -- relational operators -----------------------------------------------

    def filter(self, pred: Expr) -> "SharkFrame":
        if not isinstance(pred, Expr):
            raise TypeError("SharkFrame.filter() takes an Expr predicate, "
                            "e.g. col('pageRank') > 100")
        if _contains_agg(pred):
            raise FrameBindError(
                "SharkFrame.filter(): predicate contains an aggregate — "
                "filter aggregated output with .having() after .agg()")
        self._check_columns(pred.columns(), "filter")
        return self._derive(FilterNode(self._node, pred))

    where = filter

    def select(self, *items) -> "SharkFrame":
        if not items:
            raise ValueError("SharkFrame.select() needs at least one column")
        pairs = [_unalias(i) for i in items]
        for _, e in pairs:
            self._check_columns(e.columns(), "select")
        if any(_contains_agg(e) for _, e in pairs):
            for _, e in pairs:
                if _contains_agg(e) and not isinstance(e, _AggExpr):
                    raise FrameBindError(
                        f"SharkFrame.select(): aggregate calls must be "
                        f"top-level, not nested inside {e!r}; aggregate "
                        f"first (e.g. .agg(sum_(col('x')).alias('s'))), "
                        f"then compute over the output")
            # global aggregate: SELECT COUNT(*), SUM(x) FROM ...
            return self._bind_agg(pairs, group_items=[], op="select")
        exprs = [(alias or _auto_name(e), e) for alias, e in pairs]
        return self._derive(ProjectNode(self._node, exprs))

    def join(self, other: Union["SharkFrame", str], on,
             how: str = "inner") -> "SharkFrame":
        """Equi-join with another frame (or table name).  Chained
        `.join().join()` calls build the same left-deep JoinNode trees the
        SQL binder emits for `FROM a JOIN b ON ... JOIN c ON ...`, so an
        N-way frame query and its SQL twin optimize — including the
        cost-based join-ordering pass — to byte-identical plans: one
        `plan_fingerprint`, one result-cache entry, and the same PDE
        re-optimization points at every join boundary."""
        if isinstance(other, str):
            other = SharkFrame.table(self._session, other)
        if other._session.catalog is not self._session.catalog:
            raise FrameBindError("SharkFrame.join(): frames belong to "
                                 "different catalogs")
        if how not in ("inner", "left"):
            raise FrameBindError(f"SharkFrame.join(): unsupported how={how!r} "
                                 "(inner or left)")
        lk, rk = self._join_keys(other, on)
        self._check_columns([lk], "join")
        other._check_columns([rk], "join")
        return self._derive(JoinNode(self._node, other._node, lk, rk, how))

    def _join_keys(self, other: "SharkFrame", on) -> Tuple[str, str]:
        from .expr import Cmp
        if isinstance(on, str):
            return on, on
        if isinstance(on, Col):
            return on.name, on.name
        if isinstance(on, (tuple, list)) and len(on) == 2:
            l, r = on
            lk = l.name if isinstance(l, Col) else l
            rk = r.name if isinstance(r, Col) else r
            return lk, rk
        if isinstance(on, Cmp) and on.op == "=" and \
                isinstance(on.left, Col) and isinstance(on.right, Col):
            lk, rk = on.left.name, on.right.name
            if lk not in self.columns and rk in self.columns:
                lk, rk = rk, lk  # user wrote the sides swapped
            return lk, rk
        raise FrameBindError(
            "SharkFrame.join(): `on` must be a column name, a "
            "(left_key, right_key) pair, or an equality like "
            "col('pageURL') == col('destURL')")

    def group_by(self, *keys) -> "GroupedFrame":
        if not keys:
            raise ValueError("SharkFrame.group_by() needs at least one key")
        pairs = [_unalias(k) for k in keys]
        for _, e in pairs:
            if _contains_agg(e):
                raise FrameBindError("SharkFrame.group_by(): cannot group by "
                                     "an aggregate")
            self._check_columns(e.columns(), "group_by")
        return GroupedFrame(self, pairs)

    def agg(self, *aggs) -> "SharkFrame":
        """Global aggregation (no grouping): frame.agg(count().alias('n'))."""
        return GroupedFrame(self, []).agg(*aggs)

    def having(self, pred: Expr) -> "SharkFrame":
        agg = self._agg_output()
        if agg is None:
            raise FrameBindError(
                "SharkFrame.having(): no preceding aggregation — call "
                ".group_by(...).agg(...) first (or use .filter())")
        pred = self._resolve_having_aggs(pred, agg)
        self._check_columns(pred.columns(), "having")
        return self._derive(FilterNode(self._node, pred))

    def _agg_output(self) -> Optional[AggregateNode]:
        """The AggregateNode whose output this frame exposes (through any
        stack of post-project / filter / sort / limit), else None.  Computed
        from the plan itself so SQL-built frames (`sess.sql(...)`) support
        `.having()` exactly like fluent ones."""
        node = self._node
        while isinstance(node, (ProjectNode, FilterNode, SortNode,
                                LimitNode)):
            if isinstance(node, ProjectNode) and not all(
                    isinstance(e, Col) for _, e in node.exprs):
                return None  # computed projection: agg outputs not addressable
            node = node.child
        return node if isinstance(node, AggregateNode) else None

    def _resolve_having_aggs(self, pred: Expr, agg: AggregateNode) -> Expr:
        """Rewrite aggregate calls in a having predicate to the output
        column of the matching AggSpec (mirroring SQL HAVING's resolution),
        so `.having(count() > 5)` works like `HAVING COUNT(*) > 5`."""
        out_name: Dict[Tuple, str] = {}
        for spec in agg.aggs:
            if spec.func == AggFunc.COUNT_DISTINCT:
                key = (AggFunc.COUNT, repr(spec.arg), True)
            else:
                key = (spec.func, repr(spec.arg), False)
            out_name.setdefault(key, spec.out_name)
        visible = set(self.columns)

        def resolve(e):
            if isinstance(e, _AggExpr):
                name = out_name.get((e.func, repr(e.arg), e.distinct))
                if name is None or name not in visible:
                    raise FrameBindError(
                        f"SharkFrame.having(): aggregate {e!r} is not in "
                        f"this frame's .agg() output; available columns: "
                        f"{', '.join(self.columns)}")
                return Col(name)
            return None

        return rewrite_expr(pred, resolve)

    def order_by(self, *keys, desc: bool = False) -> "SharkFrame":
        out: List[Tuple[str, bool]] = []
        for k in keys:
            if isinstance(k, tuple):
                name, d = k
                name = name.name if isinstance(name, Col) else name
                out.append((name, bool(d)))
            elif isinstance(k, Col):
                out.append((k.name, desc))
            else:
                out.append((k, desc))
        self._check_columns([n for n, _ in out], "order_by")
        return self._derive(SortNode(self._node, out))

    def limit(self, n: int) -> "SharkFrame":
        return self._derive(LimitNode(self._node, int(n)))

    def similarity_join(self, embedding: str, query, k: int,
                        score_col: str = "score") -> "SharkFrame":
        """Top-k dot-product similarity search against an embedding's lane
        columns (DESIGN.md §15.3): every surviving row gets
        `score = sum(lane_i * query_i)` and the k highest-scoring rows win
        (ties by physical row order, both execution paths).

        Lowers to ordinary relational nodes —
        Limit(k, Sort(score desc, Project(*, score))) — which is exactly
        the plan of the SQL twin `SELECT *, f_0*q_0 + f_1*q_1 + ... AS
        score FROM ... ORDER BY score DESC LIMIT k`, so filters written
        before the call push below the score projection and prune
        partitions as usual, and the physical layer may route eligible
        partitions to the Pallas `topk_similarity` kernel
        (`physical._match_topk`).  `embedding` resolves through the
        catalog's `Table.embeddings` lane mapping, or by `{embedding}_{i}`
        prefix over this frame's columns."""
        q = np.asarray(query, dtype=np.float64).ravel()
        lanes = self._embedding_lanes(embedding)
        if not lanes:
            raise FrameBindError(
                f"SharkFrame.similarity_join(): no embedding {embedding!r} "
                f"— expected catalog lane metadata or consecutive "
                f"'{embedding}_0', '{embedding}_1', ... columns; available "
                f"columns: {', '.join(self.columns)}")
        if len(q) != len(lanes):
            raise FrameBindError(
                f"SharkFrame.similarity_join(): query vector has {len(q)} "
                f"components but embedding {embedding!r} has {len(lanes)} "
                f"lanes ({lanes[0]}..{lanes[-1]})")
        if score_col in self.columns:
            raise FrameBindError(
                f"SharkFrame.similarity_join(): score column {score_col!r} "
                f"already exists; pass score_col= to rename")
        expr: Optional[Expr] = None
        for lane, w in zip(lanes, q.tolist()):
            term = BinOp("*", Col(lane), Lit(float(w)))
            expr = term if expr is None else BinOp("+", expr, term)
        proj = ProjectNode(self._node,
                           [(c, Col(c)) for c in self.columns]
                           + [(score_col, expr)])
        return self._derive(
            LimitNode(SortNode(proj, [(score_col, True)]), int(k)))

    def _embedding_lanes(self, embedding: str) -> List[str]:
        """Lane columns for `embedding`, in lane order: the source table's
        `embeddings` metadata when the lanes survive to this frame's
        output, else consecutive `{embedding}_{i}` name matching."""
        cols = set(self.columns)
        node = self._node
        while True:
            if isinstance(node, ScanNode):
                table = self._session.catalog.get(node.table)
                lanes = table.embeddings.get(embedding)
                if lanes and all(l in cols for l in lanes):
                    return list(lanes)
                break
            kids = node.children()
            if len(kids) != 1:
                break               # joins/unions: fall back to names
            node = kids[0]
        lanes = []
        while f"{embedding}_{len(lanes)}" in cols:
            lanes.append(f"{embedding}_{len(lanes)}")
        return lanes

    def _bind_agg(self, select_items, group_items, op: str) -> "SharkFrame":
        sess = self._session
        try:
            node = bind_aggregate(sess.catalog, self._node, select_items,
                                  [e for _, e in group_items])
        except ValueError as err:
            raise FrameBindError(f"SharkFrame.{op}(): {err}") from None
        return self._derive(node)

    # -- planning -----------------------------------------------------------

    def logical_plan(self) -> Node:
        """The bound (un-optimized) plan.  The tree is shared with this
        frame: optimize a deep copy, never the original."""
        return self._node

    def optimized_plan(self) -> Node:
        # optimize() rewrites in place; frames are immutable and may share
        # subtrees, so it must run on a private copy
        return optimize(copy.deepcopy(self._node), self._session.catalog)

    def explain(self) -> str:
        return explain_plan(self.optimized_plan())

    # -- terminal actions ---------------------------------------------------

    def collect(self):
        """Execute (once; memoized) and return the ExecResult.  Attached
        sessions submit the bound plan to the server — the query is admission
        controlled, fair-scheduled, and served from / filling the
        plan-fingerprint result cache exactly like its SQL-text twin."""
        if self._result is None:
            sess = self._session
            if sess.server is not None:
                self._result = sess.server.submit(
                    self._node, client=sess.client_id).result()
            else:
                self._result = sess.executor.execute(
                    copy.deepcopy(self._node))
        return self._result

    def to_numpy(self) -> Dict[str, np.ndarray]:
        return self.collect().to_numpy()

    def count(self) -> int:
        return int(self.collect().num_rows)

    def to_rdd(self):
        """Compile to an RDD whose final narrow stage is left lazy, so
        downstream ML extends the same lineage graph (paper §4.1).  Upstream
        shuffle map outputs are recorded on the session's executor and are
        freed by `session.release_shuffles()` / `session.shutdown()` — a
        server-attached session cannot silently leak shared-store memory."""
        sess = self._session
        node = optimize(copy.deepcopy(self._node), sess.catalog)
        compiled = sess.executor._compile(node)
        return compiled.rdd

    def to_features(self, feature_cols: Sequence[str],
                    label_col: Optional[str] = None,
                    map_rows=None, dtype=None):
        """Encoded-feature RDD for ml/ (Listing 1's mapRows step), extending
        this frame's lineage graph with one narrow map; partitions stay
        encoded column blocks until the jitted train step decodes them
        in-trace (DESIGN.md §15.1).  `dtype` sets the feature compute
        dtype (float32 default; labels always keep their source dtype)."""
        self._check_columns(list(feature_cols)
                            + ([label_col] if label_col else []),
                            "to_features")
        import numpy as _np
        from ..ml.featurize import table_rdd_to_features
        return table_rdd_to_features(self.to_rdd(), feature_cols, label_col,
                                     map_rows,
                                     dtype=(_np.float32 if dtype is None
                                            else dtype))

    def cache(self, name: str, num_partitions: Optional[int] = None,
              distribute_by: Optional[str] = None) -> "SharkFrame":
        """Materialize and register the result as table `name` (the fluent
        CREATE TABLE ... AS equivalent).  The catalog registration bumps the
        table's epoch, invalidating dependent server result-cache entries.
        Returns a frame scanning the new table."""
        if distribute_by is not None and distribute_by not in self.columns:
            raise FrameBindError(
                f"SharkFrame.cache(): distribute_by column "
                f"{distribute_by!r} not in output; available columns: "
                f"{', '.join(self.columns)}")
        from .session import register_result_as_table
        sess = self._session
        register_result_as_table(
            sess.catalog, name, self.collect(),
            num_partitions or sess.default_partitions, distribute_by)
        return SharkFrame.table(sess, name)

    # -- ExecResult back-compat shim ----------------------------------------
    # sess.sql() historically returned an ExecResult; frames expose the same
    # surface (executing on first access) so existing call sites keep working.

    @property
    def batches(self):
        return self.collect().batches

    @property
    def schema_names(self) -> List[str]:
        return self.columns

    @property
    def num_rows(self) -> int:
        return self.collect().num_rows

    def __repr__(self):
        plan = explain_plan(self._node).replace("\n", " <- ")
        return f"SharkFrame[{', '.join(self.columns)}]({plan})"


class GroupedFrame:
    """Intermediate of `SharkFrame.group_by()`: holds the grouping keys and
    waits for `.agg(...)` to complete the aggregation."""

    def __init__(self, parent: SharkFrame,
                 group_items: List[Tuple[Optional[str], Expr]]):
        self._parent = parent
        self._group_items = group_items

    def agg(self, *aggs) -> SharkFrame:
        if not aggs:
            raise ValueError("GroupedFrame.agg() needs at least one "
                             "aggregate, e.g. sum_(col('x')).alias('s')")
        pairs = [_unalias(a) for a in aggs]
        for _, e in pairs:
            if not isinstance(e, _AggExpr):
                raise FrameBindError(
                    f"GroupedFrame.agg(): {e!r} is not an aggregate; use "
                    "sum_/avg/min_/max_/count/count_distinct from "
                    "repro.core.functions")
            self._parent._check_columns(e.columns(), "agg")
        # output order matches SQL: group keys first, then aggregates
        select_items = list(self._group_items) + pairs
        return self._parent._bind_agg(select_items, self._group_items,
                                      op="agg")
