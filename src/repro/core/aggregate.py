"""Hash aggregation (paper §6.2.2, §6.3.1).

Like Shark (and Hive), aggregations run in two phases: task-local partial
aggregation on each partition, then a shuffle of the partial states by group
key and a final merge on the reduce side.  Spark's hash-based distributed
aggregation (no sort before shuffle, §7.1) is reproduced: grouping is
hash/unique-based, never a global sort.

Integer aggregates stay integer end to end: SUM/MIN/MAX over integer
columns accumulate in int64 (value-exact above 2^53, where a float64
round-trip silently loses precision); float aggregates accumulate in
float64.  String group keys are dictionary codes throughout — with the
dictionary-preserving exchange (DESIGN.md §11) the reduce side groups on
codes into the unified dictionary and never materializes strings.

`partial_aggregate` / `merge_aggregate` are the interpreted (numpy) oracle.
`CompiledMerge` lowers the reduce-side merge into ONE jitted segmented-
reduce program over all aggregate states (cached per state signature,
power-of-two padded so re-traces stay bounded), mirroring what
expr.compile_expr does for scan-side expressions; the reduce router
(physical.ReduceRunner) picks between them per reduce task.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .batch import PartitionBatch
from .expr import (ColumnVal, Evaluator, ExprCompileError, evaluate,
                   next_pow2 as _next_pow2)
from .plan import AggFunc, AggSpec


def group_indices(keys: Sequence[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """Group rows by composite key.  Returns (representative row indices of
    each group, inverse mapping row -> group id).  Hash-based (np.unique),
    not sort-order dependent."""
    n = len(keys[0]) if keys else 0
    if not keys:
        return np.zeros(1, np.int64), np.zeros(n, np.int64)
    if len(keys) == 1:
        _, first, inverse = np.unique(keys[0], return_index=True,
                                      return_inverse=True)
        return first, inverse
    # composite: unique over a void view of stacked columns
    cols = [np.asarray(k) for k in keys]
    rec = np.empty(n, dtype=[(f"k{i}", c.dtype) for i, c in enumerate(cols)])
    for i, c in enumerate(cols):
        rec[f"k{i}"] = c
    _, first, inverse = np.unique(rec, return_index=True, return_inverse=True)
    return first, inverse


# -- integer-exact segmented reductions (the numpy oracle) -------------------

INT64_MIN_IDENT = np.iinfo(np.int64).max   # MIN identity for empty groups
INT64_MAX_IDENT = np.iinfo(np.int64).min


def seg_sum(inverse: np.ndarray, val: np.ndarray,
            num_groups: int) -> np.ndarray:
    """Per-group sum; int64 accumulation for integer inputs (bincount's
    float64 weights would round above 2^53), float64 otherwise."""
    if np.issubdtype(val.dtype, np.integer) or val.dtype.kind == "b":
        acc = np.zeros(num_groups, np.int64)
        np.add.at(acc, inverse, val.astype(np.int64))
        return acc
    return np.bincount(inverse, weights=val.astype(np.float64),
                       minlength=num_groups)


def seg_minmax(inverse: np.ndarray, val: np.ndarray, num_groups: int,
               is_min: bool) -> np.ndarray:
    """Per-group min/max with dtype-preserving accumulators: int64 with
    iinfo sentinels for integer inputs, float64 with ±inf otherwise."""
    if np.issubdtype(val.dtype, np.integer):
        fill = INT64_MIN_IDENT if is_min else INT64_MAX_IDENT
        acc = np.full(num_groups, fill, np.int64)
        v = val.astype(np.int64)
    else:
        acc = np.full(num_groups, np.inf if is_min else -np.inf, np.float64)
        v = val.astype(np.float64)
    (np.minimum if is_min else np.maximum).at(acc, inverse, v)
    return acc


def combine_colscan_stats(stats: Sequence[Sequence[float]]
                          ) -> Tuple[float, float, float, float]:
    """Combine per-chunk colscan [count, sum, min, max] states into one.

    Count/min/max combine exactly; the sum stays in the same float64
    rounding class as a single-pass accumulation (DESIGN.md §14: the
    double-buffered chunked colscan must be a semantic no-op)."""
    cnt = 0.0
    s = np.float64(0.0)
    mn = np.inf
    mx = -np.inf
    for st in stats:
        cnt += float(st[0])
        s = s + np.float64(st[1])
        if float(st[0]) > 0:
            mn = min(mn, float(st[2]))
            mx = max(mx, float(st[3]))
    return cnt, float(s), mn, mx


# State columns per aggregate: AVG keeps (sum, count); COUNT_DISTINCT defers
# to the reduce side (map side emits distinct (group, value) pairs).

def _state_cols(spec: AggSpec) -> List[str]:
    if spec.func == AggFunc.AVG:
        return [f"__{spec.out_name}__sum", f"__{spec.out_name}__cnt"]
    if spec.func == AggFunc.COUNT:
        return [f"__{spec.out_name}__cnt"]
    if spec.func == AggFunc.COUNT_DISTINCT:
        return [f"__{spec.out_name}__val"]
    return [f"__{spec.out_name}__acc"]


def _group_key_cols(batch: PartitionBatch, group_cols: Sequence[str],
                    first: np.ndarray) -> Dict[str, ColumnVal]:
    """Representative group-key columns (codes stay codes) — shared by
    every merge/partial output assembly."""
    out: Dict[str, ColumnVal] = {}
    for g in group_cols:
        v = batch.col(g)
        out[g] = ColumnVal(np.asarray(v.arr)[first], v.sdict, v.sorted_dict)
    return out


def _partial_states(spec: AggSpec, inverse: np.ndarray,
                    val: Optional[np.ndarray], num_groups: int,
                    out: Dict[str, ColumnVal]) -> None:
    """One spec's partial state columns for a pre-grouped partition."""
    if spec.func == AggFunc.COUNT:
        acc = np.bincount(inverse, minlength=num_groups).astype(np.int64)
        out[_state_cols(spec)[0]] = ColumnVal(acc)
    elif spec.func == AggFunc.SUM:
        out[_state_cols(spec)[0]] = ColumnVal(seg_sum(inverse, val,
                                                      num_groups))
    elif spec.func == AggFunc.AVG:
        s = np.bincount(inverse, weights=val.astype(np.float64),
                        minlength=num_groups)
        c = np.bincount(inverse, minlength=num_groups).astype(np.int64)
        sc, cc = _state_cols(spec)
        out[sc] = ColumnVal(s)
        out[cc] = ColumnVal(c)
    elif spec.func in (AggFunc.MIN, AggFunc.MAX):
        out[_state_cols(spec)[0]] = ColumnVal(
            seg_minmax(inverse, val, num_groups,
                       spec.func == AggFunc.MIN))
    else:
        raise NotImplementedError(spec.func)


def partial_aggregate(batch: PartitionBatch, group_cols: Sequence[str],
                      aggs: Sequence[AggSpec]) -> PartitionBatch:
    """Task-local aggregation: one output row per group in this partition."""
    n = batch.num_rows
    keys = [np.asarray(batch.col(g).arr) for g in group_cols]
    # string group keys: group locally on codes (cheap), the reduce side
    # unifies dictionaries — representative rows stay codes end to end.
    first, inverse = group_indices(keys) if group_cols else \
        (np.zeros(1, np.int64), np.zeros(n, np.int64))
    num_groups = len(first)

    out: Dict[str, ColumnVal] = _group_key_cols(batch, group_cols, first)

    distinct_specs = [a for a in aggs if a.func == AggFunc.COUNT_DISTINCT]
    plain_specs = [a for a in aggs if a.func != AggFunc.COUNT_DISTINCT]

    for spec in plain_specs:
        if spec.arg is not None:
            ctx = {name: batch.col(name) for name in batch.names()}
            val = np.asarray(evaluate(spec.arg, ctx).arr)
        else:
            val = None
        _partial_states(spec, inverse, val, num_groups, out)

    if distinct_specs:
        # Exact distinct: partial rows become per-(group, value) instead of
        # per-group.  Plain aggregates stay correct because their states are
        # additive across the finer grouping; the reduce side re-merges by
        # group and counts unique (group, value) pairs.
        if len(distinct_specs) > 1:
            raise NotImplementedError("multiple COUNT(DISTINCT) columns")
        spec = distinct_specs[0]
        ctx = {name: batch.col(name) for name in batch.names()}
        val = evaluate(spec.arg, ctx)
        pair_keys = keys + [np.asarray(val.arr)]
        pfirst, pinverse = group_indices(pair_keys)
        num_pairs = len(pfirst)
        out = _group_key_cols(batch, group_cols, pfirst)
        out[_state_cols(spec)[0]] = ColumnVal(
            np.asarray(val.arr)[pfirst], val.sdict, val.sorted_dict)
        for pspec in plain_specs:
            if pspec.arg is not None:
                pval = np.asarray(evaluate(pspec.arg, ctx).arr)
            else:
                pval = None
            _partial_states(pspec, pinverse, pval, num_pairs, out)

    return PartitionBatch(out)


def merge_aggregate(batch: PartitionBatch, group_cols: Sequence[str],
                    aggs: Sequence[AggSpec]) -> PartitionBatch:
    """Reduce-side final merge of partial states (one row per group) — the
    interpreted oracle for CompiledMerge."""
    keys = [np.asarray(batch.col(g).arr) for g in group_cols]
    n = batch.num_rows
    first, inverse = group_indices(keys) if group_cols else \
        (np.zeros(1, np.int64), np.zeros(n, np.int64))
    num_groups = len(first)

    out: Dict[str, ColumnVal] = _group_key_cols(batch, group_cols, first)

    for spec in aggs:
        if spec.func == AggFunc.COUNT_DISTINCT:
            vc = batch.col(_state_cols(spec)[0])
            pair_keys = keys + [np.asarray(vc.arr)]
            _, pair_inv = group_indices(pair_keys)
            # count unique (group, value) pairs per group
            uniq_pairs, pair_first = np.unique(pair_inv, return_index=True)
            grp_of_pair = inverse[pair_first]
            cnt = np.bincount(grp_of_pair, minlength=num_groups).astype(np.int64)
            out[spec.out_name] = ColumnVal(cnt)
            continue
        cols = _state_cols(spec)
        if spec.func == AggFunc.COUNT:
            v = np.asarray(batch.col(cols[0]).arr)
            out[spec.out_name] = ColumnVal(
                seg_sum(inverse, v, num_groups).astype(np.int64))
        elif spec.func == AggFunc.SUM:
            v = np.asarray(batch.col(cols[0]).arr)
            out[spec.out_name] = ColumnVal(seg_sum(inverse, v, num_groups))
        elif spec.func == AggFunc.AVG:
            s = np.bincount(inverse,
                            weights=np.asarray(batch.col(cols[0]).arr,
                                               dtype=np.float64),
                            minlength=num_groups)
            c = np.bincount(inverse,
                            weights=np.asarray(batch.col(cols[1]).arr,
                                               dtype=np.float64),
                            minlength=num_groups)
            out[spec.out_name] = ColumnVal(s / np.maximum(c, 1))
        elif spec.func in (AggFunc.MIN, AggFunc.MAX):
            v = np.asarray(batch.col(cols[0]).arr)
            out[spec.out_name] = ColumnVal(
                seg_minmax(inverse, v, num_groups,
                           spec.func == AggFunc.MIN))
        else:
            raise NotImplementedError(spec.func)
    return PartitionBatch(out)


# ---------------------------------------------------------------------------
# Compiled reduce-side merge (DESIGN.md §11).
#
# The grouping itself (np.unique over the, typically few, partial-state
# rows) stays host-side: its output shape is data-dependent.  Everything
# after it — every aggregate's segmented reduction — lowers into ONE jitted
# XLA program over (inverse, state columns), cached process-wide per state
# signature.  Rows and group counts pad to powers of two (padding rows map
# to a discarded extra group slot), so each signature re-traces O(log n)
# times, the same discipline as expr._PLAN_CACHE and joins.CompiledProbe.
# ---------------------------------------------------------------------------


_MERGE_FNS: Dict[Tuple, Callable] = {}
_MERGE_FNS_LOCK = threading.Lock()


def _merge_fn(sig: Tuple) -> Callable:
    with _MERGE_FNS_LOCK:
        fn = _MERGE_FNS.get(sig)
        if fn is not None:
            return fn
        import functools

        import jax
        import jax.numpy as jnp

        def traced(inv, cols, gp):
            outs = []
            i = 0
            for kind, is_int in sig:
                if kind in ("count", "sum"):
                    dt = jnp.int64 if is_int else jnp.float64
                    acc = jnp.zeros(gp + 1, dt).at[inv].add(
                        cols[i].astype(dt))
                    i += 1
                    outs.append(acc[:gp])
                elif kind == "avg":
                    s = jnp.zeros(gp + 1, jnp.float64).at[inv].add(
                        cols[i].astype(jnp.float64))
                    c = jnp.zeros(gp + 1, jnp.float64).at[inv].add(
                        cols[i + 1].astype(jnp.float64))
                    i += 2
                    outs.append((s / jnp.maximum(c, 1.0))[:gp])
                elif kind in ("min", "max"):
                    if is_int:
                        fill = (INT64_MIN_IDENT if kind == "min"
                                else INT64_MAX_IDENT)
                        acc = jnp.full(gp + 1, fill, jnp.int64)
                        v = cols[i].astype(jnp.int64)
                    else:
                        fill = jnp.inf if kind == "min" else -jnp.inf
                        acc = jnp.full(gp + 1, fill, jnp.float64)
                        v = cols[i].astype(jnp.float64)
                    acc = (acc.at[inv].min(v) if kind == "min"
                           else acc.at[inv].max(v))
                    i += 1
                    outs.append(acc[:gp])
                else:
                    raise ValueError(kind)
            return tuple(outs)

        fn = functools.partial(jax.jit, static_argnames=("gp",))(traced)
        _MERGE_FNS[sig] = fn
        return fn


_KIND_OF = {AggFunc.COUNT: "count", AggFunc.SUM: "sum", AggFunc.AVG: "avg",
            AggFunc.MIN: "min", AggFunc.MAX: "max"}


class CompiledMerge:
    """`merge_aggregate` lowered to one fused jitted program per reduce
    task.  Bit-exact with the oracle on integer states (int64 segment
    adds); float reductions agree to rounding (XLA may reorder)."""

    def __init__(self, group_cols: Sequence[str], aggs: Sequence[AggSpec]):
        if any(a.func == AggFunc.COUNT_DISTINCT for a in aggs):
            raise ExprCompileError(
                "COUNT(DISTINCT) merge is pair-regrouping, not a segmented "
                "reduce — interpreted path")
        self.group_cols = list(group_cols)
        self.aggs = list(aggs)

    def _signature(self, batch: PartitionBatch) -> Tuple:
        sig = []
        for spec in self.aggs:
            kind = _KIND_OF[spec.func]
            state = np.asarray(batch.col(_state_cols(spec)[0]).arr)
            is_int = bool(np.issubdtype(state.dtype, np.integer))
            sig.append((kind, is_int))
        return tuple(sig)

    def __call__(self, batch: PartitionBatch) -> PartitionBatch:
        from .expr import _x64
        keys = [np.asarray(batch.col(g).arr) for g in self.group_cols]
        n = batch.num_rows
        first, inverse = group_indices(keys) if self.group_cols else \
            (np.zeros(1, np.int64), np.zeros(n, np.int64))
        num_groups = len(first)
        gp = _next_pow2(num_groups)
        npad = _next_pow2(max(n, 1))
        inv = np.full(npad, gp, np.int64)   # padding -> discarded slot gp
        inv[:n] = inverse

        cols: List[np.ndarray] = []
        for spec in self.aggs:
            for sc in _state_cols(spec):
                state = np.asarray(batch.col(sc).arr)
                pad = np.zeros(npad, state.dtype)
                pad[:n] = state
                cols.append(pad)

        sig = self._signature(batch)
        fn = _merge_fn(sig)
        with _x64():
            outs = fn(inv, tuple(cols), gp=gp)

        out = _group_key_cols(batch, self.group_cols, first)
        for spec, o in zip(self.aggs, outs):
            arr = np.asarray(o)[:num_groups]
            if spec.func == AggFunc.COUNT:
                arr = arr.astype(np.int64)
            out[spec.out_name] = ColumnVal(arr)
        return PartitionBatch(out)


def merge_from_lanes(batch: PartitionBatch, group_cols: Sequence[str],
                     aggs: Sequence[AggSpec], first: np.ndarray,
                     lanes: Dict[str, np.ndarray]) -> PartitionBatch:
    """Assemble the final merge output from per-state-column (G, 4)
    [sum, count, min, max] lanes — the shape the Pallas `segmented_merge`
    kernel produces.  Lives here (next to merge_aggregate and
    CompiledMerge) so the per-AggFunc output policy has one home."""
    out = _group_key_cols(batch, group_cols, first)
    for spec in aggs:
        cols = _state_cols(spec)
        if spec.func == AggFunc.COUNT:
            out[spec.out_name] = ColumnVal(
                np.round(lanes[cols[0]][:, 0]).astype(np.int64))
        elif spec.func == AggFunc.SUM:
            out[spec.out_name] = ColumnVal(lanes[cols[0]][:, 0])
        elif spec.func == AggFunc.AVG:
            s = lanes[cols[0]][:, 0]
            c = lanes[cols[1]][:, 0]
            out[spec.out_name] = ColumnVal(s / np.maximum(c, 1.0))
        elif spec.func == AggFunc.MIN:
            out[spec.out_name] = ColumnVal(lanes[cols[0]][:, 2])
        elif spec.func == AggFunc.MAX:
            out[spec.out_name] = ColumnVal(lanes[cols[0]][:, 3])
        else:
            raise ExprCompileError(str(spec.func))
    return PartitionBatch(out)
