"""Hash aggregation (paper §6.2.2, §6.3.1).

Like Shark (and Hive), aggregations run in two phases: task-local partial
aggregation on each partition, then a shuffle of the partial states by group
key and a final merge on the reduce side.  Spark's hash-based distributed
aggregation (no sort before shuffle, §7.1) is reproduced: grouping is
hash/unique-based, never a global sort.

On TPU, the partial phase is the Pallas `groupby_mxu` kernel for small group
cardinality (group-by as a one-hot matmul on the systolic array) and a
sort/segment-sum for large cardinality; this module is the engine-level
(host/numpy) implementation and the oracle for those kernels.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .batch import PartitionBatch
from .expr import ColumnVal, Evaluator, evaluate
from .plan import AggFunc, AggSpec


def group_indices(keys: Sequence[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """Group rows by composite key.  Returns (representative row indices of
    each group, inverse mapping row -> group id).  Hash-based (np.unique),
    not sort-order dependent."""
    n = len(keys[0]) if keys else 0
    if not keys:
        return np.zeros(1, np.int64), np.zeros(n, np.int64)
    if len(keys) == 1:
        _, first, inverse = np.unique(keys[0], return_index=True,
                                      return_inverse=True)
        return first, inverse
    # composite: unique over a void view of stacked columns
    cols = [np.asarray(k) for k in keys]
    rec = np.empty(n, dtype=[(f"k{i}", c.dtype) for i, c in enumerate(cols)])
    for i, c in enumerate(cols):
        rec[f"k{i}"] = c
    _, first, inverse = np.unique(rec, return_index=True, return_inverse=True)
    return first, inverse


# State columns per aggregate: AVG keeps (sum, count); COUNT_DISTINCT defers
# to the reduce side (map side emits distinct (group, value) pairs).

def _state_cols(spec: AggSpec) -> List[str]:
    if spec.func == AggFunc.AVG:
        return [f"__{spec.out_name}__sum", f"__{spec.out_name}__cnt"]
    if spec.func == AggFunc.COUNT:
        return [f"__{spec.out_name}__cnt"]
    if spec.func == AggFunc.COUNT_DISTINCT:
        return [f"__{spec.out_name}__val"]
    return [f"__{spec.out_name}__acc"]


def partial_aggregate(batch: PartitionBatch, group_cols: Sequence[str],
                      aggs: Sequence[AggSpec]) -> PartitionBatch:
    """Task-local aggregation: one output row per group in this partition."""
    n = batch.num_rows
    keys = [np.asarray(batch.col(g).arr) for g in group_cols]
    # string group keys: group locally on codes (cheap), decode only the
    # representative rows below.
    first, inverse = group_indices(keys) if group_cols else \
        (np.zeros(1, np.int64), np.zeros(n, np.int64))
    num_groups = len(first)

    out: Dict[str, ColumnVal] = {}
    for g in group_cols:
        v = batch.col(g)
        out[g] = ColumnVal(np.asarray(v.arr)[first], v.sdict, v.sorted_dict)

    distinct_specs = [a for a in aggs if a.func == AggFunc.COUNT_DISTINCT]
    plain_specs = [a for a in aggs if a.func != AggFunc.COUNT_DISTINCT]

    for spec in plain_specs:
        if spec.arg is not None:
            ctx = {name: batch.col(name) for name in batch.names()}
            val = np.asarray(evaluate(spec.arg, ctx).arr)
        else:
            val = None
        if spec.func == AggFunc.COUNT:
            acc = np.bincount(inverse, minlength=num_groups).astype(np.int64)
            out[_state_cols(spec)[0]] = ColumnVal(acc)
        elif spec.func == AggFunc.SUM:
            acc = np.bincount(inverse, weights=val.astype(np.float64),
                              minlength=num_groups)
            acc = acc.astype(np.int64) if np.issubdtype(val.dtype, np.integer) \
                else acc
            out[_state_cols(spec)[0]] = ColumnVal(acc)
        elif spec.func == AggFunc.AVG:
            s = np.bincount(inverse, weights=val.astype(np.float64),
                            minlength=num_groups)
            c = np.bincount(inverse, minlength=num_groups).astype(np.int64)
            sc, cc = _state_cols(spec)
            out[sc] = ColumnVal(s)
            out[cc] = ColumnVal(c)
        elif spec.func in (AggFunc.MIN, AggFunc.MAX):
            fill = np.inf if spec.func == AggFunc.MIN else -np.inf
            acc = np.full(num_groups, fill, np.float64)
            ufunc = np.minimum if spec.func == AggFunc.MIN else np.maximum
            ufunc.at(acc, inverse, val.astype(np.float64))
            out[_state_cols(spec)[0]] = ColumnVal(acc)
        else:
            raise NotImplementedError(spec.func)

    if distinct_specs:
        # Exact distinct: partial rows become per-(group, value) instead of
        # per-group.  Plain aggregates stay correct because their states are
        # additive across the finer grouping; the reduce side re-merges by
        # group and counts unique (group, value) pairs.
        if len(distinct_specs) > 1:
            raise NotImplementedError("multiple COUNT(DISTINCT) columns")
        spec = distinct_specs[0]
        ctx = {name: batch.col(name) for name in batch.names()}
        val = evaluate(spec.arg, ctx)
        pair_keys = keys + [np.asarray(val.arr)]
        pfirst, pinverse = group_indices(pair_keys)
        num_pairs = len(pfirst)
        out = {}
        for g in group_cols:
            v = batch.col(g)
            out[g] = ColumnVal(np.asarray(v.arr)[pfirst], v.sdict, v.sorted_dict)
        out[_state_cols(spec)[0]] = ColumnVal(
            np.asarray(val.arr)[pfirst], val.sdict, val.sorted_dict)
        for pspec in plain_specs:
            if pspec.arg is not None:
                pval = np.asarray(evaluate(pspec.arg, ctx).arr)
            else:
                pval = None
            if pspec.func == AggFunc.COUNT:
                out[_state_cols(pspec)[0]] = ColumnVal(
                    np.bincount(pinverse, minlength=num_pairs).astype(np.int64))
            elif pspec.func == AggFunc.SUM:
                acc = np.bincount(pinverse, weights=pval.astype(np.float64),
                                  minlength=num_pairs)
                if np.issubdtype(pval.dtype, np.integer):
                    acc = acc.astype(np.int64)
                out[_state_cols(pspec)[0]] = ColumnVal(acc)
            elif pspec.func == AggFunc.AVG:
                s = np.bincount(pinverse, weights=pval.astype(np.float64),
                                minlength=num_pairs)
                c = np.bincount(pinverse, minlength=num_pairs).astype(np.int64)
                sc, cc = _state_cols(pspec)
                out[sc] = ColumnVal(s)
                out[cc] = ColumnVal(c)
            elif pspec.func in (AggFunc.MIN, AggFunc.MAX):
                fill = np.inf if pspec.func == AggFunc.MIN else -np.inf
                acc = np.full(num_pairs, fill, np.float64)
                ufunc = np.minimum if pspec.func == AggFunc.MIN else np.maximum
                ufunc.at(acc, pinverse, pval.astype(np.float64))
                out[_state_cols(pspec)[0]] = ColumnVal(acc)

    return PartitionBatch(out)


def merge_aggregate(batch: PartitionBatch, group_cols: Sequence[str],
                    aggs: Sequence[AggSpec]) -> PartitionBatch:
    """Reduce-side final merge of partial states (one row per group)."""
    keys = [np.asarray(batch.col(g).arr) for g in group_cols]
    n = batch.num_rows
    first, inverse = group_indices(keys) if group_cols else \
        (np.zeros(1, np.int64), np.zeros(n, np.int64))
    num_groups = len(first)

    out: Dict[str, ColumnVal] = {}
    for g in group_cols:
        v = batch.col(g)
        out[g] = ColumnVal(np.asarray(v.arr)[first], v.sdict, v.sorted_dict)

    for spec in aggs:
        if spec.func == AggFunc.COUNT_DISTINCT:
            vc = batch.col(_state_cols(spec)[0])
            pair_keys = keys + [np.asarray(vc.arr)]
            _, pair_inv = group_indices(pair_keys)
            # count unique (group, value) pairs per group
            uniq_pairs, pair_first = np.unique(pair_inv, return_index=True)
            grp_of_pair = inverse[pair_first]
            cnt = np.bincount(grp_of_pair, minlength=num_groups).astype(np.int64)
            out[spec.out_name] = ColumnVal(cnt)
            continue
        cols = _state_cols(spec)
        if spec.func == AggFunc.COUNT:
            acc = np.bincount(inverse,
                              weights=np.asarray(batch.col(cols[0]).arr,
                                                 dtype=np.float64),
                              minlength=num_groups)
            out[spec.out_name] = ColumnVal(acc.astype(np.int64))
        elif spec.func == AggFunc.SUM:
            v = np.asarray(batch.col(cols[0]).arr)
            acc = np.bincount(inverse, weights=v.astype(np.float64),
                              minlength=num_groups)
            acc = acc.astype(np.int64) if np.issubdtype(v.dtype, np.integer) \
                else acc
            out[spec.out_name] = ColumnVal(acc)
        elif spec.func == AggFunc.AVG:
            s = np.bincount(inverse,
                            weights=np.asarray(batch.col(cols[0]).arr,
                                               dtype=np.float64),
                            minlength=num_groups)
            c = np.bincount(inverse,
                            weights=np.asarray(batch.col(cols[1]).arr,
                                               dtype=np.float64),
                            minlength=num_groups)
            out[spec.out_name] = ColumnVal(s / np.maximum(c, 1))
        elif spec.func in (AggFunc.MIN, AggFunc.MAX):
            v = np.asarray(batch.col(cols[0]).arr, dtype=np.float64)
            fill = np.inf if spec.func == AggFunc.MIN else -np.inf
            acc = np.full(num_groups, fill, np.float64)
            ufunc = np.minimum if spec.func == AggFunc.MIN else np.maximum
            ufunc.at(acc, inverse, v)
            out[spec.out_name] = ColumnVal(acc)
        else:
            raise NotImplementedError(spec.func)
    return PartitionBatch(out)
