"""Schema and type system for the Shark columnar engine.

Shark inherits Hive's schema-on-read model; we keep a small, explicit type
lattice sufficient for the paper's workloads (Pavlo benchmark, TPC-H,
warehouse logs, ML feature matrices).  Strings are always dictionary-encoded
to int32 codes at load time (the columnar-store design of §3.2): the engine
never materializes per-row string objects, mirroring how Shark avoids per-row
JVM objects.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Sequence

import numpy as np


class DType(enum.Enum):
    INT32 = "int32"
    INT64 = "int64"
    FLOAT32 = "float32"
    FLOAT64 = "float64"
    BOOL = "bool"
    STRING = "string"  # stored as int32 dictionary codes
    DATE = "date"      # stored as int32 days-since-epoch

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(_NP.get(self, np.int32))

    @property
    def is_numeric(self) -> bool:
        return self in (DType.INT32, DType.INT64, DType.FLOAT32, DType.FLOAT64)

    @property
    def is_integer(self) -> bool:
        return self in (DType.INT32, DType.INT64, DType.DATE)


_NP = {
    DType.INT32: np.int32,
    DType.INT64: np.int64,
    DType.FLOAT32: np.float32,
    DType.FLOAT64: np.float64,
    DType.BOOL: np.bool_,
    DType.STRING: np.int32,
    DType.DATE: np.int32,
}


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    dtype: DType

    def __repr__(self) -> str:
        return f"{self.name}:{self.dtype.value}"


@dataclasses.dataclass(frozen=True)
class Schema:
    fields: tuple[Field, ...]

    @staticmethod
    def of(**kwargs: DType) -> "Schema":
        return Schema(tuple(Field(k, v) for k, v in kwargs.items()))

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(f.name for f in self.fields)

    def field(self, name: str) -> Field:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(f"no column {name!r} in schema {self.names}")

    def dtype(self, name: str) -> DType:
        return self.field(name).dtype

    def __contains__(self, name: str) -> bool:
        return any(f.name == name for f in self.fields)

    def select(self, names: Sequence[str]) -> "Schema":
        return Schema(tuple(self.field(n) for n in names))

    def concat(self, other: "Schema") -> "Schema":
        seen = set(self.names)
        extra = tuple(f for f in other.fields if f.name not in seen)
        return Schema(self.fields + extra)

    def rename_prefixed(self, prefix: str) -> "Schema":
        return Schema(tuple(Field(f"{prefix}.{f.name}", f.dtype) for f in self.fields))

    def __repr__(self) -> str:
        return "Schema(" + ", ".join(map(repr, self.fields)) + ")"


def common_dtype(a: DType, b: DType) -> DType:
    """Numeric type promotion for binary expressions."""
    if a == b:
        return a
    order = [DType.BOOL, DType.INT32, DType.DATE, DType.INT64, DType.FLOAT32, DType.FLOAT64]
    if a in order and b in order:
        return order[max(order.index(a), order.index(b))]
    raise TypeError(f"incompatible dtypes {a} and {b}")


def np_value(dtype: DType, v: Any) -> Any:
    return dtype.np_dtype.type(v)
