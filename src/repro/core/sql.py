"""SQL front end (paper §2.4).

Query compilation follows the paper's three steps: parse to an AST, build a
logical plan with basic optimization (predicate pushdown), emit a physical
plan of RDD transformations.  The dialect covers the paper's workloads:

  SELECT <exprs|aggregates> FROM t [AS a][, u [AS b] | JOIN u ON k]
    [WHERE pred] [GROUP BY exprs] [HAVING pred]
    [ORDER BY col [DESC], ...] [LIMIT n]

  CREATE TABLE name [TBLPROPERTIES ("shark.cache"="true"
    [, "copartition"="other"])] AS SELECT ... [DISTRIBUTE BY col]

Comma-joins with equi-join predicates in WHERE (the Pavlo join query's form)
are recognized and turned into JoinNodes; remaining conjuncts stay filters.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

from .expr import (And, Between, BinOp, Cmp, Col, Expr, Func, InList, Lit,
                   Not, Or, conjoin, rewrite_expr, split_conjuncts)
from .plan import (AggFunc, AggregateNode, AggSpec, FilterNode, JoinNode,
                   LimitNode, Node, ProjectNode, ScanNode, SortNode)

KEYWORDS = {
    "SELECT", "FROM", "WHERE", "GROUP", "BY", "ORDER", "LIMIT", "AS", "AND",
    "OR", "NOT", "JOIN", "ON", "INNER", "LEFT", "OUTER", "CREATE", "TABLE",
    "TBLPROPERTIES", "DISTRIBUTE", "BETWEEN", "IN", "DESC", "ASC", "DISTINCT",
    "INTO", "TEMP", "DATE", "HAVING",
}

AGG_FUNCS = {"COUNT": AggFunc.COUNT, "SUM": AggFunc.SUM, "AVG": AggFunc.AVG,
             "MIN": AggFunc.MIN, "MAX": AggFunc.MAX}

_TOKEN_RE = re.compile(r"""
    \s*(?:
      (?P<number>\d+\.\d+|\.\d+|\d+)
    | (?P<string>'(?:[^']|'')*')
    | (?P<name>[A-Za-z_][A-Za-z_0-9]*(?:\.[A-Za-z_][A-Za-z_0-9]*)?)
    | (?P<op><>|!=|>=|<=|=|<|>|\+|-|\*|/|%|\(|\)|,|;)
    )""", re.VERBOSE)


@dataclasses.dataclass
class Token:
    kind: str  # number | string | name | keyword | op | eof
    value: str


def tokenize(sql: str) -> List[Token]:
    out: List[Token] = []
    pos = 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            if sql[pos:].strip() == "":
                break
            raise SyntaxError(f"cannot tokenize near: {sql[pos:pos+32]!r}")
        pos = m.end()
        if m.lastgroup == "number":
            out.append(Token("number", m.group("number")))
        elif m.lastgroup == "string":
            raw = m.group("string")[1:-1].replace("''", "'")
            out.append(Token("string", raw))
        elif m.lastgroup == "name":
            name = m.group("name")
            if name.upper() in KEYWORDS:
                out.append(Token("keyword", name.upper()))
            else:
                out.append(Token("name", name))
        else:
            out.append(Token("op", m.group("op")))
    out.append(Token("eof", ""))
    return out


@dataclasses.dataclass
class SelectStmt:
    select: List[Tuple[Optional[str], object]]  # (alias, Expr|AggSpec-ish)
    from_items: List[Tuple[str, str]]           # (table, alias)
    joins: List[Tuple[str, str, Expr, str]]     # (table, alias, on, how)
    where: Optional[Expr]
    group_by: List[Expr]
    order_by: List[Tuple[str, bool]]
    limit: Optional[int]
    distribute_by: Optional[str]
    having: Optional[Expr] = None


@dataclasses.dataclass
class CreateStmt:
    name: str
    properties: Dict[str, str]
    select: SelectStmt


@dataclasses.dataclass
class _AggCall:
    func: AggFunc
    arg: Optional[Expr]
    distinct: bool = False


class Parser:
    def __init__(self, sql: str):
        self.toks = tokenize(sql)
        self.i = 0

    # -- token helpers -------------------------------------------------------

    def peek(self) -> Token:
        return self.toks[self.i]

    def next(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept(self, kind: str, value: Optional[str] = None) -> Optional[Token]:
        t = self.peek()
        if t.kind == kind and (value is None or t.value == value):
            return self.next()
        return None

    def expect(self, kind: str, value: Optional[str] = None) -> Token:
        t = self.accept(kind, value)
        if t is None:
            raise SyntaxError(f"expected {value or kind}, got "
                              f"{self.peek().kind}:{self.peek().value!r}")
        return t

    # -- grammar ---------------------------------------------------------------

    def parse(self):
        if self.peek().kind == "keyword" and self.peek().value == "CREATE":
            return self.create_stmt()
        stmt = self.select_stmt()
        self.accept("op", ";")
        return stmt

    def create_stmt(self) -> CreateStmt:
        self.expect("keyword", "CREATE")
        self.expect("keyword", "TABLE")
        name = self.expect("name").value
        props: Dict[str, str] = {}
        if self.accept("keyword", "TBLPROPERTIES"):
            self.expect("op", "(")
            while True:
                k = self.expect("string").value
                self.expect("op", "=")
                v = self.expect("string").value
                props[k] = v
                if not self.accept("op", ","):
                    break
            self.expect("op", ")")
        self.expect("keyword", "AS")
        sel = self.select_stmt()
        self.accept("op", ";")
        return CreateStmt(name, props, sel)

    def select_stmt(self) -> SelectStmt:
        self.expect("keyword", "SELECT")
        self.accept("keyword", "INTO") and self.expect("keyword", "TEMP")
        select: List[Tuple[Optional[str], object]] = []
        while True:
            if self.accept("op", "*"):
                select.append((None, "*"))
            else:
                e = self.expr()
                alias = None
                if self.accept("keyword", "AS"):
                    alias = self.expect("name").value
                elif self.peek().kind == "name":
                    alias = self.next().value
                select.append((alias, e))
            if not self.accept("op", ","):
                break
        self.expect("keyword", "FROM")
        from_items: List[Tuple[str, str]] = []
        joins: List[Tuple[str, str, Expr, str]] = []
        t, a = self._table_ref()
        from_items.append((t, a))
        while True:
            if self.accept("op", ","):
                t, a = self._table_ref()
                from_items.append((t, a))
                continue
            how = "inner"
            if self.accept("keyword", "LEFT"):
                self.accept("keyword", "OUTER")
                how = "left"
                self.expect("keyword", "JOIN")
            elif self.accept("keyword", "INNER"):
                self.expect("keyword", "JOIN")
            elif not self.accept("keyword", "JOIN"):
                break
            t, a = self._table_ref()
            self.expect("keyword", "ON")
            on = self.expr()
            joins.append((t, a, on, how))
        where = None
        if self.accept("keyword", "WHERE"):
            where = self.expr()
        group_by: List[Expr] = []
        if self.accept("keyword", "GROUP"):
            self.expect("keyword", "BY")
            group_by.append(self.expr())
            while self.accept("op", ","):
                group_by.append(self.expr())
        having = None
        if self.accept("keyword", "HAVING"):
            having = self.expr()
        order_by: List[Tuple[str, bool]] = []
        if self.accept("keyword", "ORDER"):
            self.expect("keyword", "BY")
            while True:
                col = self.expect("name").value
                desc = bool(self.accept("keyword", "DESC"))
                if not desc:
                    self.accept("keyword", "ASC")
                order_by.append((col, desc))
                if not self.accept("op", ","):
                    break
        limit = None
        if self.accept("keyword", "LIMIT"):
            limit = int(self.expect("number").value)
        distribute_by = None
        if self.accept("keyword", "DISTRIBUTE"):
            self.expect("keyword", "BY")
            distribute_by = self.expect("name").value
        return SelectStmt(select, from_items, joins, where, group_by,
                          order_by, limit, distribute_by, having)

    def _table_ref(self) -> Tuple[str, str]:
        t = self.expect("name").value
        alias = t
        if self.accept("keyword", "AS"):
            alias = self.expect("name").value
        elif self.peek().kind == "name":
            alias = self.next().value
        return t, alias

    # -- expressions -------------------------------------------------------

    def expr(self) -> Expr:
        return self._or()

    def _or(self) -> Expr:
        e = self._and()
        while self.accept("keyword", "OR"):
            e = Or(e, self._and())
        return e

    def _and(self) -> Expr:
        e = self._not()
        while self.accept("keyword", "AND"):
            e = And(e, self._not())
        return e

    def _not(self) -> Expr:
        if self.accept("keyword", "NOT"):
            return Not(self._not())
        return self._cmp()

    def _cmp(self) -> Expr:
        e = self._add()
        t = self.peek()
        if t.kind == "op" and t.value in ("=", "!=", "<>", "<", "<=", ">", ">="):
            self.next()
            op = "!=" if t.value == "<>" else t.value
            return Cmp(op, e, self._add())
        if t.kind == "keyword" and t.value == "BETWEEN":
            self.next()
            lo = self._add()
            self.expect("keyword", "AND")
            hi = self._add()
            return Between(e, _litval(lo), _litval(hi))
        if t.kind == "keyword" and t.value == "NOT":
            # NOT IN / NOT BETWEEN
            save = self.i
            self.next()
            if self.accept("keyword", "IN"):
                self.expect("op", "(")
                vals = [self._literal_value()]
                while self.accept("op", ","):
                    vals.append(self._literal_value())
                self.expect("op", ")")
                return Not(InList(e, tuple(vals)))
            self.i = save
        if t.kind == "keyword" and t.value == "IN":
            self.next()
            self.expect("op", "(")
            vals = [self._literal_value()]
            while self.accept("op", ","):
                vals.append(self._literal_value())
            self.expect("op", ")")
            return InList(e, tuple(vals))
        return e

    def _add(self) -> Expr:
        e = self._mul()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("+", "-"):
                self.next()
                e = BinOp(t.value, e, self._mul())
            else:
                return e

    def _mul(self) -> Expr:
        e = self._unary()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("*", "/", "%"):
                self.next()
                e = BinOp(t.value, e, self._unary())
            else:
                return e

    def _unary(self) -> Expr:
        if self.accept("op", "-"):
            return BinOp("-", Lit(0), self._unary())
        return self._atom()

    def _literal_value(self):
        neg = bool(self.accept("op", "-"))
        t = self.next()
        if t.kind == "number":
            v = float(t.value) if "." in t.value else int(t.value)
            return -v if neg else v
        if t.kind == "string" and not neg:
            return t.value
        raise SyntaxError(f"expected literal, got {t.value!r}")

    def _atom(self) -> Expr:
        t = self.peek()
        if t.kind == "number":
            self.next()
            v = float(t.value) if "." in t.value else int(t.value)
            return Lit(v)
        if t.kind == "string":
            self.next()
            return Lit(t.value)
        if t.kind == "keyword" and t.value == "DATE":
            # Date('2000-01-15') -> days since epoch literal
            self.next()
            self.expect("op", "(")
            s = self.expect("string").value
            self.expect("op", ")")
            return Lit(_date_to_days(s))
        if t.kind == "name":
            name = self.next().value
            upper = name.upper()
            if self.accept("op", "("):
                if upper in AGG_FUNCS:
                    distinct = bool(self.accept("keyword", "DISTINCT"))
                    if self.accept("op", "*"):
                        arg = None
                    else:
                        arg = self.expr()
                    self.expect("op", ")")
                    return _AggExpr(AGG_FUNCS[upper], arg, distinct)
                args = []
                if not self.accept("op", ")"):
                    args.append(self.expr())
                    while self.accept("op", ","):
                        args.append(self.expr())
                    self.expect("op", ")")
                return Func(upper, tuple(args))
            return Col(name)
        if self.accept("op", "("):
            e = self.expr()
            self.expect("op", ")")
            return e
        raise SyntaxError(f"unexpected token {t.kind}:{t.value!r}")


@dataclasses.dataclass(eq=False)
class _AggExpr(Expr):
    """Aggregate call inside a select list (resolved by the binder)."""
    func: AggFunc
    arg: Optional[Expr]
    distinct: bool

    def children(self):
        return (self.arg,) if self.arg is not None else ()

    def __repr__(self):
        d = "DISTINCT " if self.distinct else ""
        return f"{self.func.value}({d}{self.arg if self.arg is not None else '*'})"


def _litval(e: Expr):
    # fold the unary-minus encoding (0 - x) back into a negative literal
    if (isinstance(e, BinOp) and e.op == "-" and isinstance(e.left, Lit)
            and e.left.value == 0 and isinstance(e.right, Lit)):
        return -e.right.value
    assert isinstance(e, Lit), f"expected literal, got {e}"
    return e.value


def _date_to_days(s: str) -> int:
    import datetime
    d = datetime.date.fromisoformat(s)
    return (d - datetime.date(1970, 1, 1)).days


# ---------------------------------------------------------------------------
# Binder: SelectStmt -> logical plan
# ---------------------------------------------------------------------------


class Binder:
    def __init__(self, catalog):
        self.catalog = catalog

    def bind(self, stmt: SelectStmt) -> Node:
        # resolve FROM: build scan/join tree
        alias_schema: Dict[str, List[str]] = {}
        for t, a in stmt.from_items:
            alias_schema[a] = list(self.catalog.schema(t).names)
        for t, a, _, _ in stmt.joins:
            alias_schema[a] = list(self.catalog.schema(t).names)

        def resolve(col: str) -> str:
            if "." in col:
                a, c = col.split(".", 1)
                if a in alias_schema and c in alias_schema[a]:
                    return c
                raise KeyError(f"cannot resolve {col}")
            return col

        def strip_quals(e: Expr) -> Expr:
            if isinstance(e, Col):
                return Col(resolve(e.name))
            import copy
            c = copy.copy(e)
            for attr in ("left", "right"):
                if hasattr(c, attr):
                    setattr(c, attr, strip_quals(getattr(c, attr)))
            if hasattr(c, "child") and isinstance(getattr(c, "child"), Expr):
                c.child = strip_quals(c.child)
            if hasattr(c, "args"):
                c.args = tuple(strip_quals(x) for x in c.args)
            if isinstance(c, _AggExpr) and c.arg is not None:
                c.arg = strip_quals(c.arg)
            return c

        where = strip_quals(stmt.where) if stmt.where is not None else None

        # Build the left-deep join tree over ALL from-items: explicit
        # JOIN ... ON clauses and comma tables (whose equi predicates live in
        # WHERE) bind in user order where possible, deferring any item whose
        # join keys reference a table that is not bound yet — so arbitrary
        # N-way mixes like `FROM f JOIN d1 ON ..., d2 WHERE f.x = d2.k`
        # resolve regardless of reference order.  The cost-based ordering
        # pass (plan.order_joins) then picks the initial execution order.
        node: Node = ScanNode(stmt.from_items[0][0])
        bound_aliases = [stmt.from_items[0][1]]
        pending: List[tuple] = (
            [("join", t, a, on, how) for t, a, on, how in stmt.joins]
            + [("comma", t, a, None, "inner") for t, a in stmt.from_items[1:]])
        remaining = list(split_conjuncts(where)) if where is not None else []
        while pending:
            progressed = False
            for pi, item in enumerate(pending):
                kind, t, a, on, how = item
                if kind == "join":
                    keys = self._try_equi(on, alias_schema, bound_aliases, a)
                    if not keys:
                        continue
                    lk, rk = keys
                else:
                    found = None
                    for c in remaining:
                        keys = self._try_equi(c, alias_schema, bound_aliases, a)
                        if keys:
                            found = (c, keys)
                            break
                    if not found:
                        continue
                    c, (lk, rk) = found
                    # remove by identity: Expr overloads == into a Cmp node
                    remaining = [x for x in remaining if x is not c]
                node = JoinNode(node, ScanNode(t), lk, rk, how)
                bound_aliases.append(a)
                del pending[pi]
                progressed = True
                break
            if not progressed:
                kind, t, a, on, how = pending[0]
                if kind == "join":
                    raise NotImplementedError(
                        f"unsupported join condition {on} for table {t}")
                raise NotImplementedError(
                    f"no equi-join predicate found for table {t}")
        if stmt.from_items[1:]:
            where = conjoin(remaining)

        if where is not None:
            node = FilterNode(node, where)

        # aggregation?
        has_agg = any(isinstance(e, _AggExpr) or _contains_agg(e)
                      for _, e in stmt.select if not isinstance(e, str))
        if stmt.having is not None and not (stmt.group_by or has_agg):
            raise ValueError("HAVING requires GROUP BY or an aggregate "
                             "in the SELECT list")
        if stmt.group_by or has_agg:
            items = [(alias, e if isinstance(e, str) else strip_quals(e))
                     for alias, e in stmt.select]
            group_exprs = [strip_quals(g) for g in stmt.group_by]
            having = (strip_quals(stmt.having)
                      if stmt.having is not None else None)
            node = bind_aggregate(self.catalog, node, items, group_exprs,
                                  having)
        else:
            exprs: List[Tuple[str, Expr]] = []
            star = any(isinstance(e, str) for _, e in stmt.select)
            if star:
                for a in bound_aliases:
                    # expansion by schema order; duplicate names suffixed later
                    pass
                all_cols: List[str] = []
                for t, al in (stmt.from_items + [(t, a2) for t, a2, _, _ in stmt.joins]):
                    for c in self.catalog.schema(t).names:
                        if c not in all_cols:
                            all_cols.append(c)
                exprs.extend((c, Col(c)) for c in all_cols)
            for alias, e in stmt.select:
                if isinstance(e, str):
                    continue
                e = strip_quals(e)
                name = alias or _auto_name(e)
                exprs.append((name, e))
            if not (star and len(exprs) == len([1 for _, e in stmt.select if isinstance(e, str)])):
                node = ProjectNode(node, exprs) if exprs else node

        if stmt.order_by:
            node = SortNode(node, [(c, d) for c, d in stmt.order_by])
        if stmt.limit is not None:
            node = LimitNode(node, stmt.limit)
        return node

    def _try_equi(self, c: Expr, alias_schema, left_aliases, right_alias):
        if not isinstance(c, Cmp) or c.op != "=":
            return None
        if not (isinstance(c.left, Col) and isinstance(c.right, Col)):
            return None

        def side(col: str):
            if "." in col:
                a, name = col.split(".", 1)
                if a == right_alias:
                    return "right", name
                if a in left_aliases:
                    return "left", name
                return None, col
            # unqualified: search
            if col in alias_schema.get(right_alias, []):
                return "right", col
            for a in left_aliases:
                if col in alias_schema.get(a, []):
                    return "left", col
            return None, col

        s1, n1 = side(c.left.name)
        s2, n2 = side(c.right.name)
        if s1 == "left" and s2 == "right":
            return n1, n2
        if s1 == "right" and s2 == "left":
            return n2, n1
        return None


# ---------------------------------------------------------------------------
# Aggregate binding — shared by the SQL binder and SharkFrame (core/frame.py)
# ---------------------------------------------------------------------------


def bind_aggregate(catalog, child: Node,
                   select_items: Sequence[Tuple[Optional[str], object]],
                   group_exprs: Sequence[Expr],
                   having: Optional[Expr] = None) -> Node:
    """Build pre-project -> Aggregate [-> HAVING filter] [-> post-project].

    `select_items` is the resolved output list: (alias-or-None, Expr|_AggExpr)
    pairs, qualifier-stripped.  Both query surfaces — the SQL binder and the
    fluent SharkFrame API — funnel through this one function, so a frame-built
    aggregation and its SQL-text twin produce byte-identical logical plans
    (and therefore share one plan-fingerprint result-cache entry)."""
    group_exprs = list(group_exprs)
    # pre-project: group expressions become named columns; agg args keep
    # base columns.
    pre: List[Tuple[str, Expr]] = []
    group_names: List[str] = []
    for i, g in enumerate(group_exprs):
        if isinstance(g, Col):
            group_names.append(g.name)
            pre.append((g.name, g))
        else:
            gname = f"__g{i}"
            group_names.append(gname)
            pre.append((gname, g))
    aggs: List[AggSpec] = []
    agg_out: Dict[Tuple, str] = {}           # (func, arg repr, distinct) -> out
    select_out: List[Tuple[str, str]] = []   # (out name, source col)
    for alias, e in select_items:
        if isinstance(e, str):
            raise NotImplementedError("SELECT * with GROUP BY")
        if isinstance(e, _AggExpr):
            name = alias or _auto_name(e)
            func = (AggFunc.COUNT_DISTINCT
                    if (e.func == AggFunc.COUNT and e.distinct) else e.func)
            aggs.append(AggSpec(name, func, e.arg))
            agg_out.setdefault((e.func, repr(e.arg), e.distinct), name)
            select_out.append((name, name))
            # agg args reference base columns: ensure they pass through
            if e.arg is not None:
                for c in e.arg.columns():
                    if all(p[0] != c for p in pre):
                        pre.append((c, Col(c)))
        else:
            # must match a group expression
            matched = None
            for gname, g in zip(group_names, group_exprs):
                if repr(e) == repr(g) or (isinstance(e, Col)
                                          and e.name == gname):
                    matched = gname
                    break
            if matched is None:
                raise ValueError(f"non-aggregate select expr {e} not in "
                                 f"GROUP BY")
            select_out.append((alias or _auto_name(e), matched))
    if not pre:
        # COUNT(*)-style aggregates need at least one column to carry the
        # row count through the pre-projection
        first_col = child.schema(catalog).names[0]
        pre = [(first_col, Col(first_col))]
    node: Node = ProjectNode(child, pre)
    node = AggregateNode(node, group_names, aggs)
    if having is not None:
        visible_to_src = {name: src for name, src in select_out}
        available = set(group_names) | {a.out_name for a in aggs}
        node = FilterNode(node, _resolve_having(having, agg_out,
                                                visible_to_src, available))
    # post-project for aliasing/ordering
    out_exprs = [(name, Col(src)) for name, src in select_out]
    if [n for n, _ in out_exprs] != group_names + [a.out_name for a in aggs] \
            or any(n != s for n, s in select_out):
        node = ProjectNode(node, out_exprs)
    return node


def _resolve_having(e: Expr, agg_out: Dict[Tuple, str],
                    visible_to_src: Dict[str, str], available: set) -> Expr:
    """Rewrite a HAVING predicate against the aggregate's output: aggregate
    calls resolve to their SELECT alias, output aliases to internal names."""

    def resolve(n: Expr) -> Optional[Expr]:
        if isinstance(n, _AggExpr):
            name = agg_out.get((n.func, repr(n.arg), n.distinct))
            if name is None:
                raise ValueError(f"HAVING aggregate {n!r} must also appear "
                                 f"in the SELECT list")
            return Col(name)
        if isinstance(n, Col):
            name = visible_to_src.get(n.name, n.name)
            if name not in available:
                raise ValueError(
                    f"HAVING references {n.name!r}, which is not a GROUP BY "
                    f"column or aggregate output; available: "
                    f"{', '.join(sorted(available))}")
            return Col(name)
        return None

    return rewrite_expr(e, resolve)


def _contains_agg(e) -> bool:
    if isinstance(e, _AggExpr):
        return True
    if isinstance(e, Expr):
        return any(_contains_agg(c) for c in e.children())
    return False


def _auto_name(e: Expr) -> str:
    if isinstance(e, Col):
        return e.name
    if isinstance(e, _AggExpr):
        base = e.arg.columns()[0] if (e.arg is not None and e.arg.columns()) else "star"
        return f"{e.func.value}_{base}"
    return re.sub(r"\W+", "_", repr(e)).strip("_")[:32] or "expr"


def parse(sql: str):
    return Parser(sql).parse()
