"""Physical plan compilation and PDE-driven execution (paper §2.4, §3).

The logical plan compiles into RDD transformations (not MapReduce jobs).
Narrow chains (scan -> filter -> project -> partial aggregate -> local limit)
pipeline inside one task; blocking shuffle boundaries become explicit stages
the scheduler runs one at a time, which is where Partial DAG Execution
re-plans:

  * AGGREGATE: map stage materializes partial aggregates per hash bucket
    while gathering size stats; PDE coalesces buckets into the right number
    of reducers by greedy bin-packing (§3.1.2).
  * JOIN (AUTO): the optimizer orders pre-shuffle stages by the static
    "likely small" prior (§6.3.2), observes materialized sizes, and either
    broadcasts the small side (map join — the large table is never
    pre-shuffled) or falls back to a shuffle join with aligned buckets.
  * Map pruning (§3.5) removes partitions refuted by per-partition stats
    before ANY task launches.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .aggregate import (CompiledMerge, combine_colscan_stats, group_indices,
                        merge_aggregate, partial_aggregate)
from .batch import PartitionBatch
from .catalog import Catalog
from .columnar import Table
from .expr import (_FLIP_CMP, Between, BinOp, Cmp, Col, ColumnVal,
                   CompiledExprSet, Expr, ExprCompileError, Lit, _x64,
                   evaluate, split_conjuncts)
from .joins import broadcast_join, compile_probe, join_local
from .pde import (JoinChoice, PDEConfig, SkewShard, decide_join,
                  decide_parallelism, decide_pipelined_reduce,
                  decide_reduce_backend, decide_segment_backend,
                  decide_skew_join, decide_stage_fusion, likely_small_side)
from .plan import (AggFunc, AggregateNode, AggSpec, FilterNode, JoinNode,
                   JoinStrategy, LimitNode, Node, PipelineSegment,
                   ProjectNode, ScanNode, SortNode, fold_pipeline, optimize,
                   required_columns)
from .pruning import may_match
from .rdd import (RDD, MapPartitionsRDD, PipelinedShuffledRDD,
                  ShuffleDependency, ShuffledRDD, TaskContext,
                  ZipPartitionsRDD)
from .runtime import SharkContext
from .shuffle import (BucketedBatch, bucket_by_composite, bucket_by_hash,
                      single_bucket, split_bucket_pieces)
from .stats import (HeavyHitterAccumulator, SizeAccumulator, StageStats,
                    block_ndv)
from .types import DType


@dataclasses.dataclass
class ExecResult:
    batches: List[PartitionBatch]
    schema_names: List[str]
    # the executing Executor's per-query ExecMetrics — attached by the
    # server tier, where the executor itself is not reachable from a handle
    metrics: Optional["ExecMetrics"] = None

    def to_numpy(self) -> Dict[str, np.ndarray]:
        merged = PartitionBatch.concat(self.batches)
        return merged.decoded()

    @property
    def num_rows(self) -> int:
        return sum(b.num_rows for b in self.batches)


@dataclasses.dataclass
class JoinBoundaryDecision:
    """What PDE actually chose at ONE join shuffle boundary — recorded in
    execution order so tests (and explain tooling) can assert the runtime
    re-planning: strategy per boundary, observed sizes, reducer count, and
    any skew splits."""
    boundary: int                   # 0-based, in execution order
    strategy: str                   # broadcast | shuffle | copartition | empty
    build_side: Optional[str]       # broadcast: which input was broadcast
    # bytes per side: observed map-output sizes where the strategy
    # materialized them (broadcast small side, shuffle both sides);
    # catalog/hint estimates otherwise (copartition zips without
    # materializing anything, so there is nothing observed to report)
    left_bytes: float
    right_bytes: float
    num_reducers: int
    skewed_buckets: List[int]
    skew_shards: int                # total SkewShard reduce splits
    hot_keys: List[object]
    reason: str

    def describe(self) -> str:
        extra = ""
        if self.strategy == "broadcast":
            extra = f" build={self.build_side}"
        if self.skew_shards:
            extra += (f" skew={len(self.skewed_buckets)}bucket(s)/"
                      f"{self.skew_shards}shards hot={self.hot_keys[:2]}")
        return (f"join#{self.boundary}: {self.strategy}{extra} "
                f"l={self.left_bytes:.0f}B r={self.right_bytes:.0f}B "
                f"reducers={self.num_reducers}")


@dataclasses.dataclass
class SegmentRecord:
    """Runtime record of ONE PipelineSegment: which logical operators were
    fused, and — per executed partition — which backend route ran it
    (`numpy` oracle, generic fused `jit`, or a Pallas kernel).  Updated by
    worker threads; counters are guarded by the owning runner's lock."""
    table: str
    depth: int                      # logical operators folded into the segment
    consumer: str                   # collect | aggregate | sort | limit
    outputs: List[str]
    pred: Optional[str]             # repr of the folded predicate
    partitions: int = 0
    rows_in: int = 0
    rows_out: int = 0
    bytes_in: float = 0.0
    routes: Dict[str, int] = dataclasses.field(default_factory=dict)
    fallbacks: int = 0              # ExprCompileError -> numpy fallbacks
    kept_code_cols: List[str] = dataclasses.field(default_factory=list)
    # whole-stage fusion (DESIGN.md §14): partitions whose map side ran as
    # ONE stage program — segment + partial aggregate + radix bucketing with
    # no host seam before the shuffle.  Keyed by the inner kernel route
    # (colscan / groupby_mxu / jit / ...) so kernel-routing assertions keep
    # holding; every count here is ALSO counted in `routes` above.
    fused_routes: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def compiled_partitions(self) -> int:
        return sum(n for r, n in self.routes.items() if r != "numpy")

    @property
    def fused_partitions(self) -> int:
        return sum(self.fused_routes.values())

    def describe(self) -> str:
        routes = ",".join(f"{r}:{n}" for r, n in sorted(self.routes.items()))
        fused = ""
        if self.fused_routes:
            fused = " whole-stage=" + ",".join(
                f"{r}:{n}" for r, n in sorted(self.fused_routes.items()))
        return (f"segment[{self.table}->{self.consumer} depth={self.depth}] "
                f"parts={self.partitions} rows={self.rows_in}->"
                f"{self.rows_out} routes={{{routes}}}{fused}")


@dataclasses.dataclass
class ExecMetrics:
    """Observable decisions, for tests and EXPERIMENTS.md."""
    pruned_partitions: int = 0
    scanned_partitions: int = 0
    join_decisions: List[str] = dataclasses.field(default_factory=list)
    reducer_decisions: List[str] = dataclasses.field(default_factory=list)
    pipeline_decisions: List[str] = dataclasses.field(default_factory=list)
    join_boundaries: List[JoinBoundaryDecision] = dataclasses.field(
        default_factory=list)
    shuffled_bytes: float = 0.0
    broadcast_bytes: float = 0.0
    # compiled vectorized execution (DESIGN.md §10)
    segments: List[SegmentRecord] = dataclasses.field(default_factory=list)
    # standalone interpreted filter/project operators, split by whether the
    # operator chain bottoms out at a table scan (the tentpole invariant:
    # the scan path never runs interpreted operator-at-a-time)
    interpreted_ops: int = 0
    interpreted_scan_ops: int = 0
    # storage tier (DESIGN.md §12): per-query deltas of the StorageManager
    # counters — partitions spilled / bytes written while this query ran,
    # spill segments read back, warm recompressions taken
    spills: int = 0
    spill_bytes: float = 0.0
    spill_reads: int = 0
    recompressions: int = 0
    # cluster tier (DESIGN.md §13): partitions whose map side ran on the
    # device mesh, mesh size at dispatch, rows the cross-device exchange
    # shipped off their source device, and dispatches recomputed after a
    # device loss
    mesh_partitions: int = 0
    mesh_devices: int = 0
    mesh_shipped_rows: int = 0
    mesh_retries: int = 0
    # compiled analytics tier (DESIGN.md §15): one entry per training
    # iteration — {"iteration", "seconds", "rows", "routes"} — appended by
    # ml.trainer.IterativeTrainer next to its per-iteration SegmentRecords
    train_iterations: List[Dict] = dataclasses.field(default_factory=list)
    # resilience tier (DESIGN.md §16): faults the chaos engine injected
    # while this query ran — (site, ordinal, kind) tuples, replayable via
    # FaultSchedule.replay — and the scheduler's recovery-counter deltas
    # (retries / backoffs / app_probes / fast_fails / reaps)
    fault_trips: List[Tuple[str, int, str]] = dataclasses.field(
        default_factory=list)
    resilience_events: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def describe_joins(self) -> str:
        """One line per join boundary, execution order — the runtime twin of
        the static explain() output."""
        return "\n".join(b.describe() for b in self.join_boundaries)

    def describe_segments(self) -> str:
        return "\n".join(s.describe() for s in self.segments)

    def segment_routes(self) -> Dict[str, int]:
        """Aggregate partition counts per backend route across segments.
        Partitions that ran as a fused stage program additionally appear
        under the synthetic `whole-stage` key (they keep their inner kernel
        route in the per-route counts — dual recording, DESIGN.md §14)."""
        out: Dict[str, int] = {}
        for s in self.segments:
            for r, n in s.routes.items():
                out[r] = out.get(r, 0) + n
            if s.fused_routes:
                out["whole-stage"] = (out.get("whole-stage", 0)
                                      + s.fused_partitions)
        return out

    def compiled_partitions(self) -> int:
        return sum(s.compiled_partitions for s in self.segments)

    def fused_partitions(self) -> int:
        """Partitions whose map stage ran as one traced program."""
        return sum(s.fused_partitions for s in self.segments)


def _on_tpu() -> bool:
    from ..kernels.ops import on_tpu
    return on_tpu()


_FUSED_COLSCAN_JIT = None


def _fused_colscan_fns():
    """XLA-fused filter+aggregate for the CPU jit route — the same
    [count, sum, min, max] contract as the Pallas colscan/fused_decode_scan
    kernels, traced once per process and shared across queries.  float64
    accumulation, so it matches the numpy oracle to rounding.  DICT-coded
    filter columns take this same function on their int32 codes (value
    bounds translate to code bounds host-side), so there is no separate
    dict-gather variant."""
    global _FUSED_COLSCAN_JIT
    if _FUSED_COLSCAN_JIT is None:
        import jax
        import jax.numpy as jnp

        def scan(f, a, lo, hi):
            a = a.astype(jnp.float64)
            mask = (f >= lo) & (f <= hi)
            cnt = jnp.sum(mask.astype(jnp.float64))
            s = jnp.sum(jnp.where(mask, a, 0.0))
            mn = jnp.min(jnp.where(mask, a, jnp.inf))
            mx = jnp.max(jnp.where(mask, a, -jnp.inf))
            return jnp.stack([cnt, s, mn, mx])

        _FUSED_COLSCAN_JIT = jax.jit(scan)
    return _FUSED_COLSCAN_JIT


_BITPACK_COLSCAN_JIT: Dict[int, object] = {}


def _bitpack_colscan_fn(width: int):
    """XLA-fused unpack+filter+aggregate for BITPACK filter columns: the
    packed uint32 words are unpacked to biased codes INSIDE the traced
    program (per-lane shift/mask), compared against code bounds translated
    host-side (code = value - bias is order-preserving, same arithmetic as
    the FOR route), and the value column aggregated — the filter column
    never widens to its logical dtype.  Same [count, sum, min, max]
    contract as `_fused_colscan_fns`; the tail lanes of the last word are
    masked by the valid-row count.  One trace per bit width."""
    fn = _BITPACK_COLSCAN_JIT.get(width)
    if fn is None:
        import jax
        import jax.numpy as jnp

        per_word = 32 // width
        lane_mask = np.uint32((1 << width) - 1)

        def scan(words, a, n, lo, hi):
            shifts = jnp.arange(per_word, dtype=jnp.uint32) * jnp.uint32(width)
            codes = (words[:, None] >> shifts[None, :]) & lane_mask
            codes = codes.reshape(-1).astype(jnp.float64)
            valid = jnp.arange(codes.shape[0]) < n
            mask = (codes >= lo) & (codes <= hi) & valid
            a = a.astype(jnp.float64)
            cnt = jnp.sum(mask.astype(jnp.float64))
            s = jnp.sum(jnp.where(mask, a, 0.0))
            mn = jnp.min(jnp.where(mask, a, jnp.inf))
            mx = jnp.max(jnp.where(mask, a, -jnp.inf))
            return jnp.stack([cnt, s, mn, mx])

        fn = jax.jit(scan)
        _BITPACK_COLSCAN_JIT[width] = fn
    return fn


def _code_groupby(codes: np.ndarray, vals: np.ndarray,
                  num_groups: int) -> np.ndarray:
    """Code-space small-NDV group-by for the CPU route: per-group
    [sum, count] by direct bincount on dictionary codes — the same contract
    as the Pallas groupby_mxu kernel, without the np.unique pass the
    interpreted path pays (codes ARE group ids when the dictionary is the
    group space).  float64 accumulation (numpy-oracle parity)."""
    sums = np.bincount(codes, weights=np.asarray(vals, np.float64),
                       minlength=num_groups)
    cnts = np.bincount(codes, minlength=num_groups).astype(np.float64)
    return np.stack([sums, cnts], axis=1)


def _range_of_pred(pred: Optional[Expr], schema) -> Optional[Tuple]:
    """Normalize a predicate to a single-column closed range (col, lo, hi)
    when every conjunct is a literal comparison / BETWEEN on ONE numeric
    column — the shape the fused colscan kernel evaluates.  Strict bounds
    tighten to closed ones (next representable value / next integer)."""
    if pred is None:
        return None
    col: Optional[str] = None
    lo, hi = -np.inf, np.inf

    def col_of(name: str) -> bool:
        nonlocal col
        if col is None:
            col = name
        return col == name

    def is_int(name: str) -> bool:
        return schema.dtype(name) in (DType.INT32, DType.INT64)

    for c in split_conjuncts(pred):
        if isinstance(c, Between):
            if not (isinstance(c.child, Col) and _is_num(c.lo)
                    and _is_num(c.hi) and col_of(c.child.name)):
                return None
            lo, hi = max(lo, c.lo), min(hi, c.hi)
            continue
        if not isinstance(c, Cmp):
            return None
        if isinstance(c.left, Col) and isinstance(c.right, Lit):
            name, op, v = c.left.name, c.op, c.right.value
        elif isinstance(c.right, Col) and isinstance(c.left, Lit):
            if c.op not in _FLIP_CMP or c.op == "!=":
                return None
            name, op, v = c.right.name, _FLIP_CMP[c.op], c.left.value
        else:
            return None
        if not (_is_num(v) and col_of(name)):
            return None
        if op == "=":
            lo, hi = max(lo, v), min(hi, v)
        elif op == ">=":
            lo = max(lo, v)
        elif op == "<=":
            hi = min(hi, v)
        elif op == ">":
            lo = max(lo, float(np.floor(v)) + 1 if is_int(name)
                     else float(np.nextafter(v, np.inf)))
        elif op == "<":
            hi = min(hi, float(np.ceil(v)) - 1 if is_int(name)
                     else float(np.nextafter(v, -np.inf)))
        else:
            return None
    if col is None or schema.dtype(col) == DType.STRING:
        return None
    return col, float(lo), float(hi)


def _is_num(v) -> bool:
    return isinstance(v, (int, float, np.integer, np.floating)) \
        and not isinstance(v, bool)


class SegmentRunner:
    """Executes one PipelineSegment per partition.

    The whole scan→filter→project chain is ONE function of the scan batch:
      * `jit` route — predicate + computed projections trace into a single
        jitted columnar program (expr.CompiledExprSet); dictionary-coded
        columns are evaluated on int32 codes and only decoded at the
        segment boundary, after the filter, when logical values are needed;
      * kernel routes — filter+aggregate segments lower to the Pallas
        colscan / fused_decode_scan kernels, small-group aggregates to
        groupby_mxu (interpret mode on CPU, float64 accumulation so the
        oracle parity holds to rounding);
      * `numpy` route — the evaluate()-based oracle, used for tiny
        partitions, `backend="numpy"` sessions, and ExprCompileError
        fallbacks.
    Per-partition choices are recorded in the shared SegmentRecord."""

    def __init__(self, seg: PipelineSegment, schema, backend: str,
                 cfg: PDEConfig, record: SegmentRecord):
        self.seg = seg
        self.schema = schema              # scan schema (dtype lookups)
        self.backend = backend
        self.cfg = cfg
        self.record = record
        self._lock = threading.Lock()
        self._exprset: Optional[CompiledExprSet] = None
        self._exprset_failed = False
        self._agg_shape_cache: Dict[Tuple, Optional[Tuple]] = {}
        # outputs: None = all scan columns pass through
        self.outputs = seg.exprs

    # -- bookkeeping -----------------------------------------------------------

    def _note(self, route: str, rows_in: int, rows_out: int,
              bytes_in: float, fallback: bool = False,
              kept_codes: Sequence[str] = (), fused: bool = False) -> None:
        rec = self.record
        with self._lock:
            rec.partitions += 1
            rec.rows_in += rows_in
            rec.rows_out += rows_out
            rec.bytes_in += bytes_in
            rec.routes[route] = rec.routes.get(route, 0) + 1
            rec.fallbacks += int(fallback)
            if fused:
                rec.fused_routes[route] = rec.fused_routes.get(route, 0) + 1
            for n in kept_codes:
                if n not in rec.kept_code_cols:
                    rec.kept_code_cols.append(n)

    def _note_fused(self, route: str) -> None:
        """Promote the partition most recently noted under `route` to the
        whole-stage tally — used when the fused wrapper sits OUTSIDE the
        routed call (exchange bucketing around run())."""
        rec = self.record
        with self._lock:
            rec.fused_routes[route] = rec.fused_routes.get(route, 0) + 1

    def _note_route(self, route: str) -> None:
        """Tally an auxiliary route taken ON TOP of the partition's segment
        route — e.g. the Pallas topk_similarity selection that replaces the
        host lexsort after a similarity segment ran under `jit`.  Routes
        only; partition/row counts stay with the primary `_note`."""
        rec = self.record
        with self._lock:
            rec.routes[route] = rec.routes.get(route, 0) + 1

    # -- compiled expression set ----------------------------------------------

    def _computed_exprs(self) -> List[Expr]:
        exprs: List[Expr] = []
        if self.seg.pred is not None:
            exprs.append(self.seg.pred)
        if self.outputs is not None:
            exprs.extend(e for _, e in self.outputs
                         if not isinstance(e, Col))
        return exprs

    def _get_exprset(self) -> Optional[CompiledExprSet]:
        if self._exprset_failed:
            raise ExprCompileError("segment marked uncompilable")
        if self._exprset is None:
            exprs = self._computed_exprs()
            if not exprs:
                return None
            try:
                self._exprset = CompiledExprSet(
                    exprs, compressed_domain=self.cfg.compressed_domain)
            except ExprCompileError:
                self._exprset_failed = True
                raise
        return self._exprset

    # -- routes ----------------------------------------------------------------

    def run(self, batch: PartitionBatch) -> PartitionBatch:
        """Plain narrow segment: filter + project, one fused step."""
        return self.run_routed(batch)[0]

    def run_routed(self, batch: PartitionBatch,
                   fused: bool = False) -> Tuple[PartitionBatch, str]:
        """run() returning (output, route) — the whole-stage wrapper
        (DESIGN.md §14) needs the route to decide whether the host seam
        was kept (numpy oracle) or the output may ship pre-bucketed.
        `fused=True` tallies compiled partitions under fused_routes."""
        rows = batch.num_rows
        nbytes = float(batch.nbytes)
        if self.backend == "numpy":
            out = self._run_numpy(batch)
            self._note("numpy", rows, out.num_rows, nbytes)
            return out, "numpy"
        decision = decide_segment_backend(rows, None, None, _on_tpu(),
                                          self.cfg)
        if decision.route == "numpy":
            out = self._run_numpy(batch)
            self._note("numpy", rows, out.num_rows, nbytes)
            return out, "numpy"
        try:
            out, kept = self._run_jit(batch)
            self._note("jit", rows, out.num_rows, nbytes, kept_codes=kept,
                       fused=fused)
            return out, "jit"
        except ExprCompileError:
            self._exprset_failed = True
            out = self._run_numpy(batch)
            self._note("numpy", rows, out.num_rows, nbytes, fallback=True)
            return out, "numpy"

    def _run_numpy(self, batch: PartitionBatch) -> PartitionBatch:
        """The evaluate()-based oracle — operator semantics identical to the
        pre-segmentation interpreted executor."""
        if self.seg.pred is not None:
            ctx = {n: batch.col(n) for n in batch.names()}
            mask = np.asarray(evaluate(self.seg.pred, ctx).arr)
            if mask.ndim == 0:
                mask = np.full(batch.num_rows, bool(mask))
            batch = batch.mask(mask)
        if self.outputs is None:
            return batch
        ctx = {n: batch.col(n) for n in batch.names()}
        out: Dict[str, ColumnVal] = {}
        for name, e in self.outputs:
            v = evaluate(e, ctx)
            arr = v.arr
            if np.isscalar(arr) or (hasattr(arr, "shape")
                                    and arr.shape == ()):
                arr = np.full(batch.num_rows, arr)
                v = ColumnVal(arr, v.sdict, v.sorted_dict)
            out[name] = v
        return PartitionBatch(out)

    def _run_jit(self, batch: PartitionBatch
                 ) -> Tuple[PartitionBatch, List[str]]:
        ctx = {n: batch.col(n) for n in batch.names()}
        exprset = self._get_exprset()
        results = exprset(ctx) if exprset is not None else []
        i = 0
        mask = None
        if self.seg.pred is not None:
            mask = np.asarray(results[0].arr)
            if mask.ndim == 0:
                mask = np.full(batch.num_rows, bool(mask))
            i = 1
        kept: List[str] = []
        out: Dict[str, ColumnVal] = {}
        n_out = int(mask.sum()) if mask is not None else batch.num_rows
        if self.outputs is None:
            for name in batch.names():
                out[name] = self._mask_source(batch.col(name), mask, name,
                                              kept)
        else:
            for name, e in self.outputs:
                if isinstance(e, Col):
                    out[name] = self._mask_source(batch.col(e.name), mask,
                                                  name, kept)
                    continue
                v = results[i]
                i += 1
                arr = v.arr
                if np.isscalar(arr) or (hasattr(arr, "shape")
                                        and arr.shape == ()):
                    out[name] = ColumnVal(np.full(n_out, arr), v.sdict,
                                          v.sorted_dict)
                    continue
                arr = np.asarray(arr)
                if mask is not None:
                    arr = arr[mask]
                out[name] = ColumnVal(arr, v.sdict, v.sorted_dict)
        return PartitionBatch(out), kept

    def _mask_source(self, v: ColumnVal, mask: Optional[np.ndarray],
                     out_name: str, kept: List[str]) -> ColumnVal:
        """Filter a pass-through column.  Strings stay dictionary codes
        (sdict shared, so a projection that merely renames a dict-encoded
        column never forces decode); DICT-encoded numerics are filtered in
        code space and decoded at the boundary (gather after the mask —
        `dictdecode` fused where logical values are first required)."""
        if mask is None:
            return v        # pass through, lazily decoded if never touched
        if v.is_string:
            kept.append(out_name)
            return ColumnVal(np.asarray(v.arr)[mask], v.sdict, v.sorted_dict)
        if v.block is not None and not v.materialized:
            cs = v.block.code_space()
            if cs is not None:
                codes, d = cs
                kept.append(out_name)
                return ColumnVal(d[codes[mask]])
            if self.cfg.compressed_domain:
                fs = v.block.frame_space()
                if fs is not None:
                    # FOR codes filtered narrow; only survivors widen
                    codes, bias = fs
                    kept.append(out_name)
                    orig = v.block.enc.orig_dtype
                    sel = codes[mask].astype(np.int64) + int(bias)
                    return ColumnVal(sel.astype(orig))
        return ColumnVal(np.asarray(v.arr)[mask])

    # -- fused aggregation -----------------------------------------------------

    def _source_col(self, name: str) -> Optional[str]:
        """Scan column behind segment output `name`, if it is a bare Col."""
        if self.outputs is None:
            return name if name in self.schema else None
        for n, e in self.outputs:
            if n == name:
                return e.name if isinstance(e, Col) else None
        return None

    def _agg_kernel_shape(self, group_cols: Sequence[str],
                          aggs: Sequence[AggSpec]) -> Optional[Tuple]:
        """Plan-level kernel eligibility of this segment+aggregate shape.
        Returns ("colscan", filter_col, lo, hi, value_col) or
        ("groupby_mxu", group_col, value_col) or None."""
        key = (tuple(group_cols), tuple(id(a) for a in aggs))
        if key in self._agg_shape_cache:
            return self._agg_shape_cache[key]
        shape = self._agg_kernel_shape_uncached(list(group_cols), list(aggs))
        self._agg_shape_cache[key] = shape
        return shape

    def _agg_kernel_shape_uncached(self, group_cols, aggs):
        value_col: Optional[str] = None
        for a in aggs:
            if a.func == AggFunc.COUNT_DISTINCT:
                return None
            if a.func == AggFunc.COUNT and a.arg is None:
                continue
            if a.arg is None or not isinstance(a.arg, Col):
                return None
            src = self._source_col(a.arg.name)
            if src is None or self.schema.dtype(src) == DType.STRING:
                return None
            if (self.schema.dtype(src) == DType.INT64
                    and a.func in (AggFunc.SUM, AggFunc.MIN, AggFunc.MAX)):
                # int64 aggregates keep integer accumulators (exact above
                # 2^53); the float-accumulating kernel shapes would round
                return None
            if value_col is None:
                value_col = src
            elif value_col != src:
                return None     # one value column per kernel pass
        if not group_cols:
            rng = _range_of_pred(self.seg.pred, self.schema)
            if rng is None:
                return None     # the kernel shape is filter+aggregate
            fcol, lo, hi = rng
            if value_col is None:
                value_col = fcol    # COUNT-only: count the filter column
            return ("colscan", fcol, lo, hi, value_col)
        if len(group_cols) != 1 or self.seg.pred is not None:
            return None
        if any(a.func in (AggFunc.MIN, AggFunc.MAX) for a in aggs):
            return None     # groupby_mxu produces [sum, count] only
        gsrc = self._source_col(group_cols[0])
        if gsrc is None:
            return None
        return ("groupby_mxu", gsrc, value_col)

    def run_aggregate(self, batch: PartitionBatch,
                      group_cols: Sequence[str],
                      aggs: Sequence[AggSpec]) -> PartitionBatch:
        """Fused map side of an aggregation: segment + partial aggregate in
        one step, lowered to a Pallas kernel when the shape and the
        partition statistics allow."""
        return self._aggregate_routed(batch, group_cols, aggs)[0]

    def _aggregate_routed(self, batch: PartitionBatch,
                          group_cols: Sequence[str],
                          aggs: Sequence[AggSpec], fused: bool = False,
                          force_compiled: bool = False
                          ) -> Tuple[PartitionBatch, str]:
        """run_aggregate() returning (partial states, route) — the
        whole-stage wrapper (DESIGN.md §14) consumes the route to decide
        whether the output ships pre-bucketed.  `force_compiled` upgrades a
        small-partition numpy decision to the jit route (the differential
        grid forces fusion on tiny seeds); empty partitions stay numpy —
        jnp.min/max of a zero-length array is undefined."""
        rows = batch.num_rows
        nbytes = float(batch.nbytes)
        if self.backend == "numpy":
            out = partial_aggregate(self._run_numpy(batch), group_cols, aggs)
            self._note("numpy", rows, out.num_rows, nbytes)
            return out, "numpy"
        shape = self._agg_kernel_shape(group_cols, aggs)
        ndv = None
        if shape is not None and shape[0] == "groupby_mxu":
            gblock = batch.col(shape[1]).block
            ndv = block_ndv(gblock) if gblock is not None else None
            if ndv is None:
                shape = None
        decision = decide_segment_backend(
            rows, shape[0] if shape is not None else None, ndv, _on_tpu(),
            self.cfg)
        route = decision.route
        if route == "numpy" and force_compiled and rows > 0:
            route = "jit"
        try:
            if route == "colscan":
                out, route = self._run_colscan(batch, shape, aggs,
                                               pallas=True)
            elif route == "groupby_mxu":
                out, route = self._run_groupby(batch, shape, group_cols,
                                               aggs, ndv, kernel=True)
            elif route == "jit":
                if shape is not None and shape[0] == "colscan":
                    # CPU fast path: the same fused filter+aggregate as the
                    # Pallas kernel, as one XLA program — no mask batch is
                    # ever materialized
                    out, route = self._run_colscan(batch, shape, aggs,
                                                   pallas=False)
                elif shape is not None and shape[0] == "groupby_mxu":
                    # CPU fast path for the small-NDV group-by shape: group
                    # directly on dictionary codes — no np.unique pass
                    out, route = self._run_groupby(batch, shape, group_cols,
                                                   aggs, ndv, kernel=False)
                else:
                    filtered, _ = self._run_jit(batch)
                    out = partial_aggregate(filtered, group_cols, aggs)
            else:
                out = partial_aggregate(self._run_numpy(batch), group_cols,
                                        aggs)
        except ExprCompileError:
            self._exprset_failed = True
            out = partial_aggregate(self._run_numpy(batch), group_cols, aggs)
            self._note("numpy", rows, out.num_rows, nbytes, fallback=True)
            return out, "numpy"
        self._note(route, rows, out.num_rows, nbytes,
                   fused=fused and route != "numpy")
        return out, route

    def _acc_dtype(self) -> str:
        # float32 is the TPU-native accumulator; CPU interpret mode matches
        # the numpy oracle to rounding with float64
        return "float32" if _on_tpu() else "float64"

    def _run_colscan(self, batch: PartitionBatch, shape, aggs,
                     pallas: bool) -> Tuple[PartitionBatch, str]:
        from ..kernels import ops as kernel_ops
        _, fcol, lo, hi, vcol = shape
        fv = batch.col(fcol)
        if (self.cfg.compressed_domain and not pallas
                and fv.block is not None and not fv.materialized
                and not fv.is_string and fv.block.run_space() is not None):
            # run-level RLE scan: predicate on run VALUES, never widened
            return self._run_rle_scan(batch, fcol, lo, hi, vcol, aggs)
        vals = np.asarray(batch.col(vcol).arr)
        coded = (fv.block is not None and not fv.materialized
                 and fv.block.code_space() is not None)
        framed = (not coded and self.cfg.compressed_domain
                  and fv.block is not None and not fv.materialized
                  and fv.block.frame_space() is not None)
        packed = (not coded and not framed and not pallas
                  and self.cfg.compressed_domain
                  and fv.block is not None and not fv.materialized
                  and fv.block.pack_space() is not None)
        with _x64():
            if pallas and coded:
                codes, d = fv.block.code_space()
                # decode fused into the scan: the filter column is read as
                # codes, its dictionary gathered inside the kernel
                res = self._pallas_colscan_chunked(
                    lambda c, v: kernel_ops.fused_decode_scan(
                        c, d, v, lo, hi, acc_dtype=self._acc_dtype()),
                    np.asarray(codes), vals)
                route = "fused_decode_scan"
            elif pallas:
                res = self._pallas_colscan_chunked(
                    lambda f, v: kernel_ops.colscan(
                        f, v, lo, hi, acc_dtype=self._acc_dtype()),
                    np.asarray(fv.arr), vals)
                route = "colscan"
            elif coded:
                # value bounds translate to CODE bounds host-side (sorted
                # dictionary, same trick as expr._Lowering._dict_cmp): the
                # scan compares int32 codes — no per-row dictionary gather,
                # which is what made this route lose to numpy (the
                # BENCH_exec_engine filter_agg_dict regression)
                codes, d = fv.block.code_space()
                clo = float(np.searchsorted(d, lo, side="left"))
                chi = float(np.searchsorted(d, hi, side="right") - 1)
                res = _fused_colscan_fns()(codes, vals,
                                              np.float64(clo),
                                              np.float64(chi))
                route = "jit-colscan"
            elif framed:
                # frame-of-reference: value bounds translate to CODE bounds
                # by pure integer arithmetic (code = value - bias is order-
                # preserving); the scan compares the narrow code lane and
                # the filter column never widens (DESIGN.md §12)
                codes, bias = fv.block.frame_space()
                clo = (float(int(math.ceil(lo)) - int(bias))
                       if math.isfinite(lo) else -np.inf)
                chi = (float(int(math.floor(hi)) - int(bias))
                       if math.isfinite(hi) else np.inf)
                res = _fused_colscan_fns()(codes, vals,
                                              np.float64(clo),
                                              np.float64(chi))
                route = "for-colscan"
            elif packed:
                # bit-packed: value bounds translate to biased-code bounds
                # host-side exactly like FOR, and the packed words unpack
                # inside the fused scan — no host-side widening of the
                # filter column (DESIGN.md §12)
                ps = fv.block.pack_space()
                if ps is None:      # recompressed since the route check
                    raise ExprCompileError("BITPACK words gone (recompressed)")
                words, width, bias, nrows = ps
                clo = (float(int(math.ceil(lo)) - int(bias))
                       if math.isfinite(lo) else -np.inf)
                chi = (float(int(math.floor(hi)) - int(bias))
                       if math.isfinite(hi) else np.inf)
                pad = words.shape[0] * (32 // width) - nrows
                a = np.asarray(vals, np.float64)
                if pad:
                    a = np.pad(a, (0, pad))
                res = _bitpack_colscan_fn(width)(words, a, np.int64(nrows),
                                                 np.float64(clo),
                                                 np.float64(chi))
                route = "bitpack-colscan"
            else:
                res = _fused_colscan_fns()(np.asarray(fv.arr), vals,
                                              np.float64(lo), np.float64(hi))
                route = "jit-colscan"
            res = np.asarray(res)
        cnt, s, mn, mx = (float(res[0]), float(res[1]), float(res[2]),
                          float(res[3]))
        int_sum = np.issubdtype(np.asarray(vals).dtype, np.integer)
        return self._colscan_result(aggs, cnt, s, mn, mx, int_sum), route

    def _pallas_colscan_chunked(self, fn, fcol: np.ndarray, vals: np.ndarray):
        """Double-buffered Pallas colscan (DESIGN.md §14): large partitions
        split into DOUBLE_BUFFER chunks, each chunk's dispatch overlapping
        the previous chunk's compute (JAX async dispatch), with the per-
        chunk [count, sum, min, max] states combined in the same float64
        rounding class as one pass.  Small partitions take one call."""
        from ..kernels import ops as kernel_ops
        chunk = kernel_ops.DOUBLE_BUFFER["chunk_rows"]
        n = len(fcol)
        if n < 2 * chunk:
            return fn(fcol, vals)
        states = kernel_ops.double_buffer_map(
            lambda fv_pair: fn(fv_pair[0], fv_pair[1]),
            [(fcol[i:i + chunk], vals[i:i + chunk])
             for i in range(0, n, chunk)])
        cnt, s, mn, mx = combine_colscan_stats(
            [np.asarray(st) for st in states])
        return np.array([cnt, s, mn, mx], np.float64)

    def _run_rle_scan(self, batch: PartitionBatch, fcol: str, lo, hi,
                      vcol: str, aggs) -> Tuple[PartitionBatch, str]:
        """Run-level RLE scan (DESIGN.md §12): the predicate is evaluated
        once per RUN on the run values.  When the aggregate reads the same
        column the whole filter+aggregate is run-level (O(runs), never
        expanded); otherwise the run mask expands via np.repeat and only
        the value column is touched row-wise.  float64 accumulation
        (numpy-oracle parity)."""
        rs = batch.col(fcol).block.run_space()
        if rs is None:      # recompressed since the route check
            raise ExprCompileError("RLE runs gone (recompressed)")
        run_values, run_lengths = rs
        rl = np.asarray(run_lengths, np.int64)
        rmask = (run_values >= lo) & (run_values <= hi)
        if vcol == fcol:
            sel_v = np.asarray(run_values[rmask], np.float64)
            sel_l = rl[rmask]
            cnt = float(sel_l.sum())
            s = float((sel_v * sel_l).sum())
            mn = float(sel_v.min()) if sel_v.size else float("inf")
            mx = float(sel_v.max()) if sel_v.size else float("-inf")
            int_sum = np.issubdtype(np.asarray(run_values).dtype, np.integer)
        else:
            mask = np.repeat(rmask, rl)
            vraw = np.asarray(batch.col(vcol).arr)
            int_sum = np.issubdtype(vraw.dtype, np.integer)
            sel = vraw[mask].astype(np.float64)
            cnt = float(sel.shape[0])
            s = float(sel.sum())
            mn = float(sel.min()) if sel.size else float("inf")
            mx = float(sel.max()) if sel.size else float("-inf")
        return self._colscan_result(aggs, cnt, s, mn, mx, int_sum), "rle-scan"

    @staticmethod
    def _colscan_result(aggs, cnt: float, s: float, mn: float, mx: float,
                        int_sum: bool) -> PartitionBatch:
        out: Dict[str, ColumnVal] = {}
        for spec in aggs:
            sc = _agg_state_cols(spec)
            if spec.func == AggFunc.COUNT:
                out[sc[0]] = ColumnVal(np.array([cnt], np.int64))
            elif spec.func == AggFunc.SUM:
                arr = np.array([s], np.int64 if int_sum else np.float64)
                out[sc[0]] = ColumnVal(arr)
            elif spec.func == AggFunc.AVG:
                out[sc[0]] = ColumnVal(np.array([s], np.float64))
                out[sc[1]] = ColumnVal(np.array([cnt], np.int64))
            elif spec.func == AggFunc.MIN:
                out[sc[0]] = ColumnVal(np.array([mn], np.float64))
            elif spec.func == AggFunc.MAX:
                out[sc[0]] = ColumnVal(np.array([mx], np.float64))
            else:
                raise ExprCompileError(str(spec.func))
        return PartitionBatch(out)

    def _run_groupby(self, batch: PartitionBatch, shape, group_cols, aggs,
                     ndv: int, kernel: bool = True
                     ) -> Tuple[PartitionBatch, str]:
        from ..kernels import ops as kernel_ops
        _, gsrc, vcol = shape
        gv = batch.col(gsrc)
        if gv.is_string:
            codes = np.asarray(gv.arr)
            reps: Optional[np.ndarray] = None      # group i == code i
            num_groups = len(gv.sdict)
        else:
            cs = (gv.block.code_space()
                  if gv.block is not None and not gv.materialized else None)
            if cs is not None:
                codes, reps = cs
                num_groups = len(reps)
            else:
                reps, codes = np.unique(np.asarray(gv.arr),
                                        return_inverse=True)
                num_groups = len(reps)
        vals = (np.asarray(batch.col(vcol).arr) if vcol is not None
                else np.zeros(batch.num_rows))
        int_sum = vcol is not None and np.issubdtype(
            np.asarray(vals).dtype, np.integer)
        with _x64():
            if kernel:
                res = np.asarray(kernel_ops.groupby_sum(
                    codes, vals, num_groups, acc_dtype=self._acc_dtype()))
                route = "groupby_mxu"
            else:
                res = _code_groupby(np.asarray(codes), vals, num_groups)
                route = "code-groupby"
        sums = res[:, 0]
        cnts = np.round(res[:, 1]).astype(np.int64)
        sel = cnts > 0      # partial states carry only present groups
        out: Dict[str, ColumnVal] = {}
        gname = group_cols[0]
        if gv.is_string:
            out[gname] = ColumnVal(
                np.flatnonzero(sel).astype(np.int32), gv.sdict, True)
        else:
            out[gname] = ColumnVal(reps[sel])
        for spec in aggs:
            sc = _agg_state_cols(spec)
            if spec.func == AggFunc.COUNT:
                out[sc[0]] = ColumnVal(cnts[sel])
            elif spec.func == AggFunc.SUM:
                arr = (np.round(sums[sel]).astype(np.int64) if int_sum
                       else sums[sel].astype(np.float64))
                out[sc[0]] = ColumnVal(arr)
            elif spec.func == AggFunc.AVG:
                out[sc[0]] = ColumnVal(sums[sel].astype(np.float64))
                out[sc[1]] = ColumnVal(cnts[sel])
            else:
                raise ExprCompileError(str(spec.func))
        return PartitionBatch(out), route


def _agg_state_cols(spec: AggSpec) -> List[str]:
    from .aggregate import _state_cols
    return _state_cols(spec)


class ReduceRunner:
    """Routes ONE reduce-side operator — the final aggregation merge or the
    local join probe — per reduce task (DESIGN.md §11), mirroring what
    SegmentRunner does for scan-side segments:

      * `numpy` route — merge_aggregate / _match_pairs, the interpreted
        oracle (tiny bucket groups, `backend="numpy"` sessions, fallbacks);
      * `jit` route — aggregate.CompiledMerge (one fused segmented-reduce
        program over all aggregate states) / joins.CompiledProbe (the
        sort-searchsorted probe as two cached jitted programs);
      * `segmented_merge` route — the Pallas kernel, per float state
        column, on TPU/forced routes.

    Every per-task choice lands in the shared SegmentRecord, so
    ExecMetrics.segments exposes the reduce side exactly like the scan
    side."""

    def __init__(self, backend: str, cfg: PDEConfig, record: SegmentRecord):
        self.backend = backend
        self.cfg = cfg
        self.record = record
        self._lock = threading.Lock()
        self._merge: Optional[CompiledMerge] = None
        self._merge_failed = False

    def _note(self, route: str, rows_in: int, rows_out: int,
              bytes_in: float, fallback: bool = False) -> None:
        rec = self.record
        with self._lock:
            rec.partitions += 1
            rec.rows_in += rows_in
            rec.rows_out += rows_out
            rec.bytes_in += bytes_in
            rec.routes[route] = rec.routes.get(route, 0) + 1
            rec.fallbacks += int(fallback)

    # -- final aggregation merge ----------------------------------------------

    def _kernel_merge_eligible(self, batch: PartitionBatch,
                               aggs: Sequence[AggSpec]) -> bool:
        """The Pallas segmented_merge accumulates in float: only merges
        whose every state column is float-typed (and present) qualify —
        integer states stay on the int64-exact jitted route."""
        for spec in aggs:
            if spec.func == AggFunc.COUNT_DISTINCT:
                return False
            for sc in _agg_state_cols(spec):
                if sc not in batch.cols:
                    return False
                if not np.issubdtype(
                        np.asarray(batch.col(sc).arr).dtype, np.floating):
                    return False
        return True

    def merge(self, batch: PartitionBatch, group_cols: Sequence[str],
              aggs: Sequence[AggSpec]) -> PartitionBatch:
        rows = batch.num_rows
        nbytes = float(batch.nbytes)
        if self.backend == "numpy":
            out = merge_aggregate(batch, group_cols, aggs)
            self._note("numpy", rows, out.num_rows, nbytes)
            return out
        kernel_eligible = ("segmented_merge"
                           if self._kernel_merge_eligible(batch, aggs)
                           else None)
        decision = decide_reduce_backend(rows, kernel_eligible, None,
                                         _on_tpu(), self.cfg)
        route = decision.route
        try:
            if route == "segmented_merge":
                out, route = self._merge_kernel(batch, group_cols, aggs)
            elif route == "jit":
                out = self._merge_jit(batch, group_cols, aggs)
            else:
                out = merge_aggregate(batch, group_cols, aggs)
        except ExprCompileError:
            out = merge_aggregate(batch, group_cols, aggs)
            self._note("numpy", rows, out.num_rows, nbytes, fallback=True)
            return out
        self._note(route, rows, out.num_rows, nbytes)
        return out

    def _merge_jit(self, batch: PartitionBatch, group_cols, aggs
                   ) -> PartitionBatch:
        if self._merge_failed:
            raise ExprCompileError("merge marked uncompilable")
        if self._merge is None:
            try:
                self._merge = CompiledMerge(group_cols, aggs)
            except ExprCompileError:
                self._merge_failed = True
                raise
        return self._merge(batch)

    def _merge_kernel(self, batch: PartitionBatch, group_cols, aggs
                      ) -> Tuple[PartitionBatch, str]:
        """Host grouping + one Pallas segmented_merge pass per state
        column; each spec consumes the lane(s) it needs (assembly shared
        with the oracle in aggregate.merge_from_lanes)."""
        from ..kernels import ops as kernel_ops
        from .aggregate import merge_from_lanes
        keys = [np.asarray(batch.col(g).arr) for g in group_cols]
        n = batch.num_rows
        first, inverse = group_indices(keys) if group_cols else \
            (np.zeros(1, np.int64), np.zeros(n, np.int64))
        num_groups = len(first)
        # re-decide with the NOW-KNOWN group cardinality: the NDV policy
        # lives in decide_reduce_backend, not here
        redecide = decide_reduce_backend(n, "segmented_merge", num_groups,
                                         _on_tpu(), self.cfg)
        if num_groups == 0 or redecide.route != "segmented_merge":
            return self._merge_jit(batch, group_cols, aggs), "jit"
        acc = "float32" if _on_tpu() else "float64"
        lanes: Dict[str, np.ndarray] = {}
        with _x64():
            for spec in aggs:
                for sc in _agg_state_cols(spec):
                    if sc in lanes:
                        continue
                    lanes[sc] = np.asarray(kernel_ops.segmented_merge(
                        inverse, np.asarray(batch.col(sc).arr),
                        num_groups, acc_dtype=acc))
        return (merge_from_lanes(batch, group_cols, aggs, first, lanes),
                "segmented_merge")

    # -- local join probe -----------------------------------------------------

    def join(self, lbatch: PartitionBatch, rbatch: PartitionBatch,
             lkey: str, rkey: str, how: str) -> PartitionBatch:
        rows = lbatch.num_rows + rbatch.num_rows
        nbytes = float(lbatch.nbytes + rbatch.nbytes)
        if self.backend == "numpy":
            out = join_local(lbatch, rbatch, lkey, rkey, how)
            self._note("numpy", rows, out.num_rows, nbytes)
            return out
        decision = decide_reduce_backend(rows, None, None, _on_tpu(),
                                         self.cfg)
        if decision.route == "numpy":
            out = join_local(lbatch, rbatch, lkey, rkey, how)
            self._note("numpy", rows, out.num_rows, nbytes)
            return out
        try:
            out = join_local(lbatch, rbatch, lkey, rkey, how,
                             matcher=compile_probe())
            self._note("jit", rows, out.num_rows, nbytes)
        except TypeError:
            # non-numeric key layout the probe cannot take: oracle fallback
            out = join_local(lbatch, rbatch, lkey, rkey, how)
            self._note("numpy", rows, out.num_rows, nbytes, fallback=True)
        return out


class JoinShuffledRDD(RDD):
    """Reduce side of a shuffle join.  Each split is either a plain bucket
    group (fetch the group from BOTH parents' map outputs, join locally) or
    a `SkewShard`: one stripe of a heavy-hitter bucket, where the sharded
    (probe) side fetches only map outputs shard, shard+n, ... and the other
    side's bucket is replicated to each stripe — the skew-splitting half of
    §3.1.2.  Across the stripes every probe map output is read exactly
    once, so splitting adds no fetch amplification on the big side, and a
    recomputed-after-failure stripe deterministically sees the same rows
    (map tasks are deterministic)."""

    def __init__(self, ldep: ShuffleDependency, rdep: ShuffleDependency,
                 bucket_groups: List[object], lkey: str, rkey: str,
                 how: str = "inner", runner: Optional["ReduceRunner"] = None):
        self.ldep, self.rdep = ldep, rdep
        self.bucket_groups = bucket_groups
        self.lkey, self.rkey, self.how = lkey, rkey, how
        self.runner = runner
        super().__init__(ldep.parent.ctx, len(bucket_groups), [ldep, rdep])

    def _fetch(self, dep: ShuffleDependency, buckets: List[int],
               maps=None) -> PartitionBatch:
        pieces = self.ctx.block_manager.fetch_shuffle(
            dep.shuffle_id, dep.parent.num_partitions, buckets, maps)
        return PartitionBatch.concat(pieces)

    def _join(self, l: PartitionBatch, r: PartitionBatch) -> PartitionBatch:
        if self.runner is not None:
            return self.runner.join(l, r, self.lkey, self.rkey, self.how)
        return join_local(l, r, self.lkey, self.rkey, self.how)

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        spec = self.bucket_groups[split]
        if isinstance(spec, SkewShard):
            sdep, odep = ((self.ldep, self.rdep)
                          if spec.shard_side == "left"
                          else (self.rdep, self.ldep))
            stripe = range(spec.shard, sdep.parent.num_partitions,
                           spec.num_shards)
            sharded = self._fetch(sdep, [spec.bucket], list(stripe))
            other = self._fetch(odep, [spec.bucket])
            l, r = ((sharded, other) if spec.shard_side == "left"
                    else (other, sharded))
            return self._join(l, r)
        l = self._fetch(self.ldep, spec)
        r = self._fetch(self.rdep, spec)
        return self._join(l, r)


@dataclasses.dataclass
class Compiled:
    rdd: RDD
    names: List[str]
    table: Optional[Table] = None            # set when rdd is a bare scan
    scan_filtered: bool = False              # a filter applies at/below scan
    size_hint: Optional[float] = None        # bytes prior (for join ordering)
    # the SegmentRunner producing this RDD's partitions, when the RDD is a
    # segment map — join boundaries use it to tally fused exchanges under
    # the whole-stage route (DESIGN.md §14)
    runner: Optional["SegmentRunner"] = None


class ScanCache:
    """Shared registry of *cached* TableScanRDDs (server tier, DESIGN.md §6).

    Plain sessions build a fresh TableScanRDD per query, so its RDD id — and
    therefore its block-manager keys — never repeat and nothing is reused.
    The server shares one ScanCache across all per-query Executors: scans of
    the same (table, version, columns, surviving partitions) resolve to ONE
    RDD marked `.cache()`, so materialized scan blocks are shared across
    queries and clients, live under the MemoryManager's budget, and are
    recomputed from the column store on eviction miss."""

    def __init__(self):
        self._lock = threading.RLock()
        self._rdds: Dict[Tuple, RDD] = {}

    def get_or_create(self, ctx: SharkContext, table: Table, version: int,
                      cols: List[str], selected: List[int]) -> RDD:
        key = (table.name, version, tuple(cols), tuple(selected))
        with self._lock:
            rdd = self._rdds.get(key)
            if rdd is None:
                # a version bump invalidates all older scans of this table;
                # drop their RDDs and any blocks they pinned in the store
                for k in [k for k in self._rdds
                          if k[0] == table.name and k[1] != version]:
                    stale = self._rdds.pop(k)
                    stale.unpersist()
                rdd = ctx.scan(table, cols, selected).cache()
                self._rdds[key] = rdd
            return rdd

    def clear(self) -> None:
        with self._lock:
            for rdd in self._rdds.values():
                rdd.unpersist()
            self._rdds.clear()


class Executor:
    def __init__(self, ctx: SharkContext, catalog: Catalog,
                 pde: PDEConfig = PDEConfig(), enable_pde: bool = True,
                 enable_map_pruning: bool = True,
                 default_shuffle_buckets: int = 64,
                 scan_cache: Optional[ScanCache] = None,
                 backend: str = "compiled", exchange: str = "coded",
                 mesh=None, stage_fusion: str = "on"):
        assert backend in ("compiled", "numpy"), backend
        assert exchange in ("coded", "decoded"), exchange
        assert stage_fusion in ("on", "off", "force"), stage_fusion
        self.ctx = ctx
        self.catalog = catalog
        # cluster.MeshContext (DESIGN.md §13.1): when set, eligible
        # aggregate map sides run sharded over the device mesh and the
        # compiled exchange ships buckets across devices.  Physical layer
        # only — plans, explain() and fingerprints never see it.
        self.mesh = mesh
        self.pde = pde
        self.enable_pde = enable_pde
        self.enable_map_pruning = enable_map_pruning
        self.default_shuffle_buckets = default_shuffle_buckets
        self.scan_cache = scan_cache
        # "compiled": pipeline segments pick jit/Pallas routes per partition;
        # "numpy": segments run the evaluate() oracle (differential testing)
        self.backend = backend
        # "coded": dictionary-preserving exchange — string columns cross
        # shuffles as (codes, partition dictionary) and the reduce side
        # merge-remaps dictionaries (DESIGN.md §11); "decoded": the legacy
        # exchange that materializes raw strings before hashing, kept as
        # the semantic oracle for differential tests and shuffle_bench
        self.exchange = exchange
        # whole-stage fusion (DESIGN.md §14): "on" fuses eligible map
        # stages into one traced program ending in pre-bucketed shuffle
        # output; "force" bypasses the PDE row threshold (test grids);
        # "off" is the segment-at-a-time semantic oracle.  Fusion requires
        # the compiled backend and the dictionary-preserving exchange —
        # the decoded exchange's string re-materialization IS a host seam,
        # and the numpy oracle must keep every seam — so it self-disables
        # otherwise.
        self._fusion_mode = (stage_fusion
                             if backend == "compiled" and exchange == "coded"
                             else "off")
        # map-side radix bucketing through the Pallas kernel (TPU/forced);
        # fixed per executor so every map task of a shuffle agrees
        self._radix_kernel = (backend == "compiled"
                              and (pde.segment_force_kernels or _on_tpu()))
        # shuffle ids this executor created: the server releases their map
        # outputs from the block store once the query completes
        self.created_shuffles: List[int] = []
        self.metrics = ExecMetrics()

    def _prep_exchange(self, rdd: RDD) -> RDD:
        """Map-side exchange prep.  The legacy ('decoded') exchange
        materializes raw strings so the shuffle hashes raw values; the
        dictionary-preserving exchange ships (codes, partition-local
        dictionary) through the shuffle block untouched — hashing runs on
        the dictionary (one crc32 per distinct value) and the reduce side
        unifies dictionaries instead of decoding."""
        if self.exchange == "decoded":
            return rdd.map_partitions(lambda s, b: b.decode_strings())
        return rdd

    def _reduce_runner(self, consumer: str, outputs: List[str]
                       ) -> ReduceRunner:
        """Reduce-side runner + metrics record for one shuffle boundary."""
        record = SegmentRecord(table="<exchange>", depth=1,
                               consumer=consumer, outputs=outputs, pred=None)
        self.metrics.segments.append(record)
        return ReduceRunner(self.backend, self.pde, record)

    def _new_shuffle(self, parent: RDD, num_buckets: int, partitioner,
                     **kw) -> ShuffleDependency:
        dep = ShuffleDependency(parent, num_buckets, partitioner, **kw)
        self.created_shuffles.append(dep.shuffle_id)
        return dep

    # ---------------------------------------------------------------- public

    def _storage(self):
        mm = self.ctx.block_manager.memory_manager
        return getattr(mm, "storage", None) if mm is not None else None

    def execute(self, plan: Node) -> ExecResult:
        self.metrics = ExecMetrics()
        storage = self._storage()
        before = storage.stats() if storage is not None else None
        chaos = getattr(self.ctx, "chaos", None)
        trips_before = chaos.trip_count() if chaos is not None else 0
        res_before = dict(self.ctx.scheduler.resilience_counters)
        plan = optimize(plan, self.catalog)
        compiled = self._compile(plan)
        batches = self.ctx.scheduler.run_result_stage(compiled.rdd)
        if storage is not None:
            after = storage.stats()
            m = self.metrics
            m.spills = after["spills"] - before["spills"]
            m.spill_bytes = (after["spill_write_bytes"]
                             - before["spill_write_bytes"])
            m.spill_reads = after["spill_reads"] - before["spill_reads"]
            m.recompressions = (after["recompressions"]
                                - before["recompressions"])
        if chaos is not None:
            self.metrics.fault_trips = [tuple(t) for t in
                                        chaos.trips_since(trips_before)]
        res_after = self.ctx.scheduler.resilience_counters
        self.metrics.resilience_events = {
            k: res_after[k] - res_before.get(k, 0)
            for k in res_after if res_after[k] - res_before.get(k, 0)}
        return ExecResult(batches, compiled.names)

    # ------------------------------------------------------------- internals

    def _compile(self, node: Node) -> Compiled:
        if isinstance(node, ScanNode):
            return self._compile_scan(node, pred=None)
        if isinstance(node, (FilterNode, ProjectNode)):
            seg = fold_pipeline(node)
            if seg is not None:
                return self._compile_segment(seg)
        if isinstance(node, FilterNode):
            return self._compile_filter(node)
        if isinstance(node, ProjectNode):
            return self._compile_project(node)
        if isinstance(node, AggregateNode):
            return self._compile_aggregate(node)
        if isinstance(node, JoinNode):
            return self._compile_join(node)
        if isinstance(node, SortNode):
            return self._compile_sort(node, limit=None)
        if isinstance(node, LimitNode):
            return self._compile_limit(node)
        raise NotImplementedError(type(node))

    def _compile_scan(self, node: ScanNode, pred: Optional[Expr],
                      columns: Optional[Sequence[str]] = None) -> Compiled:
        table, version = self.catalog.get_versioned(node.table)
        selected = list(range(table.num_partitions))
        if pred is not None and self.enable_map_pruning:
            kept = []
            for i in selected:
                if may_match(pred, table.partitions[i].stats()):
                    kept.append(i)
            self.metrics.pruned_partitions += len(selected) - len(kept)
            selected = kept
        self.metrics.scanned_partitions += len(selected)
        cols = list(columns) if columns is not None else list(table.schema.names)
        if self.scan_cache is not None:
            rdd = self.scan_cache.get_or_create(
                self.ctx, table, version, cols, selected)
        else:
            rdd = self.ctx.scan(table, cols, selected)
        return Compiled(rdd, cols, table=table,
                        scan_filtered=pred is not None,
                        size_hint=float(table.nbytes))

    # -- compiled pipeline segments (DESIGN.md §10) ---------------------------

    def _make_runner(self, seg: PipelineSegment, consumer: str
                     ) -> Tuple[Compiled, SegmentRunner]:
        """Compile the scan under a segment (map pruning against the folded
        predicate, §3.5) and build its per-partition runner + metrics
        record."""
        scanc = self._compile_scan(seg.scan, seg.pred)
        record = SegmentRecord(
            table=seg.scan.table, depth=seg.depth, consumer=consumer,
            outputs=seg.output_names(self.catalog),
            pred=repr(seg.pred) if seg.pred is not None else None)
        self.metrics.segments.append(record)
        runner = SegmentRunner(seg, seg.scan.schema(self.catalog),
                               self.backend, self.pde, record)
        return scanc, runner

    def _segment_source_rdd(self, scanc: Compiled, seg: PipelineSegment,
                            ensure_nonempty: bool) -> RDD:
        """The scan RDD a segment maps over; blocking consumers (aggregate /
        sort / limit) need at least one partition even when map pruning
        refuted all of them, so substitute a zero-row scan-schema batch."""
        if scanc.rdd.num_partitions > 0 or not ensure_nonempty:
            return scanc.rdd
        schema = seg.scan.schema(self.catalog)
        return self.ctx.parallelize([_empty_batch(list(schema.names),
                                                  schema)])

    def _compile_segment(self, seg: PipelineSegment,
                         consumer: str = "collect") -> Compiled:
        scanc, runner = self._make_runner(seg, consumer)
        rdd = scanc.rdd.map_partitions(lambda s, b: runner.run(b))
        return Compiled(rdd, seg.output_names(self.catalog), None,
                        seg.pred is not None, scanc.size_hint, runner=runner)

    # -- interpreted operators (only ever above shuffle boundaries now) -------

    def _note_interpreted(self, node: Node) -> None:
        self.metrics.interpreted_ops += 1
        n = node
        while isinstance(n, (FilterNode, ProjectNode)):
            n = n.child
        if isinstance(n, ScanNode):
            # the tentpole invariant: this must never happen — scan-path
            # chains always fold into a PipelineSegment
            self.metrics.interpreted_scan_ops += 1

    def _compile_filter(self, node: FilterNode) -> Compiled:
        pred = node.pred
        self._note_interpreted(node)
        if isinstance(node.child, ScanNode):
            child = self._compile_scan(node.child, pred)
        else:
            child = self._compile(node.child)
            child = Compiled(child.rdd, child.names, child.table, True,
                             child.size_hint)

        def apply_filter(split: int, batch: PartitionBatch) -> PartitionBatch:
            ctx = {n: batch.col(n) for n in batch.names()}
            mask = np.asarray(evaluate(pred, ctx).arr)
            return batch.mask(mask)

        rdd = child.rdd.map_partitions(apply_filter)
        return Compiled(rdd, child.names, None, True, child.size_hint)

    def _compile_project(self, node: ProjectNode) -> Compiled:
        self._note_interpreted(node)
        child = self._compile(node.child)
        exprs = node.exprs

        def apply_project(split: int, batch: PartitionBatch) -> PartitionBatch:
            ctx = {n: batch.col(n) for n in batch.names()}
            out = {}
            for name, e in exprs:
                v = evaluate(e, ctx)
                arr = v.arr
                if np.isscalar(arr) or (hasattr(arr, "shape") and arr.shape == ()):
                    arr = np.full(batch.num_rows, arr)
                    v = ColumnVal(arr, v.sdict, v.sorted_dict)
                out[name] = v
            return PartitionBatch(out)

        rdd = child.rdd.map_partitions(apply_project)
        return Compiled(rdd, [n for n, _ in exprs], None, child.scan_filtered,
                        child.size_hint)

    def _materialize_empty(self, compiled: Compiled, child_node: Node
                           ) -> Compiled:
        """Blocking operators (aggregate/sort/limit) need at least one input
        partition to produce their (possibly identity-valued) output; a
        scan whose partitions were ALL map-pruned compiles to a 0-partition
        RDD, so substitute a single zero-row batch with the right schema."""
        if compiled.rdd.num_partitions > 0:
            return compiled
        schema = child_node.schema(self.catalog)
        rdd = self.ctx.parallelize([_empty_batch(compiled.names, schema)])
        return Compiled(rdd, compiled.names, None, compiled.scan_filtered,
                        compiled.size_hint)

    # -- aggregation ---------------------------------------------------------

    def _compile_aggregate(self, node: AggregateNode) -> Compiled:
        group_cols = node.group_by
        aggs = node.aggs
        names = group_cols + [a.out_name for a in aggs]

        seg = fold_pipeline(node.child)
        partitioner = None
        if seg is not None:
            # fused map side: scan→filter→project→partial-aggregate is ONE
            # function per partition, kernel-lowered when the shape allows
            scanc, runner = self._make_runner(seg, "aggregate")
            src = self._segment_source_rdd(scanc, seg, ensure_nonempty=True)
            mesh_partials = None
            if self.mesh is not None and self.backend == "compiled":
                # cluster tier: run the map side sharded over the device
                # mesh; the partial states feed the SAME shuffle/merge
                # reduce below, so semantics and row order match the
                # single-host path by construction
                mesh_partials = self._mesh_partials(src, runner, group_cols,
                                                    aggs)
            if mesh_partials is not None:
                map_rdd = self._prep_exchange(
                    self.ctx.parallelize(mesh_partials))
            elif self._fusion_mode != "off":
                # whole-stage (DESIGN.md §14): the bucket layout is fixed
                # BEFORE the map fn exists because radix bucketing runs
                # inside the stage program — one traced call per partition
                # from scan to pre-bucketed shuffle pieces
                num_buckets, partitioner = self._bucket_layout(
                    group_cols, src.num_partitions)
                from .stage import StageRunner
                stage = StageRunner(runner, partitioner, num_buckets,
                                    self._fusion_mode, self.pde)
                map_rdd = src.map_partitions(
                    lambda s, b: stage.run_aggregate_stage(b, group_cols,
                                                           aggs))
            else:
                map_rdd = self._prep_exchange(src.map_partitions(
                    lambda s, b: runner.run_aggregate(b, group_cols, aggs)))
        else:
            child = self._materialize_empty(self._compile(node.child),
                                            node.child)

            def map_side(split: int, batch: PartitionBatch) -> PartitionBatch:
                return partial_aggregate(batch, group_cols, aggs)

            map_rdd = self._prep_exchange(child.rdd.map_partitions(map_side))

        if partitioner is None:
            num_buckets, partitioner = self._bucket_layout(
                group_cols, map_rdd.num_partitions)

        dep = self._new_shuffle(
            map_rdd, num_buckets, partitioner,
            accumulators=lambda: [SizeAccumulator(num_buckets)] + (
                [HeavyHitterAccumulator(group_cols[0])] if group_cols else []))

        if (not group_cols and self._fusion_mode != "off"
                and self._pipeline_gate(dep)):
            # single-bucket boundary: no PDE re-planning consumes the map
            # stats, so the reduce can start as soon as pieces land —
            # pipelined map→reduce overlap (DESIGN.md §14)
            rrunner = self._reduce_runner("merge_aggregate", names)
            reduce_fn = lambda split, b: rrunner.merge(b, group_cols, aggs)
            return self._pipelined_single_reduce(dep, names, reduce_fn)

        stats = self.ctx.scheduler.run_map_stage(dep)
        self.metrics.shuffled_bytes += stats.total_output_bytes()

        if self.enable_pde and group_cols:
            decision = decide_parallelism(stats, num_buckets, self.pde)
            self.metrics.reducer_decisions.append(decision.reason)
            groups = decision.bucket_groups
        else:
            groups = [[b] for b in range(num_buckets)]

        rrunner = self._reduce_runner("merge_aggregate", names)
        reduce_fn = lambda split, b: rrunner.merge(b, group_cols, aggs)
        rdd = ShuffledRDD(dep, groups, reduce_fn)
        return Compiled(rdd, names)

    def _bucket_layout(self, group_cols: Sequence[str], num_maps: int):
        """(num_buckets, partitioner) for an aggregation boundary — split
        out so the fused path can fix the layout before building map fns;
        byte-identical to the legacy inline computation."""
        if not group_cols:
            return 1, single_bucket()
        num_buckets = max(self.default_shuffle_buckets, num_maps)
        return num_buckets, bucket_by_composite(list(group_cols), num_buckets,
                                                kernel=self._radix_kernel)

    def _pipeline_gate(self, dep: ShuffleDependency) -> bool:
        """Admission check for the map→reduce overlap (DESIGN.md §14): the
        boundary pipelines only when the executor pool has slots free of
        map tasks; otherwise it takes the sequential pull fetch over the
        SAME shuffle blocks (the fused map side is unaffected)."""
        d = decide_pipelined_reduce(dep.parent.num_partitions,
                                    self.ctx.scheduler.max_threads,
                                    self._fusion_mode, self.pde)
        self.metrics.pipeline_decisions.append(d.reason)
        return d.route == "pipelined"

    def _pipelined_single_reduce(self, dep: ShuffleDependency,
                                 names: List[str], reduce_fn) -> Compiled:
        """Run a single-bucket boundary with the pipelined scheduler: the
        reduce thread consumes map pieces as they land, and the result RDD
        serves the precomputed batch (falling back to the ordinary fetch
        path if the pipelined attempt lost a race with a failure)."""
        groups = [[0]]
        pipe_fn = (lambda split, pieces:
                   reduce_fn(split, PartitionBatch.concat(pieces)))
        stats, pre = self.ctx.scheduler.run_map_stage_pipelined(
            dep, groups, pipe_fn)
        self.metrics.shuffled_bytes += stats.total_output_bytes()
        rdd = PipelinedShuffledRDD(dep, groups, reduce_fn)
        rdd.offer_precomputed(pre)
        return Compiled(rdd, names)

    # -- mesh-sharded map side (cluster tier, DESIGN.md §13.1) ----------------

    def _mesh_partials(self, src: RDD, runner: "SegmentRunner",
                       group_cols, aggs) -> Optional[List[PartitionBatch]]:
        """Compute the aggregate's partial states on the device mesh.

        Eligibility is the kernel shape check the single-host routes use
        (`_agg_kernel_shape`) narrowed to numeric columns; anything else
        returns None and the host map side runs — a silent, always-correct
        fallback.  The colscan shape shards (device × partition) with no
        collective; the group-by shape runs the compiled radix exchange
        across devices and partial-aggregates each device's received rows.
        Either way the output is a list of partial-state batches that feed
        the standard shuffle + merge, so the final rows (and their order)
        are produced by exactly the single-host reduce path.
        """
        shape = runner._agg_kernel_shape(group_cols, aggs)
        if shape is None:
            return None
        from ..cluster import shard_exec
        mesh = self.mesh
        before = mesh.retries
        batches = self.ctx.scheduler.run_result_stage(src)
        try:
            if shape[0] == "colscan":
                _, fcol, lo, hi, vcol = shape
                fvals, avals, int_sum = [], [], False
                for b in batches:
                    fv, vv = b.col(fcol), b.col(vcol)
                    if fv.is_string or vv.is_string:
                        return None
                    varr = np.asarray(vv.arr)
                    int_sum = int_sum or np.issubdtype(varr.dtype, np.integer)
                    fvals.append(np.asarray(fv.arr, np.float64))
                    avals.append(varr.astype(np.float64, copy=False))
                stats, report = shard_exec.mesh_colscan(
                    mesh, fvals, avals, float(lo), float(hi))
                out = []
                for (cnt, s, mn, mx), b in zip(stats, batches):
                    out.append(runner._colscan_result(
                        aggs, float(cnt), float(s), float(mn), float(mx),
                        int_sum))
                    runner._note("mesh-colscan", b.num_rows, 1,
                                 float(b.nbytes))
            else:                                   # ("groupby_mxu", g, v)
                _, gsrc, vcol = shape
                keys, vals = [], ([] if vcol is not None else None)
                kdt = None
                for b in batches:
                    gv = b.col(gsrc)
                    karr = np.asarray(gv.arr)
                    if gv.is_string or not np.issubdtype(karr.dtype,
                                                         np.integer):
                        return None     # exchange hashes integer key lanes
                    kdt = karr.dtype
                    keys.append(karr)
                    if vcol is not None:
                        vv = b.col(vcol)
                        if vv.is_string:
                            return None
                        vals.append(np.asarray(vv.arr))
                per_dev, report = shard_exec.mesh_group_exchange(
                    mesh, keys, vals)
                self.metrics.mesh_shipped_rows += report["shipped_rows"]
                out = []
                for kd, vd in per_dev:
                    cols = {group_cols[0]: ColumnVal(
                        kd.astype(kdt, copy=False))}
                    for a in aggs:
                        if a.arg is not None:
                            cols[a.arg.name] = ColumnVal(vd)
                    pb = partial_aggregate(PartitionBatch(cols), group_cols,
                                           aggs)
                    runner._note("mesh-exchange", int(kd.shape[0]),
                                 pb.num_rows, float(kd.nbytes))
                    out.append(pb)
        except ExprCompileError:
            return None
        self.metrics.mesh_partitions += len(batches)
        self.metrics.mesh_devices = report["devices"]
        self.metrics.mesh_retries += mesh.retries - before
        return out

    # -- joins ----------------------------------------------------------------

    def _fetch_shuffle_recovering(self, dep, buckets) -> List[PartitionBatch]:
        """Master-side shuffle fetch with lineage recovery: a worker lost
        between the map stage and this fetch (e.g. mid multi-way join) only
        costs recomputation of its map tasks (§2.3)."""
        from .runtime import FetchFailed
        retries = self.ctx.scheduler.max_stage_retries
        for attempt in range(retries + 1):
            try:
                return self.ctx.block_manager.fetch_shuffle(
                    dep.shuffle_id, dep.parent.num_partitions, buckets)
            except FetchFailed as ff:
                if attempt == retries:
                    raise RuntimeError(
                        "exceeded max stage retries fetching broadcast "
                        "side") from ff
                self.ctx.scheduler._recover_map_outputs(dep, ff.missing_maps)
        raise AssertionError("unreachable")

    def _record_boundary(self, strategy: str, build_side: Optional[str],
                         left_bytes: float, right_bytes: float,
                         num_reducers: int, reason: str,
                         skewed_buckets: Optional[List[int]] = None,
                         skew_shards: int = 0,
                         hot_keys: Optional[List[object]] = None
                         ) -> JoinBoundaryDecision:
        dec = JoinBoundaryDecision(
            boundary=len(self.metrics.join_boundaries), strategy=strategy,
            build_side=build_side, left_bytes=left_bytes,
            right_bytes=right_bytes, num_reducers=num_reducers,
            skewed_buckets=skewed_buckets or [], skew_shards=skew_shards,
            hot_keys=hot_keys or [], reason=reason)
        self.metrics.join_boundaries.append(dec)
        return dec

    def _fused_exchange(self, side: Compiled, partitioner,
                        num_buckets: int) -> RDD:
        """Map-side exchange for one join input.  When the side is a
        compiled segment map and whole-stage fusion is on, bucket
        assignment + per-bucket slicing chain into the segment's map task
        (MapPartitionsRDD composes in-task): the task ships a BucketedBatch
        of finished pieces, skipping the scheduler's host-assembly copy
        (DESIGN.md §14).  `partitioner` MUST be the same closure the
        ShuffleDependency carries, so fused and seam-by-seam pieces are
        byte-identical.  Falls back to the legacy prep for interpreted /
        non-segment sides and small partitions.

        Bare unfiltered scans have no SegmentRunner (the PR-8 legacy-seam
        gap): synthesize a pass-through segment for them so their exchange
        buckets in-task too — observable as a `<table>->exchange-passthrough`
        record in ExecMetrics.segments."""
        if self._fusion_mode == "off":
            return self._prep_exchange(side.rdd)
        if side.runner is None:
            if side.table is None:
                return self._prep_exchange(side.rdd)
            side = dataclasses.replace(
                side, runner=self._passthrough_runner(side.table))
        runner = side.runner
        mode = self._fusion_mode
        cfg = self.pde

        def bucketize(split: int, batch: PartitionBatch):
            d = decide_stage_fusion(batch.num_rows, mode, runner.backend,
                                    "coded", cfg)
            if d.route != "whole-stage":
                return batch
            bucket_of = partitioner(batch)
            pieces = split_bucket_pieces(batch, bucket_of, num_buckets)
            if getattr(runner, "_passthrough", False):
                # synthesized bare-scan segment: no run_routed() ever fires,
                # so tally the partition here for the route assertion
                runner._note("passthrough", batch.num_rows, batch.num_rows,
                             float(batch.nbytes))
            runner._note_fused("exchange")
            return BucketedBatch(pieces)

        return side.rdd.map_partitions(bucketize)

    def _passthrough_runner(self, table: Table) -> SegmentRunner:
        """Compiled pass-through segment for a bare unfiltered scan feeding
        an exchange: no predicate, no projections — it exists so the fused
        exchange can bucket the scan batch in-task instead of falling back
        to the scheduler's host-assembly seam (PR-8 follow-up)."""
        seg = PipelineSegment(ScanNode(table.name), None, None, 0)
        record = SegmentRecord(
            table=table.name, depth=0, consumer="exchange-passthrough",
            outputs=list(table.schema.names), pred=None)
        self.metrics.segments.append(record)
        runner = SegmentRunner(seg, table.schema, self.backend, self.pde,
                               record)
        runner._passthrough = True
        return runner

    def _compile_join(self, node: JoinNode) -> Compiled:
        """One join boundary.  Because _compile recurses left-then-right and
        every boundary runs its map stage(s) eagerly, an N-way join is
        re-planned boundary by boundary: each decision below sees the
        *materialized* output of all upstream joins, not compile-time
        guesses (paper §3.1 — the DAG is altered while the query runs)."""
        left = self._compile(node.left)
        right = self._compile(node.right)
        lkey, rkey = node.left_key, node.right_key
        names = left.names + [n if n not in left.names else n + "_r"
                              for n in right.names]
        hint = ((left.size_hint or 0.0) + (right.size_hint or 0.0)
                if (left.size_hint is not None or right.size_hint is not None)
                else None)
        # the output of this boundary is a materialized intermediate: its
        # selectivity has been OBSERVED, so it must not carry the
        # "filtered, likely small" prior into the next boundary
        filtered = False

        # a side with zero compiled partitions (map pruning refuted every
        # partition, §3.5): the inner join is provably empty — skip the
        # boundary entirely; a left join keeps left rows, zero-padding the
        # right columns (the dialect's NULL emulation)
        if left.rdd.num_partitions == 0 or right.rdd.num_partitions == 0:
            self.metrics.join_decisions.append(
                "pruned-empty side: join short-circuited")
            self._record_boundary("empty", None, 0.0, 0.0, 0,
                                  "a side was pruned to zero partitions")
            if node.how == "inner" or left.rdd.num_partitions == 0:
                return Compiled(self.ctx.parallelize([]), names)
            rschema = node.right.schema(self.catalog)
            lnames = list(left.names)

            def pad_right(split: int, batch: PartitionBatch) -> PartitionBatch:
                out = dict(batch.cols)
                n = batch.num_rows
                for f in rschema.fields:
                    name = f.name if f.name not in lnames else f.name + "_r"
                    empty = _empty_batch([f.name], rschema).cols[f.name]
                    arr = np.zeros(n, np.asarray(empty.arr).dtype)
                    sdict = (np.array([""]) if empty.sdict is not None
                             else None)
                    out[name] = ColumnVal(arr, sdict, True)
                return PartitionBatch(out)

            return Compiled(left.rdd.map_partitions(pad_right), names,
                            size_hint=hint)

        # §3.4 co-partitioned tables: zip corresponding partitions, no shuffle
        if (node.strategy in (JoinStrategy.AUTO, JoinStrategy.COPARTITION)
                and left.table is not None and right.table is not None
                and left.table.co_partitioned_with(right.table, lkey, rkey)):
            self.metrics.join_decisions.append("copartition: zip, no shuffle")
            self._record_boundary(
                "copartition", None, left.size_hint or 0.0,
                right.size_hint or 0.0, left.rdd.num_partitions,
                "co-partitioned zip, no shuffle")
            zrunner = self._reduce_runner("join_probe", names)
            rdd = ZipPartitionsRDD(
                left.rdd, right.rdd,
                lambda s, l, r: zrunner.join(l, r, lkey, rkey, node.how))
            return Compiled(rdd, names, size_hint=hint, scan_filtered=filtered)

        if node.strategy == JoinStrategy.BROADCAST:
            return self._broadcast(left, right, lkey, rkey, node.how,
                                   "planner-forced broadcast", names,
                                   broadcast_side="right")
        if node.strategy == JoinStrategy.SHUFFLE or not self.enable_pde:
            return self._shuffle_join(left, right, lkey, rkey, node.how,
                                      names, note="planner-forced shuffle")

        # ---- AUTO: Partial DAG Execution (§3.1.1 + §6.3.2) ----
        num_buckets = max(self.default_shuffle_buckets,
                          left.rdd.num_partitions,
                          right.rdd.num_partitions)
        first = likely_small_side(left.size_hint, right.size_hint,
                                  left.scan_filtered, right.scan_filtered)
        first = first or "right"
        a, b = (left, right) if first == "left" else (right, left)
        akey, bkey = (lkey, rkey) if first == "left" else (rkey, lkey)

        apart = bucket_by_hash(akey, num_buckets, kernel=self._radix_kernel)
        adep = self._new_shuffle(
            self._fused_exchange(a, apart, num_buckets), num_buckets, apart,
            accumulators=lambda: [SizeAccumulator(num_buckets),
                                  HeavyHitterAccumulator(akey)])
        astats = self.ctx.scheduler.run_map_stage(adep)
        decision = decide_join(astats, None, self.pde)
        # broadcasting the non-preserved side of an outer join is invalid
        broadcast_ok = node.how == "inner" or (node.how == "left"
                                               and first == "right")
        if decision.choice == JoinChoice.BROADCAST_LEFT and broadcast_ok:
            # observed small: broadcast `a`, never pre-shuffle `b` (the 3x
            # win — the large table sees exactly one wave of map tasks).
            self.metrics.join_decisions.append(
                f"PDE map-join: broadcast {'left' if first == 'left' else 'right'} "
                f"({decision.left_bytes:.0f}B observed); large side not shuffled")
            small = PartitionBatch.concat(
                self._fetch_shuffle_recovering(adep, list(range(num_buckets))))
            self.metrics.broadcast_bytes += small.nbytes
            observed = float(small.nbytes)
            lb, rb = ((observed, right.size_hint or 0.0) if first == "left"
                      else (left.size_hint or 0.0, observed))
            self._record_boundary(
                "broadcast", first, lb, rb, b.rdd.num_partitions,
                decision.reason)
            brunner = self._reduce_runner("join_probe", names)
            if first == "left":
                # inner join is symmetric; emit left-major column order
                rdd = b.rdd.map_partitions(
                    lambda s, big: _reorder(brunner.join(
                        small, big, akey, bkey, node.how), names))
            else:
                rdd = b.rdd.map_partitions(
                    lambda s, big: _reorder(brunner.join(
                        big, small, bkey, akey, node.how), names))
            return Compiled(rdd, names, size_hint=hint, scan_filtered=filtered)

        # not small: pre-shuffle the other side too, aligned buckets
        self.metrics.join_decisions.append(
            f"PDE shuffle-join: first side observed {decision.left_bytes:.0f}B "
            f"> threshold; shuffling both")
        self.metrics.shuffled_bytes += astats.total_output_bytes()
        bpart = bucket_by_hash(bkey, num_buckets, kernel=self._radix_kernel)
        bdep = self._new_shuffle(
            self._fused_exchange(b, bpart, num_buckets), num_buckets, bpart,
            accumulators=lambda: [SizeAccumulator(num_buckets),
                                  HeavyHitterAccumulator(bkey)])
        bstats = self.ctx.scheduler.run_map_stage(bdep)
        self.metrics.shuffled_bytes += bstats.total_output_bytes()

        lstats, rstats = (astats, bstats) if first == "left" else (bstats, astats)
        ldep, rdep = (adep, bdep) if first == "left" else (bdep, adep)
        sdecision = decide_skew_join(lstats, rstats, num_buckets, node.how,
                                     self.pde,
                                     left_maps=ldep.parent.num_partitions,
                                     right_maps=rdep.parent.num_partitions)
        self.metrics.reducer_decisions.append(sdecision.reason)
        self._record_boundary(
            "shuffle", None, lstats.total_output_bytes(),
            rstats.total_output_bytes(), sdecision.num_reducers,
            sdecision.reason, skewed_buckets=sdecision.skewed_buckets,
            skew_shards=sum(1 for s in sdecision.splits
                            if isinstance(s, SkewShard)),
            hot_keys=sdecision.hot_keys)

        rdd = JoinShuffledRDD(ldep, rdep, sdecision.splits, lkey, rkey,
                              node.how,
                              runner=self._reduce_runner("join_probe", names))
        return Compiled(rdd, names, size_hint=hint, scan_filtered=filtered)

    def _broadcast(self, left: Compiled, right: Compiled, lkey: str,
                   rkey: str, how: str, note: str, names: List[str],
                   broadcast_side: str) -> Compiled:
        small, big = (right, left) if broadcast_side == "right" else (left, right)
        skey, bkey = (rkey, lkey) if broadcast_side == "right" else (lkey, rkey)
        self.metrics.join_decisions.append(note)
        collected = PartitionBatch.concat(
            self.ctx.scheduler.run_result_stage(
                self._prep_exchange(small.rdd)))
        self.metrics.broadcast_bytes += collected.nbytes
        observed = float(collected.nbytes)
        lb, rb = ((observed, big.size_hint or 0.0)
                  if broadcast_side == "left"
                  else (big.size_hint or 0.0, observed))
        self._record_boundary("broadcast", broadcast_side, lb, rb,
                              big.rdd.num_partitions, note)
        brunner = self._reduce_runner("join_probe", names)
        if broadcast_side == "right":
            rdd = big.rdd.map_partitions(
                lambda s, part: _reorder(
                    brunner.join(part, collected, bkey, skey, how), names))
        else:
            rdd = big.rdd.map_partitions(
                lambda s, part: _reorder(
                    brunner.join(collected, part, skey, bkey, how), names))
        return Compiled(rdd, names)

    def _shuffle_join(self, left: Compiled, right: Compiled, lkey: str,
                      rkey: str, how: str, names: List[str],
                      note: str) -> Compiled:
        num_buckets = max(self.default_shuffle_buckets,
                          left.rdd.num_partitions, right.rdd.num_partitions)
        self.metrics.join_decisions.append(note)
        lpart = bucket_by_hash(lkey, num_buckets, kernel=self._radix_kernel)
        ldep = self._new_shuffle(
            self._fused_exchange(left, lpart, num_buckets), num_buckets,
            lpart, accumulators=lambda: [SizeAccumulator(num_buckets)])
        rpart = bucket_by_hash(rkey, num_buckets, kernel=self._radix_kernel)
        rdep = self._new_shuffle(
            self._fused_exchange(right, rpart, num_buckets), num_buckets,
            rpart, accumulators=lambda: [SizeAccumulator(num_buckets)])
        ls = self.ctx.scheduler.run_map_stage(ldep)
        rs = self.ctx.scheduler.run_map_stage(rdep)
        self.metrics.shuffled_bytes += (ls.total_output_bytes()
                                        + rs.total_output_bytes())
        self._record_boundary("shuffle", None, ls.total_output_bytes(),
                              rs.total_output_bytes(), num_buckets, note)
        groups = [[b] for b in range(num_buckets)]
        rdd = JoinShuffledRDD(ldep, rdep, groups, lkey, rkey, how,
                              runner=self._reduce_runner("join_probe", names))
        return Compiled(rdd, names)

    # -- sort / limit ----------------------------------------------------------

    def _compile_sort(self, node: SortNode, limit: Optional[int]) -> Compiled:
        keys = node.keys
        seg = fold_pipeline(node.child)
        if seg is not None:
            # fused sort prefix: segment + per-partition top-k in one step
            scanc, runner = self._make_runner(seg, "sort")
            src = self._segment_source_rdd(scanc, seg, ensure_nonempty=True)
            names = seg.output_names(self.catalog)
            # ORDER BY <dot-product score> DESC LIMIT k over a segment
            # whose lanes survive projection: the per-partition top-k may
            # run the Pallas topk_similarity kernel (DESIGN.md §15.3)
            topk = (_match_topk(seg, keys[0][0], names)
                    if limit is not None and len(keys) == 1 and keys[0][1]
                    else None)

            if self._fusion_mode != "off":
                # whole-stage (DESIGN.md §14): the sorted prefix ships as
                # one zero-copy piece straight into the shuffle block
                from .stage import StageRunner
                stage = StageRunner(runner, single_bucket(), 1,
                                    self._fusion_mode, self.pde, topk=topk)
                map_rdd = src.map_partitions(
                    lambda s, b: stage.run_sort_stage(b, keys, limit))
            else:
                def seg_sort(split: int,
                             batch: PartitionBatch) -> PartitionBatch:
                    b = runner.run(batch)
                    idx = _sort_indices(b, keys)
                    if limit is not None:
                        idx = idx[:limit]
                    return b.take(idx)

                map_rdd = self._prep_exchange(
                    src.map_partitions(seg_sort))
            child = Compiled(map_rdd, names)
        else:
            child = self._materialize_empty(self._compile(node.child),
                                            node.child)

            def local_sort(split: int, batch: PartitionBatch) -> PartitionBatch:
                idx = _sort_indices(batch, keys)
                if limit is not None:
                    idx = idx[:limit]
                return batch.take(idx)

            # per-partition top-k, then single merge task (ORDER BY ... LIMIT)
            map_rdd = self._prep_exchange(
                child.rdd.map_partitions(local_sort))
        dep = self._new_shuffle(map_rdd, 1, single_bucket(),
                                accumulators=lambda: [SizeAccumulator(1)])

        def final(split: int, batch: PartitionBatch) -> PartitionBatch:
            idx = _sort_indices(batch, keys)
            if limit is not None:
                idx = idx[:limit]
            return batch.take(idx)

        if self._fusion_mode != "off" and self._pipeline_gate(dep):
            pipe_fn = (lambda split, pieces:
                       final(split, PartitionBatch.concat(pieces)))
            _stats, pre = self.ctx.scheduler.run_map_stage_pipelined(
                dep, [[0]], pipe_fn)
            rdd = PipelinedShuffledRDD(dep, [[0]], final)
            rdd.offer_precomputed(pre)
            return Compiled(rdd, child.names)

        self.ctx.scheduler.run_map_stage(dep)
        rdd = ShuffledRDD(dep, [[0]], final)
        return Compiled(rdd, child.names)

    def _compile_limit(self, node: LimitNode) -> Compiled:
        if isinstance(node.child, SortNode):
            return self._compile_sort(node.child, node.n)
        n = node.n
        seg = fold_pipeline(node.child)
        if seg is not None:
            # fused pushed-down limit: segment + head(n) in one step
            scanc, runner = self._make_runner(seg, "limit")
            src = self._segment_source_rdd(scanc, seg, ensure_nonempty=True)
            if self._fusion_mode != "off":
                # whole-stage (DESIGN.md §14): surviving columns ship
                # encoded straight into the shuffle block as one zero-copy
                # piece — the pass-through host-assembly seam fix
                from .stage import StageRunner
                stage = StageRunner(runner, single_bucket(), 1,
                                    self._fusion_mode, self.pde)
                head_rdd = src.map_partitions(
                    lambda s, b: stage.run_limit_stage(b, n))
            else:
                head_rdd = src.map_partitions(
                    lambda s, b: runner.run(b).head(n))
            child = Compiled(head_rdd, seg.output_names(self.catalog))
            prepped = (head_rdd if self._fusion_mode != "off"
                       else self._prep_exchange(head_rdd))
        else:
            child = self._materialize_empty(self._compile(node.child),
                                            node.child)

            # §2.4: LIMIT pushed to partitions, final limit at collect
            head_rdd = child.rdd.map_partitions(lambda s, b: b.head(n))
            prepped = self._prep_exchange(head_rdd)

        # wrap as a one-partition RDD via shuffle to a single bucket
        dep = self._new_shuffle(prepped, 1, single_bucket())
        final = lambda s, b: b.head(n)
        if self._fusion_mode != "off" and self._pipeline_gate(dep):
            pipe_fn = (lambda split, pieces:
                       final(split, PartitionBatch.concat(pieces)))
            _stats, pre = self.ctx.scheduler.run_map_stage_pipelined(
                dep, [[0]], pipe_fn)
            rdd = PipelinedShuffledRDD(dep, [[0]], final)
            rdd.offer_precomputed(pre)
            return Compiled(rdd, child.names)
        self.ctx.scheduler.run_map_stage(dep)
        rdd = ShuffledRDD(dep, [[0]], final)
        return Compiled(rdd, child.names)


def _match_topk(seg: PipelineSegment, key: str,
                output_names: List[str]) -> Optional[Tuple[List[str],
                                                           np.ndarray]]:
    """(lane columns, query weights) when the sort key is a dot-product
    score — a sum of Col*Lit products over distinct numeric lanes, the
    shape `SharkFrame.similarity_join` (and its SQL twin) emits.  The lanes
    must survive the segment's projection: the kernel recomputes the tiled
    dot product from the lane columns of the segment output.  Returns None
    for anything else, keeping the generic lexsort path."""
    if seg.exprs is None:
        return None
    expr = next((e for n, e in seg.exprs if n == key), None)
    if expr is None:
        return None
    terms: List[Tuple[str, float]] = []

    def walk(e: Expr) -> bool:
        if isinstance(e, BinOp) and e.op == "+":
            return walk(e.left) and walk(e.right)
        if isinstance(e, BinOp) and e.op == "*":
            a, b = e.left, e.right
            if isinstance(a, Col) and isinstance(b, Lit) and _is_num(b.value):
                terms.append((a.name, float(b.value)))
                return True
            if isinstance(b, Col) and isinstance(a, Lit) and _is_num(a.value):
                terms.append((b.name, float(a.value)))
                return True
        return False

    if not walk(expr) or len(terms) < 2:
        return None
    lanes = [n for n, _ in terms]
    out = set(output_names)
    if len(set(lanes)) != len(lanes) or not all(n in out for n in lanes):
        return None
    return lanes, np.asarray([w for _, w in terms], np.float64)


def _empty_batch(names: List[str], schema) -> PartitionBatch:
    """A zero-row batch carrying the right columns (and string-ness), so
    blocking operators behave identically whether their input is empty
    because rows were filtered or because map pruning refuted every
    partition (§3.5)."""
    from .types import DType
    cols: Dict[str, ColumnVal] = {}
    for name in names:
        field = schema.field(name) if name in schema else None
        if field is not None and field.dtype == DType.STRING:
            cols[name] = ColumnVal(np.zeros(0, np.int32),
                                   np.array([], dtype=np.str_), True)
        else:
            dt = field.dtype.np_dtype if field is not None else np.float64
            cols[name] = ColumnVal(np.zeros(0, dt), None, True)
    return PartitionBatch(cols)


def _reorder(batch: PartitionBatch, names: List[str]) -> PartitionBatch:
    cols = {}
    for n in names:
        if n in batch.cols:
            cols[n] = batch.cols[n]
    for n, v in batch.cols.items():
        if n not in cols:
            cols[n] = v
    return PartitionBatch(cols)


def _sort_indices(batch: PartitionBatch, keys: List[Tuple[str, bool]]
                  ) -> np.ndarray:
    arrays = []
    for name, desc in reversed(keys):
        v = batch.col(name)
        if v.is_string and v.sorted_dict:
            # sorted dictionaries make code order string order: ORDER BY on
            # a dict-coded column never decodes (dictionary-preserving
            # exchange keeps this true across the shuffle)
            a = np.asarray(v.arr)
        elif v.is_string:
            a = v.decoded()
        else:
            a = np.asarray(v.arr)
        if desc:
            if a.dtype.kind in ("U", "S"):
                # lexsort has no descending: sort by negated rank
                _, inv = np.unique(a, return_inverse=True)
                a = -inv
            else:
                a = -a
        arrays.append(a)
    return np.lexsort(arrays) if arrays else np.arange(batch.num_rows)


def _stats_from_sizes(sizes: np.ndarray) -> StageStats:
    from .stats import TaskStats, encode_size
    st = StageStats(-1)
    st.add(TaskStats(0, -1, {
        "sizes": {"codes": np.array([encode_size(int(s)) for s in sizes],
                                    np.uint8),
                  "records": np.zeros(len(sizes), np.int64)}}))
    return st
