"""Resilient Distributed Datasets with lineage (paper §2.2, §2.3).

RDDs are immutable, partitioned collections created only through
deterministic coarse-grained operators.  Instead of replicating data, the
engine remembers each dataset's *lineage* — the operator graph that built it
— and recovers lost partitions by recomputing them, in parallel, on other
workers.  This module defines the dataset graph; `runtime.py` is the
scheduler that executes it, injects failures, and performs lineage recovery
and speculative execution.

The host runtime plays the role of Spark's cluster: logical workers hold
block stores (cached partitions + shuffle map outputs), and per-partition
tasks execute jit-compiled columnar kernels.  On a real TPU fleet the same
lineage graph drives per-host recomputation of the data pipeline while the
SPMD training step restarts from checkpoints (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .batch import PartitionBatch
from .columnar import Table
from .stats import Accumulator

_rdd_counter = itertools.count()
_shuffle_counter = itertools.count()


class Dependency:
    def __init__(self, parent: "RDD"):
        self.parent = parent


class OneToOneDependency(Dependency):
    def parents_of(self, split: int) -> List[int]:
        return [split]


class RangeDependency(Dependency):
    """Narrow dependency on an explicit list of parent partitions per split
    (used for PDE's reducer coalescing: one coarse partition reads many
    fine-grained map buckets)."""

    def __init__(self, parent: "RDD", groups: List[List[int]]):
        super().__init__(parent)
        self.groups = groups

    def parents_of(self, split: int) -> List[int]:
        return self.groups[split]


class ShuffleDependency(Dependency):
    """Wide dependency: every output partition reads from every map task.

    `partitioner(batch) -> np.ndarray[int]` assigns each row to a bucket.
    `map_side_combine` optionally pre-aggregates each bucket before it is
    materialized (Shark/Hive task-local aggregation).
    `accumulators()` builds the PDE statistics gathered while map output
    materializes (§3.1).
    """

    def __init__(self, parent: "RDD", num_buckets: int,
                 partitioner: Callable[[PartitionBatch], np.ndarray],
                 map_side_combine: Optional[Callable[[PartitionBatch], PartitionBatch]] = None,
                 accumulators: Optional[Callable[[], List[Accumulator]]] = None):
        super().__init__(parent)
        self.shuffle_id = next(_shuffle_counter)
        self.num_buckets = num_buckets
        self.partitioner = partitioner
        self.map_side_combine = map_side_combine
        self.accumulators = accumulators or (lambda: [])


@dataclasses.dataclass
class TaskContext:
    worker_id: int
    stage_id: int
    split: int
    attempt: int = 0


class RDD:
    def __init__(self, ctx: "SharkContext", num_partitions: int,
                 deps: Sequence[Dependency]):
        self.ctx = ctx
        self.id = next(_rdd_counter)
        self._num_partitions = num_partitions
        self.deps = list(deps)
        self.cached = False
        # optional per-split artificial delay (seconds) for straggler tests
        self.delay_fn: Optional[Callable[[int], float]] = None

    # -- graph -------------------------------------------------------------

    @property
    def num_partitions(self) -> int:
        return self._num_partitions

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        raise NotImplementedError

    def iterator(self, split: int, tc: TaskContext) -> PartitionBatch:
        """Cache-aware access: reuse a materialized block if present, else
        compute from lineage (and cache if marked).

        This is the paper's fallback-to-recompute path (§3.2): a cached
        partition may have been dropped at any time — worker loss, or the
        MemoryManager evicting under a cache budget — and the query still
        succeeds by recomputing the partition from its lineage.  The re-put
        below re-admits the block, subject to the same budget."""
        if self.cached:
            hit = self.ctx.block_manager.get_partition(self.id, split)
            if hit is not None:
                return hit
        if self.delay_fn is not None:
            import time
            time.sleep(self.delay_fn(split))
        out = self.compute(split, tc)
        if self.cached:
            self.ctx.block_manager.put_partition(self.id, split, out,
                                                 tc.worker_id)
        return out

    def cache(self) -> "RDD":
        self.cached = True
        return self

    def unpersist(self) -> "RDD":
        """Unmark and drop any materialized blocks from the block store."""
        self.cached = False
        for split in range(self.num_partitions):
            self.ctx.block_manager.drop_block(("part", self.id, split))
        return self

    # -- functional API (paper §2.2 operators) ------------------------------

    def map_partitions(self, f: Callable[[int, PartitionBatch], PartitionBatch]
                       ) -> "MapPartitionsRDD":
        return MapPartitionsRDD(self, f)

    def zip_partitions(self, other: "RDD",
                       f: Callable[[int, PartitionBatch, PartitionBatch], PartitionBatch]
                       ) -> "ZipPartitionsRDD":
        return ZipPartitionsRDD(self, other, f)

    def collect(self) -> List[PartitionBatch]:
        return self.ctx.scheduler.run_job(self)

    def __repr__(self):
        return f"{type(self).__name__}(id={self.id}, parts={self.num_partitions})"


class TableScanRDD(RDD):
    """Source RDD over the columnar memory store.  `selected` is the list of
    partition indices that survived map pruning — the master simply does not
    create tasks for pruned partitions (§3.5)."""

    def __init__(self, ctx, table: Table, columns: Optional[Sequence[str]] = None,
                 selected: Optional[List[int]] = None):
        self.table = table
        self.columns = list(columns) if columns is not None else None
        self.selected = selected if selected is not None \
            else list(range(table.num_partitions))
        super().__init__(ctx, len(self.selected), [])

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        part = self.table.partitions[self.selected[split]]
        part.touch()    # access recency drives coldest-first spill (§12)
        return PartitionBatch.from_partition(part, self.columns)


class ParallelCollectionRDD(RDD):
    def __init__(self, ctx, batches: List[PartitionBatch]):
        self.batches = batches
        super().__init__(ctx, len(batches), [])

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        return self.batches[split]


class MapPartitionsRDD(RDD):
    def __init__(self, parent: RDD, f: Callable[[int, PartitionBatch], PartitionBatch]):
        self.f = f
        super().__init__(parent.ctx, parent.num_partitions,
                         [OneToOneDependency(parent)])

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        parent = self.deps[0].parent
        return self.f(split, parent.iterator(split, tc))


class ZipPartitionsRDD(RDD):
    """Narrow two-parent dependency — the co-partitioned join (§3.4) compiles
    to this: corresponding partitions join with *no shuffle*."""

    def __init__(self, left: RDD, right: RDD,
                 f: Callable[[int, PartitionBatch, PartitionBatch], PartitionBatch]):
        assert left.num_partitions == right.num_partitions, \
            "zip requires equal partitioning"
        self.f = f
        super().__init__(left.ctx, left.num_partitions,
                         [OneToOneDependency(left), OneToOneDependency(right)])

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        l = self.deps[0].parent.iterator(split, tc)
        r = self.deps[1].parent.iterator(split, tc)
        return self.f(split, l, r)


class ShuffledRDD(RDD):
    """Reduce side of a shuffle.  Each split fetches its bucket group from
    every map task's materialized output (memory-based shuffle, §5), then
    applies `reduce_fn` (e.g. final aggregation or the reduce-side join).

    `bucket_groups` defaults to the identity [ [0], [1], ... ]; PDE's
    coalescing replaces it with greedy-bin-packed groups of fine-grained
    buckets (§3.1.2).
    """

    def __init__(self, dep: ShuffleDependency,
                 bucket_groups: Optional[List[List[int]]] = None,
                 reduce_fn: Optional[Callable[[int, PartitionBatch], PartitionBatch]] = None):
        self.dep = dep
        self.bucket_groups = bucket_groups if bucket_groups is not None \
            else [[b] for b in range(dep.num_buckets)]
        self.reduce_fn = reduce_fn
        super().__init__(dep.parent.ctx, len(self.bucket_groups), [dep])

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        buckets = self.bucket_groups[split]
        pieces = self.ctx.block_manager.fetch_shuffle(
            self.dep.shuffle_id, self.dep.parent.num_partitions, buckets)
        merged = PartitionBatch.concat(pieces)
        if self.reduce_fn is not None:
            merged = self.reduce_fn(split, merged)
        return merged


class PipelinedShuffledRDD(ShuffledRDD):
    """ShuffledRDD whose splits may already have been computed by the
    pipelined scheduler (DESIGN.md §14): `Scheduler.run_map_stage_pipelined`
    ran the reduce concurrently with the map stage and deposits the results
    here via `offer_precomputed`.  `compute` consumes each precomputed
    result exactly once — speculative re-runs and lineage recomputes of the
    same split fall through to the ordinary fetch-from-blocks path, which
    yields an identical batch because reduce tasks are deterministic."""

    def __init__(self, dep: ShuffleDependency,
                 bucket_groups: Optional[List[List[int]]] = None,
                 reduce_fn: Optional[Callable[[int, PartitionBatch],
                                              PartitionBatch]] = None):
        super().__init__(dep, bucket_groups, reduce_fn)
        self._precomputed: Dict[int, PartitionBatch] = {}
        self._pre_lock = threading.Lock()
        self.pipelined_hits = 0

    def offer_precomputed(self, results: Dict[int, PartitionBatch]) -> None:
        with self._pre_lock:
            self._precomputed.update(results)

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        with self._pre_lock:
            hit = self._precomputed.pop(split, None)
            if hit is not None:
                self.pipelined_hits += 1
        if hit is not None:
            return hit
        return super().compute(split, tc)


class UnionRDD(RDD):
    def __init__(self, parents: List[RDD]):
        self.offsets = []
        total = 0
        deps = []
        for p in parents:
            self.offsets.append(total)
            total += p.num_partitions
            deps.append(OneToOneDependency(p))
        super().__init__(parents[0].ctx, total, deps)
        self.parents = parents

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        for p, off in zip(self.parents, self.offsets):
            if split < off + p.num_partitions:
                return p.iterator(split - off, tc)
        raise IndexError(split)


def lineage_string(rdd: RDD, indent: int = 0) -> str:
    """Debug view of the lineage graph (Figure 3 of the paper)."""
    pad = "  " * indent
    lines = [f"{pad}{rdd!r}{' [cached]' if rdd.cached else ''}"]
    for d in rdd.deps:
        kind = type(d).__name__
        lines.append(f"{pad} <-{kind}")
        lines.append(lineage_string(d.parent, indent + 1))
    return "\n".join(lines)
