"""Table catalog — the warehouse metadata store (paper Figure 2).

Shark keeps warehouse metadata in an external transactional database (the
Hive metastore); here the catalog is an in-process registry of cached
columnar tables plus "external" tables (loaded lazily from generator
functions, standing in for HDFS data the engine can also query directly).

For the server tier (DESIGN.md §6) the catalog is also the *versioning*
authority: every mutation (CREATE TABLE / load / drop) bumps a global epoch
and stamps the mutated table with it.  Query-result cache entries record the
versions of the tables they read; a version mismatch (or an invalidation
callback) means the cached result may be stale and must not be served.
Lazy materialization of an external source does NOT bump the version — the
loader is deterministic, so the logical table content is unchanged.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from .columnar import Table, build_partition, from_arrays
from .types import Schema


@dataclasses.dataclass
class ExternalSource:
    """Stands in for an HDFS/S3 table: schema + a loader that yields raw
    column arrays.  Loading into the memory store == CREATE TABLE ...
    TBLPROPERTIES ('shark.cache'='true') AS SELECT ..."""
    name: str
    schema: Schema
    loader: Callable[[], Dict[str, np.ndarray]]
    num_partitions: int = 8


def _external_partition_lineage(src: ExternalSource, index: int):
    """Recompute-from-lineage closure for ONE partition of a materialized
    external table (storage tier, DESIGN.md §12): re-run the deterministic
    loader and rebuild exactly the contiguous slice `from_arrays` assigned
    to this partition.  A spilled partition whose segment is lost or corrupt
    restores from here — same content, because loader and split edges are
    both deterministic."""
    def rebuild():
        data = src.loader()
        n = len(next(iter(data.values()))) if data else 0
        edges = np.linspace(0, n, src.num_partitions + 1, dtype=np.int64)
        lo, hi = int(edges[index]), int(edges[index + 1])
        sliced = {f.name: np.asarray(data[f.name])[lo:hi]
                  for f in src.schema.fields}
        return build_partition(index, src.schema, sliced).columns
    return rebuild


class Catalog:
    def __init__(self):
        self._tables: Dict[str, Table] = {}
        self._external: Dict[str, ExternalSource] = {}
        self._lock = threading.RLock()
        self._epoch = 0
        self._versions: Dict[str, int] = {}
        self._listeners: List[Callable[[str, int], None]] = []

    # -- versioning (server result-cache invalidation) ----------------------

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def version(self, name: str) -> int:
        """Epoch at which `name` last changed (0 = never registered)."""
        with self._lock:
            return self._versions.get(name, 0)

    def subscribe(self, fn: Callable[[str, int], None]) -> None:
        """`fn(table_name, new_epoch)` fires on every catalog mutation."""
        with self._lock:
            self._listeners.append(fn)

    def _bump_locked(self, name: str):
        # caller holds self._lock; returns the notification to fire AFTER
        # the lock is released (listeners may take their own locks that
        # also call back into the catalog — holding ours would AB-BA)
        self._epoch += 1
        self._versions[name] = self._epoch
        return list(self._listeners), name, self._epoch

    @staticmethod
    def _fire(notification) -> None:
        listeners, name, epoch = notification
        for fn in listeners:
            fn(name, epoch)

    def adopt_version(self, name: str, version: int) -> None:
        """Force `name`'s version to a peer catalog's (the fleet epoch
        protocol, DESIGN.md §13.2): the global epoch advances to at least
        `version` and listeners fire, so dependent result-cache entries
        invalidate exactly as they would for a local mutation.  Idempotent
        when the versions already agree."""
        with self._lock:
            if self._versions.get(name, 0) == version:
                return
            self._epoch = max(self._epoch, version)
            self._versions[name] = version
            note = (list(self._listeners), name, version)
        self._fire(note)

    # -- registry ------------------------------------------------------------

    def register_table(self, table: Table) -> None:
        with self._lock:
            self._tables[table.name] = table
            note = self._bump_locked(table.name)
        self._fire(note)

    def register_external(self, src: ExternalSource) -> None:
        with self._lock:
            self._external[src.name] = src
            note = self._bump_locked(src.name)
        self._fire(note)

    def get(self, name: str) -> Table:
        return self.get_versioned(name)[0]

    def get_versioned(self, name: str):
        """(table, version) read atomically — a concurrent mutation cannot
        pair the old table object with the new version (the server's scan
        cache keys blocks by version, so a torn read would poison it)."""
        with self._lock:
            if name in self._tables:
                return self._tables[name], self._versions.get(name, 0)
            if name in self._external:
                src = self._external[name]
                # schema-on-read load path: materialize as columnar partitions
                # (deterministic loader -> logical content unchanged, no bump)
                table = from_arrays(name, src.schema, src.loader(),
                                    src.num_partitions)
                for part in table.partitions:
                    part.lineage = _external_partition_lineage(src, part.index)
                self._tables[name] = table
                return table, self._versions.get(name, 0)
        raise KeyError(f"unknown table {name!r}")

    def schema(self, name: str) -> Schema:
        with self._lock:
            if name in self._tables:
                return self._tables[name].schema
            if name in self._external:
                return self._external[name].schema
        raise KeyError(f"unknown table {name!r}")

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._tables or name in self._external

    def drop(self, name: str) -> None:
        note = None
        with self._lock:
            existed = name in self._tables or name in self._external
            self._tables.pop(name, None)
            self._external.pop(name, None)
            if existed:
                note = self._bump_locked(name)
        if note is not None:
            self._fire(note)

    def tables(self):
        with self._lock:
            return dict(self._tables)
