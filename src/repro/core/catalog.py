"""Table catalog — the warehouse metadata store (paper Figure 2).

Shark keeps warehouse metadata in an external transactional database (the
Hive metastore); here the catalog is an in-process registry of cached
columnar tables plus "external" tables (loaded lazily from generator
functions, standing in for HDFS data the engine can also query directly).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional

import numpy as np

from .columnar import Table, from_arrays
from .types import Schema


@dataclasses.dataclass
class ExternalSource:
    """Stands in for an HDFS/S3 table: schema + a loader that yields raw
    column arrays.  Loading into the memory store == CREATE TABLE ...
    TBLPROPERTIES ('shark.cache'='true') AS SELECT ..."""
    name: str
    schema: Schema
    loader: Callable[[], Dict[str, np.ndarray]]
    num_partitions: int = 8


class Catalog:
    def __init__(self):
        self._tables: Dict[str, Table] = {}
        self._external: Dict[str, ExternalSource] = {}
        self._lock = threading.RLock()

    def register_table(self, table: Table) -> None:
        with self._lock:
            self._tables[table.name] = table

    def register_external(self, src: ExternalSource) -> None:
        with self._lock:
            self._external[src.name] = src

    def get(self, name: str) -> Table:
        with self._lock:
            if name in self._tables:
                return self._tables[name]
            if name in self._external:
                src = self._external[name]
                # schema-on-read load path: materialize as columnar partitions
                table = from_arrays(name, src.schema, src.loader(),
                                    src.num_partitions)
                self._tables[name] = table
                return table
        raise KeyError(f"unknown table {name!r}")

    def schema(self, name: str) -> Schema:
        with self._lock:
            if name in self._tables:
                return self._tables[name].schema
            if name in self._external:
                return self._external[name].schema
        raise KeyError(f"unknown table {name!r}")

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._tables or name in self._external

    def drop(self, name: str) -> None:
        with self._lock:
            self._tables.pop(name, None)
            self._external.pop(name, None)

    def tables(self):
        with self._lock:
            return dict(self._tables)
