"""CPU-efficient columnar compression schemes (paper §3.2–3.3).

Shark compresses each column *per partition*, choosing the scheme from local
metadata collected during the load task — no global coordination — so the
load phase keeps maximum parallelism.  We reproduce the three schemes the
paper names (dictionary encoding, run-length encoding, bit packing) plus the
PLAIN fallback, and the local per-partition selection heuristic.

Encoding happens host-side at load (numpy).  Decoding is a device kernel:
`decode_jnp` is the pure-jnp oracle, and `repro.kernels` provides the Pallas
HBM->VMEM streaming versions used on TPU, where decompression is fused into
the consuming scan (the TPU analogue of eliminating Shark's 200 MB/s/core
deserialization bottleneck).

On TPU, compression is a *bandwidth* optimization: HBM->VMEM bytes shrink by
the compression ratio, directly reducing the memory roofline term.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax.numpy as jnp
import numpy as np


class Encoding(enum.Enum):
    PLAIN = "plain"
    DICT = "dict"        # code stream + value dictionary
    RLE = "rle"          # (run value, run length) streams
    BITPACK = "bitpack"  # ints packed to minimal bit width in uint32 words
    FOR = "for"          # frame of reference: (value - bias) in a narrow uint lane


# ---------------------------------------------------------------------------
# Selection heuristic (paper: "the loading task will compress a column using
# dictionary encoding if its number of distinct values is below a threshold";
# each task decides locally, per partition).
# ---------------------------------------------------------------------------

DICT_DISTINCT_THRESHOLD = 4096
RLE_MIN_AVG_RUN = 4.0
BITPACK_MAX_BITS = 16


@dataclasses.dataclass
class Encoded:
    encoding: Encoding
    # PLAIN: data; DICT: codes + dictionary; RLE: values + lengths; BITPACK:
    # words + bit width + original length + bias.
    data: Optional[np.ndarray] = None
    codes: Optional[np.ndarray] = None
    dictionary: Optional[np.ndarray] = None
    run_values: Optional[np.ndarray] = None
    run_lengths: Optional[np.ndarray] = None
    words: Optional[np.ndarray] = None
    bit_width: int = 0
    bias: int = 0
    n: int = 0
    orig_dtype: Optional[np.dtype] = None
    # Memoized decode: a query typically touches the same block several
    # times (scan predicate, then projection, then aggregation argument);
    # the first decode_np caches here and later calls are free.  The
    # MemoryManager calls drop_decoded() under cache pressure — the cache
    # is pure derived state, so dropping it is always safe.
    _decoded: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    decode_count: int = dataclasses.field(default=0, repr=False, compare=False)

    @property
    def nbytes(self) -> int:
        total = 0
        for a in (self.data, self.codes, self.dictionary, self.run_values,
                  self.run_lengths, self.words):
            if a is not None:
                total += a.nbytes
        return total

    @property
    def decoded_nbytes(self) -> int:
        """Bytes currently held by the memoized decode cache."""
        return self._decoded.nbytes if self._decoded is not None else 0

    def drop_decoded(self) -> int:
        """Release the memoized decoded array; returns bytes freed."""
        freed = self.decoded_nbytes
        self._decoded = None
        return freed


def _avg_run_length(values: np.ndarray) -> float:
    if len(values) == 0:
        return 0.0
    changes = int(np.count_nonzero(values[1:] != values[:-1])) + 1
    return len(values) / changes


def choose_encoding(values: np.ndarray) -> Encoding:
    """Local, per-partition scheme selection from column metadata."""
    if values.size == 0:
        return Encoding.PLAIN
    if _avg_run_length(values) >= RLE_MIN_AVG_RUN:
        return Encoding.RLE
    if np.issubdtype(values.dtype, np.integer):
        lo, hi = int(values.min()), int(values.max())
        span = hi - lo
        if span >= 0 and span < (1 << BITPACK_MAX_BITS):
            return Encoding.BITPACK
    distinct = len(np.unique(values[: 65536]))  # sample-bounded, like a load task would
    if distinct <= DICT_DISTINCT_THRESHOLD:
        return Encoding.DICT
    return Encoding.PLAIN


def _for_lane_dtype(span: int) -> Optional[np.dtype]:
    """Narrowest unsigned lane that holds codes in [0, span]."""
    if span < (1 << 8):
        return np.dtype(np.uint8)
    if span < (1 << 16):
        return np.dtype(np.uint16)
    if span < (1 << 32):
        return np.dtype(np.uint32)
    return None


def choose_recompression(values: np.ndarray,
                         ndv: Optional[int] = None) -> Encoding:
    """Adaptive scheme selection for the storage tier's WARM transition
    (DESIGN.md §12): unlike the load-time `choose_encoding`, this ranks
    candidate schemes by *projected encoded size* so a pressure-driven
    recompression only ever shrinks the block.  Signals are the same
    piggybacked statistics the store already keeps: run length (RLE),
    value span (frame-of-reference / bit packing), and NDV (dictionary).
    """
    n = len(values)
    if n == 0:
        return Encoding.PLAIN
    itemsize = values.dtype.itemsize
    sizes = {Encoding.PLAIN: n * itemsize}
    changes = int(np.count_nonzero(values[1:] != values[:-1])) + 1
    if n / changes >= RLE_MIN_AVG_RUN:
        sizes[Encoding.RLE] = changes * (itemsize + 4)
    if np.issubdtype(values.dtype, np.integer):
        span = int(values.max()) - int(values.min())
        lane = _for_lane_dtype(span)
        if lane is not None:
            sizes[Encoding.FOR] = n * lane.itemsize
        if 0 <= span < (1 << BITPACK_MAX_BITS):
            width = max(1, span.bit_length())
            sizes[Encoding.BITPACK] = -(-n // (32 // width)) * 4
    if ndv is None:
        ndv = len(np.unique(values[: 65536]))
    if ndv <= DICT_DISTINCT_THRESHOLD:
        sizes[Encoding.DICT] = n * 4 + ndv * itemsize
    # ties break toward schemes the engine can execute on directly without
    # widening (run-level RLE scans, FOR/DICT code-bound predicates) —
    # BITPACK must be unpacked before any compare
    pref = {Encoding.RLE: 0, Encoding.FOR: 1, Encoding.DICT: 2,
            Encoding.BITPACK: 3, Encoding.PLAIN: 4}
    return min(sizes, key=lambda e: (sizes[e], pref[e]))


def recompress(enc: Encoded) -> Encoded:
    """Re-encode a block with the adaptively chosen scheme.  Returns a NEW
    Encoded strictly smaller than the input, or the input unchanged when no
    candidate wins.  Never changes decoded content (round-trip property,
    tests/test_storage_property.py)."""
    values = decode_np(enc)
    ndv = len(enc.dictionary) if enc.dictionary is not None else None
    target = choose_recompression(values, ndv=ndv)
    if target == enc.encoding:
        return enc
    out = encode(values, target)
    return out if out.nbytes < enc.nbytes else enc


# ---------------------------------------------------------------------------
# Encoders (host side, run inside data-loading tasks)
# ---------------------------------------------------------------------------

def encode(values: np.ndarray, encoding: Optional[Encoding] = None) -> Encoded:
    if encoding is None:
        encoding = choose_encoding(values)
    n = len(values)
    if encoding == Encoding.PLAIN:
        return Encoded(Encoding.PLAIN, data=values, n=n, orig_dtype=values.dtype)
    if encoding == Encoding.DICT:
        dictionary, codes = np.unique(values, return_inverse=True)
        return Encoded(Encoding.DICT, codes=codes.astype(np.int32),
                       dictionary=dictionary, n=n, orig_dtype=values.dtype)
    if encoding == Encoding.RLE:
        if n == 0:
            return Encoded(Encoding.RLE, run_values=values,
                           run_lengths=np.zeros(0, np.int32), n=0,
                           orig_dtype=values.dtype)
        boundaries = np.flatnonzero(values[1:] != values[:-1]) + 1
        starts = np.concatenate([[0], boundaries])
        ends = np.concatenate([boundaries, [n]])
        return Encoded(Encoding.RLE, run_values=values[starts],
                       run_lengths=(ends - starts).astype(np.int32), n=n,
                       orig_dtype=values.dtype)
    if encoding == Encoding.FOR:
        assert np.issubdtype(values.dtype, np.integer), "frame-of-reference needs ints"
        lo = int(values.min()) if n else 0
        span = (int(values.max()) - lo) if n else 0
        lane = _for_lane_dtype(span)
        assert lane is not None, f"span {span} too wide for frame-of-reference"
        codes = (values.astype(np.int64) - lo).astype(lane)
        return Encoded(Encoding.FOR, codes=codes, bias=lo, n=n,
                       orig_dtype=values.dtype)
    if encoding == Encoding.BITPACK:
        assert np.issubdtype(values.dtype, np.integer), "bitpack needs ints"
        lo = int(values.min()) if n else 0
        shifted = (values.astype(np.int64) - lo).astype(np.uint32)
        span = int(shifted.max()) if n else 0
        width = max(1, int(span).bit_length())
        per_word = 32 // width
        n_words = -(-n // per_word) if n else 0
        padded = np.zeros(n_words * per_word, np.uint32)
        padded[:n] = shifted
        lanes = padded.reshape(n_words, per_word)
        shifts = (np.arange(per_word, dtype=np.uint32) * width)
        words = np.bitwise_or.reduce(lanes << shifts[None, :], axis=1)
        return Encoded(Encoding.BITPACK, words=words.astype(np.uint32),
                       bit_width=width, bias=lo, n=n, orig_dtype=values.dtype)
    raise ValueError(encoding)


# ---------------------------------------------------------------------------
# Decoders — pure-jnp oracle used by the engine on CPU and as the reference
# for the Pallas kernels.
# ---------------------------------------------------------------------------

def decode_np(enc: Encoded) -> np.ndarray:
    """Host-side decode (ground truth), memoized on the Encoded.

    PLAIN blocks return the stored array directly (no copy, nothing to
    cache); every other scheme materializes once and caches the result on
    the block until `drop_decoded()` releases it."""
    if enc.encoding == Encoding.PLAIN:
        return enc.data
    if enc._decoded is not None:
        return enc._decoded
    enc.decode_count += 1
    # encoded-pipeline promise (DESIGN.md §15): paths that claim to hand
    # encoded blocks straight to XLA must never reach this point — the
    # counters make the claim assertable (expr.DECODE_COUNTERS).
    from .expr import DECODE_COUNTERS
    DECODE_COUNTERS["numeric_blocks"] += 1
    DECODE_COUNTERS["numeric_rows"] += int(enc.n)
    if enc.encoding == Encoding.DICT:
        out = enc.dictionary[enc.codes]
    elif enc.encoding == Encoding.FOR:
        out = (enc.codes.astype(np.int64) + enc.bias).astype(enc.orig_dtype)
    elif enc.encoding == Encoding.RLE:
        out = np.repeat(enc.run_values, enc.run_lengths)
    elif enc.encoding == Encoding.BITPACK:
        width, per_word = enc.bit_width, 32 // enc.bit_width
        shifts = (np.arange(per_word, dtype=np.uint32) * width)
        lanes = (enc.words[:, None] >> shifts[None, :]) & np.uint32((1 << width) - 1)
        flat = lanes.reshape(-1)[: enc.n].astype(np.int64) + enc.bias
        out = flat.astype(enc.orig_dtype)
    else:
        raise ValueError(enc.encoding)
    enc._decoded = out
    return out


def decode_jnp(enc: Encoded) -> jnp.ndarray:
    """Device decode, jnp oracle (static output length = enc.n)."""
    if enc.encoding == Encoding.PLAIN:
        return jnp.asarray(enc.data)
    if enc.encoding == Encoding.DICT:
        return jnp.asarray(enc.dictionary)[jnp.asarray(enc.codes)]
    if enc.encoding == Encoding.FOR:
        codes = jnp.asarray(enc.codes)
        return (codes.astype(jnp.int64) + enc.bias).astype(enc.orig_dtype)
    if enc.encoding == Encoding.RLE:
        # searchsorted-based repeat with static total length.
        lengths = jnp.asarray(enc.run_lengths)
        ends = jnp.cumsum(lengths)
        idx = jnp.searchsorted(ends, jnp.arange(enc.n), side="right")
        return jnp.asarray(enc.run_values)[idx]
    if enc.encoding == Encoding.BITPACK:
        width, per_word = enc.bit_width, 32 // enc.bit_width
        words = jnp.asarray(enc.words)
        shifts = (jnp.arange(per_word, dtype=jnp.uint32) * width)
        lanes = (words[:, None] >> shifts[None, :]) & jnp.uint32((1 << width) - 1)
        flat = lanes.reshape(-1)[: enc.n].astype(jnp.int64) + enc.bias
        return flat.astype(enc.orig_dtype)
    raise ValueError(enc.encoding)


def compression_ratio(enc: Encoded) -> float:
    raw = enc.n * (np.dtype(enc.orig_dtype).itemsize if enc.orig_dtype else 4)
    return raw / max(enc.nbytes, 1)
