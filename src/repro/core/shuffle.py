"""Shuffle partitioners (paper §3.1, §5 "Memory-based Shuffle").

Map output is materialized in worker memory (the BlockManager), never on
disk; the partitioner assigns rows to reduce buckets by a deterministic key
hash shared with DISTRIBUTE BY so co-partitioned tables align.

String keys hash through the partition dictionary — one crc32 per *distinct*
value, then an O(1) gather per row — the columnar store making the shuffle
CPU-cheap (§3.2).
"""

from __future__ import annotations

import zlib
from typing import Callable, List, Optional, Sequence

import numpy as np

from .batch import PartitionBatch
from .columnar import hash_key_values


def _row_keys(batch: PartitionBatch, key: str) -> np.ndarray:
    v = batch.col(key)
    if v.is_string:
        hd = np.array([zlib.crc32(s.encode()) for s in v.sdict.tolist()],
                      dtype=np.int64)
        return hd[np.asarray(v.arr)]
    return hash_key_values(np.asarray(v.arr))


def bucket_by_hash(key: str, num_buckets: int
                   ) -> Callable[[PartitionBatch], np.ndarray]:
    def partitioner(batch: PartitionBatch) -> np.ndarray:
        k = _row_keys(batch, key)
        h = k.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        h ^= h >> np.uint64(29)
        return (h % np.uint64(num_buckets)).astype(np.int32)
    return partitioner


def bucket_by_composite(keys: Sequence[str], num_buckets: int
                        ) -> Callable[[PartitionBatch], np.ndarray]:
    def partitioner(batch: PartitionBatch) -> np.ndarray:
        h = np.zeros(batch.num_rows, np.int64)
        for key in keys:
            k = _row_keys(batch, key)
            h = h * np.int64(1000003) + k
        hu = h.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
        hu ^= hu >> np.uint64(29)
        return (hu % np.uint64(num_buckets)).astype(np.int32)
    return partitioner


def single_bucket() -> Callable[[PartitionBatch], np.ndarray]:
    """Degenerate partitioner: everything to reducer 0 (the MPP-style single
    coordinator plan the paper contrasts against in §6.2.2)."""
    def partitioner(batch: PartitionBatch) -> np.ndarray:
        return np.zeros(batch.num_rows, np.int32)
    return partitioner
