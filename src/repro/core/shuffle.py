"""Shuffle partitioners (paper §3.1, §5 "Memory-based Shuffle").

Map output is materialized in worker memory (the BlockManager), never on
disk; the partitioner assigns rows to reduce buckets by a deterministic key
hash shared with DISTRIBUTE BY so co-partitioned tables align.

String keys hash through the partition dictionary — one crc32 per *distinct*
value, then an O(1) gather per row — so the shuffle path never materializes
a string (the columnar store making the shuffle CPU-cheap, §3.2).

`kernel=True` routes the hash-mix + modulo + bucket histogram through the
Pallas `radix_partition` kernel (TPU/forced routes).  The flag is fixed per
partitioner, never per task: a shuffle's bucket assignment must be one
function of the key value on every map task, and the kernel's 32-bit mix is
a *different* (equally valid) function than the host's 64-bit mix.
"""

from __future__ import annotations

import weakref
import zlib
from typing import Callable, List, Optional, Sequence

import numpy as np

from .batch import PartitionBatch
from .columnar import hash_key_values

# diagnostic: how many partitioner calls took the Pallas radix route
RADIX_KERNEL_CALLS = {"count": 0}

# Dictionaries are immutable load-time state, so their per-entry crc32
# hashes are derived metadata worth memoizing (the same partition
# dictionary is hashed by every query shuffling that partition) — the
# shuffle-side analogue of the memoized block decode in compression.py.
# Keyed by id() (ndarrays are not hashable) with a weakref finalizer
# evicting dead entries; the liveness check below guards id reuse.
_DICT_HASH_CACHE: dict = {}
_DICT_HASH_CACHE_MAX = 4096


def _dict_hashes(sdict: np.ndarray) -> np.ndarray:
    key = id(sdict)
    hit = _DICT_HASH_CACHE.get(key)
    if hit is not None and hit[0]() is sdict:
        return hit[1]
    hd = np.array([zlib.crc32(s.encode()) for s in sdict.tolist()],
                  dtype=np.int64)
    try:
        ref = weakref.ref(sdict,
                          lambda _r, k=key: _DICT_HASH_CACHE.pop(k, None))
    except TypeError:
        return hd   # un-weakref-able object: skip caching
    if len(_DICT_HASH_CACHE) >= _DICT_HASH_CACHE_MAX:
        _DICT_HASH_CACHE.clear()    # crude but bounded; hashes rebuild
    _DICT_HASH_CACHE[key] = (ref, hd)
    return hd


def _row_keys(batch: PartitionBatch, key: str) -> np.ndarray:
    v = batch.col(key)
    if v.is_string:
        return _dict_hashes(v.sdict)[np.asarray(v.arr)]
    return hash_key_values(np.asarray(v.arr))


def _mix_mod(k: np.ndarray, num_buckets: int) -> np.ndarray:
    h = k.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    h ^= h >> np.uint64(29)
    return (h % np.uint64(num_buckets)).astype(np.int32)


def _kernel_buckets(k: np.ndarray, num_buckets: int) -> np.ndarray:
    from ..kernels import ops as kernel_ops
    from ..kernels.radix_partition import fold_keys_u32
    RADIX_KERNEL_CALLS["count"] += 1
    chunk = kernel_ops.DOUBLE_BUFFER["chunk_rows"]
    if len(k) >= 2 * chunk:
        # Double-buffered: fold+dispatch of chunk i+1 overlaps compute of
        # chunk i (DESIGN.md §14).  Bucket id is per-row, so chunked and
        # single-shot results are bit-identical.
        parts = kernel_ops.double_buffer_map(
            lambda c: kernel_ops.radix_partition(
                fold_keys_u32(c), num_buckets=num_buckets,
                with_counts=False)[0],
            [k[i:i + chunk] for i in range(0, len(k), chunk)])
        return np.concatenate([np.asarray(p) for p in parts])
    buckets, _ = kernel_ops.radix_partition(
        fold_keys_u32(k), num_buckets=num_buckets, with_counts=False)
    return np.asarray(buckets)


def bucket_by_hash(key: str, num_buckets: int, kernel: bool = False
                   ) -> Callable[[PartitionBatch], np.ndarray]:
    from .batch import EXCHANGE_TIMERS

    def partitioner(batch: PartitionBatch) -> np.ndarray:
        import time
        t0 = time.perf_counter()
        k = _row_keys(batch, key)
        out = (_kernel_buckets(k, num_buckets) if kernel
               else _mix_mod(k, num_buckets))
        EXCHANGE_TIMERS["hash"] += time.perf_counter() - t0
        return out
    return partitioner


def bucket_by_composite(keys: Sequence[str], num_buckets: int,
                        kernel: bool = False
                        ) -> Callable[[PartitionBatch], np.ndarray]:
    from .batch import EXCHANGE_TIMERS

    def partitioner(batch: PartitionBatch) -> np.ndarray:
        import time
        t0 = time.perf_counter()
        h = np.zeros(batch.num_rows, np.int64)
        for key in keys:
            k = _row_keys(batch, key)
            h = h * np.int64(1000003) + k
        out = (_kernel_buckets(h, num_buckets) if kernel
               else _mix_mod(h, num_buckets))
        EXCHANGE_TIMERS["hash"] += time.perf_counter() - t0
        return out
    return partitioner


# -- whole-stage fusion: pre-bucketed map output (DESIGN.md §14) -------------
#
# A fused stage program finishes the map side *inside* the task — partial
# aggregate, bucket assignment, and per-bucket slicing all happen before
# control returns to the scheduler.  The task then hands back a
# BucketedBatch: the per-reducer pieces in bucket order, produced by the
# exact slicing the scheduler would otherwise apply (same stable argsort /
# searchsorted / take), so shuffle blocks are byte-identical to the
# segment-at-a-time path — including under lineage recovery, where the
# re-run task re-derives the same pieces deterministically.


class BucketedBatch:
    """Map output already split into per-reducer pieces (bucket order)."""

    def __init__(self, pieces: List[PartitionBatch]):
        self.pieces = pieces

    @property
    def num_rows(self) -> int:
        return sum(p.num_rows for p in self.pieces)

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.pieces)


def split_bucket_pieces(batch: PartitionBatch, bucket_of: np.ndarray,
                        num_buckets: int) -> List[PartitionBatch]:
    """Slice `batch` into per-bucket pieces — the scheduler's legacy
    slicing, verbatim, so fused and seam-by-seam shuffle blocks match."""
    order = np.argsort(bucket_of, kind="stable")
    sorted_buckets = np.asarray(bucket_of)[order]
    bounds = np.searchsorted(sorted_buckets, np.arange(num_buckets + 1))
    return [batch.take(order[bounds[b]:bounds[b + 1]])
            for b in range(num_buckets)]


def single_bucket() -> Callable[[PartitionBatch], np.ndarray]:
    """Degenerate partitioner: everything to reducer 0 (the MPP-style single
    coordinator plan the paper contrasts against in §6.2.2)."""
    def partitioner(batch: PartitionBatch) -> np.ndarray:
        return np.zeros(batch.num_rows, np.int32)
    return partitioner
