"""Out-of-core storage tier: spill-to-disk with adaptive recompression
(DESIGN.md §12).

Shark's memory store is a *cache* over recomputable data (paper §3.2); the
only pressure valve the server had was LRU eviction + recompute-from-lineage,
which thrashes once the working set exceeds the budget.  This module adds the
storage hierarchy between "in memory decoded" and "gone":

  HOT   resident column blocks, memoized decode caches allowed;
  WARM  resident but squeezed — decode caches dropped, blocks adaptively
        *recompressed* (RLE / BITPACK / frame-of-reference picked from
        run-length, span and NDV signals, `compression.choose_recompression`);
  COLD  spilled to disk as a self-describing compressed segment with a
        checksum (or dropped outright in `mode="drop"`, the
        eviction+recompute baseline the spill bench compares against).

Cold partitions fault back in transparently through `Partition.columns`:
the spill segment is read and checksum-verified first; a lost or corrupt
file falls back to recompute-from-lineage — never a wrong answer, exactly
the fault contract of the BlockManager's cached batches.

Spill writes are *write-behind*: `evict()` serializes synchronously (the
bytes must exist before the blocks are released) but performs the file I/O
on a background writer thread; until the flush lands, reads are served from
the in-flight payload (read-your-writes).

Spill segment format (little-endian):

    b"SHRKSPL1" | u32 header_len | header JSON | array payload | u32 crc32

The header describes every column block (field, encoding, per-array dtype
and shape, bias/bit width, string dictionary, stats snapshot); the crc32
covers everything before it.  Segments are self-describing: a reader needs
no catalog state to reconstruct the partition.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import queue
import shutil
import struct
import tempfile
import threading
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .columnar import ColumnBlock, ColumnStats, Partition
from .compression import Encoded, Encoding
from .types import DType, Field

MAGIC = b"SHRKSPL1"

_ARRAY_FIELDS = ("data", "codes", "dictionary", "run_values", "run_lengths",
                 "words")


class SpillCorrupt(Exception):
    """A spill segment failed structural or checksum validation."""


@dataclasses.dataclass
class SpillRef:
    """Handle to one cold partition's on-disk (or in-flight) segment."""
    path: str
    nbytes: int


# ---------------------------------------------------------------------------
# Segment serialization
# ---------------------------------------------------------------------------


def _py(v):
    """JSON-safe scalar (numpy scalars -> python)."""
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.str_, np.bool_)):
        return v.item()
    return v


def _stats_to_json(s: ColumnStats) -> dict:
    return {"min": s.min, "max": s.max, "count": s.count, "nbytes": s.nbytes,
            "null_count": s.null_count,
            "distinct": (sorted(_py(v) for v in s.distinct)
                         if s.distinct is not None else None)}


def _stats_from_json(d: dict) -> ColumnStats:
    distinct = frozenset(d["distinct"]) if d["distinct"] is not None else None
    return ColumnStats(min=d["min"], max=d["max"], distinct=distinct,
                       count=d["count"], nbytes=d["nbytes"],
                       null_count=d["null_count"])


def serialize_partition(index: int, columns: Dict[str, ColumnBlock]) -> bytes:
    """Encode a partition's column blocks as one self-describing segment."""
    cols_meta: List[dict] = []
    chunks: List[bytes] = []
    for name, block in columns.items():
        enc = block.enc
        arrays = []
        for fld in _ARRAY_FIELDS:
            a = getattr(enc, fld)
            if a is None:
                continue
            raw = np.ascontiguousarray(a).tobytes()
            arrays.append({"field": fld, "dtype": a.dtype.str,
                           "shape": list(a.shape), "nbytes": len(raw)})
            chunks.append(raw)
        meta = {"name": name, "dtype": block.field.dtype.value,
                "encoding": enc.encoding.value, "n": enc.n,
                "bit_width": enc.bit_width, "bias": enc.bias,
                "orig_dtype": (np.dtype(enc.orig_dtype).str
                               if enc.orig_dtype is not None else None),
                "arrays": arrays, "stats": _stats_to_json(block.stats),
                "str_dict": None}
        if block.str_dict is not None:
            raw = np.ascontiguousarray(block.str_dict).tobytes()
            meta["str_dict"] = {"dtype": block.str_dict.dtype.str,
                                "shape": list(block.str_dict.shape),
                                "nbytes": len(raw)}
            chunks.append(raw)
        cols_meta.append(meta)
    header = json.dumps({"kind": "partition", "index": index,
                         "columns": cols_meta}).encode()
    body = b"".join([MAGIC, struct.pack("<I", len(header)), header] + chunks)
    return body + struct.pack("<I", zlib.crc32(body))


def _take(payload: bytes, offset: int, spec: dict) -> Tuple[np.ndarray, int]:
    nbytes = spec["nbytes"]
    raw = payload[offset: offset + nbytes]
    if len(raw) != nbytes:
        raise SpillCorrupt("truncated array payload")
    arr = np.frombuffer(raw, dtype=np.dtype(spec["dtype"]))
    return arr.reshape(spec["shape"]).copy(), offset + nbytes


def deserialize_partition(data: bytes) -> Tuple[int, Dict[str, ColumnBlock]]:
    """Validate and decode one spill segment; raises SpillCorrupt on any
    structural or checksum mismatch (the caller treats that as a lost file
    and recomputes from lineage)."""
    if len(data) < len(MAGIC) + 8 or data[: len(MAGIC)] != MAGIC:
        raise SpillCorrupt("bad magic")
    body, (crc,) = data[:-4], struct.unpack("<I", data[-4:])
    if zlib.crc32(body) != crc:
        raise SpillCorrupt("checksum mismatch")
    (hlen,) = struct.unpack_from("<I", body, len(MAGIC))
    hstart = len(MAGIC) + 4
    try:
        header = json.loads(body[hstart: hstart + hlen].decode())
    except ValueError as e:
        raise SpillCorrupt(f"bad header: {e}") from e
    offset = hstart + hlen
    if header.get("kind", "partition") != "partition":
        raise SpillCorrupt(f"not a partition segment: {header.get('kind')}")
    columns: Dict[str, ColumnBlock] = {}
    for meta in header["columns"]:
        kwargs = {}
        for spec in meta["arrays"]:
            kwargs[spec["field"]], offset = _take(body, offset, spec)
        enc = Encoded(Encoding(meta["encoding"]), n=meta["n"],
                      bit_width=meta["bit_width"], bias=meta["bias"],
                      orig_dtype=(np.dtype(meta["orig_dtype"])
                                  if meta["orig_dtype"] is not None else None),
                      **kwargs)
        str_dict = None
        if meta["str_dict"] is not None:
            str_dict, offset = _take(body, offset, meta["str_dict"])
        field = Field(meta["name"], DType(meta["dtype"]))
        columns[meta["name"]] = ColumnBlock(field, enc,
                                            _stats_from_json(meta["stats"]),
                                            str_dict)
    return header["index"], columns


def serialize_batch(batch) -> bytes:
    """Encode one shuffle block (PartitionBatch) as a self-describing
    segment — the SHUFFLE sibling of `serialize_partition`, sharing the
    container framing (magic | header | arrays | crc32).  Columns
    materialize on serialization (shuffle blocks are already materialized
    row views; block-backed columns decode once here), and string columns
    keep their dictionary-preserving (codes, dictionary) form so a faulted
    block is byte-identical to the in-memory one the reduce side expects."""
    cols_meta: List[dict] = []
    chunks: List[bytes] = []
    for name, v in batch.cols.items():
        arr = np.ascontiguousarray(np.asarray(v.arr))
        raw = arr.tobytes()
        meta = {"name": name, "dtype": arr.dtype.str,
                "shape": list(arr.shape), "nbytes": len(raw),
                "sorted_dict": bool(v.sorted_dict), "sdict": None}
        chunks.append(raw)
        if v.sdict is not None:
            sraw = np.ascontiguousarray(v.sdict).tobytes()
            meta["sdict"] = {"dtype": v.sdict.dtype.str,
                             "shape": list(v.sdict.shape),
                             "nbytes": len(sraw)}
            chunks.append(sraw)
        cols_meta.append(meta)
    header = json.dumps({"kind": "shuffle", "columns": cols_meta}).encode()
    body = b"".join([MAGIC, struct.pack("<I", len(header)), header] + chunks)
    return body + struct.pack("<I", zlib.crc32(body))


def deserialize_batch(data: bytes):
    """Validate and decode one shuffle segment; raises SpillCorrupt on any
    structural or checksum mismatch (the caller treats that as a lost map
    output: FetchFailed -> recompute from lineage)."""
    from .batch import PartitionBatch
    from .expr import ColumnVal
    if len(data) < len(MAGIC) + 8 or data[: len(MAGIC)] != MAGIC:
        raise SpillCorrupt("bad magic")
    body, (crc,) = data[:-4], struct.unpack("<I", data[-4:])
    if zlib.crc32(body) != crc:
        raise SpillCorrupt("checksum mismatch")
    (hlen,) = struct.unpack_from("<I", body, len(MAGIC))
    hstart = len(MAGIC) + 4
    try:
        header = json.loads(body[hstart: hstart + hlen].decode())
    except ValueError as e:
        raise SpillCorrupt(f"bad header: {e}") from e
    if header.get("kind") != "shuffle":
        raise SpillCorrupt(f"not a shuffle segment: {header.get('kind')}")
    offset = hstart + hlen
    cols: Dict[str, "ColumnVal"] = {}
    for meta in header["columns"]:
        arr, offset = _take(body, offset, meta)
        sdict = None
        if meta["sdict"] is not None:
            sdict, offset = _take(body, offset, meta["sdict"])
        cols[meta["name"]] = ColumnVal(arr, sdict,
                                       sorted_dict=meta["sorted_dict"])
    return PartitionBatch(cols)


# ---------------------------------------------------------------------------
# StorageManager — the tier orchestrator
# ---------------------------------------------------------------------------


class StorageManager:
    """Owns the cold tier: spill directory, write-behind thread, checksummed
    reads with lineage fallback, and the WARM recompression hook.  Attached
    to the server's MemoryManager, which decides *when* to change tiers;
    this class knows *how*.

    `mode="spill"` is the real storage tier; `mode="drop"` releases cold
    partitions without writing anything (every fault recomputes from
    lineage) — the eviction+recompute baseline `benchmarks/spill_bench.py`
    measures against."""

    def __init__(self, spill_dir: Optional[str] = None, mode: str = "spill",
                 async_write: bool = True, policy=None):
        assert mode in ("spill", "drop"), mode
        self.mode = mode
        self.policy = policy       # core.resilience.ResiliencePolicy | None
        self.chaos = None          # core.faults.ChaosEngine, when installed
        env_dir = os.environ.get("SHARK_SPILL_DIR")
        self._own_dir = spill_dir is None and env_dir is None
        self.dir = spill_dir or env_dir or tempfile.mkdtemp(
            prefix="shark-spill-")
        os.makedirs(self.dir, exist_ok=True)
        self.lock = threading.RLock()
        self._seq = itertools.count()
        self._pending: Dict[str, bytes] = {}   # enqueued, not yet flushed
        self._live: set = set()                # paths of live segments
        # counters (monotonic unless noted; exposed via stats())
        self.spills = 0                 # cold transitions that wrote a segment
        self.drops = 0                  # cold transitions in drop mode
        self.spill_bytes = 0            # CURRENT live segment bytes (disk+pending)
        self.spill_write_bytes = 0      # total segment bytes ever written
        self.spill_reads = 0            # faults served from a segment
        self.spill_read_bytes = 0
        self.spill_lost = 0             # fault found the file missing
        self.spill_corrupt = 0          # fault found the file corrupt
        self.lineage_faults = 0         # faults that recomputed from lineage
        self.shuffle_spills = 0         # shuffle blocks written to a segment
        self.shuffle_faults = 0         # shuffle blocks read back from disk
        self.shuffle_lost = 0           # shuffle faults that found no segment
        self.recompressions = 0         # blocks shrunk by the WARM hook
        self.recompressed_bytes = 0
        self.released_bytes = 0         # resident bytes freed by cold transitions
        self._queue: "queue.Queue" = queue.Queue()
        self._writer: Optional[threading.Thread] = None
        if async_write and mode == "spill":
            self._writer = threading.Thread(target=self._writer_loop,
                                            name="shark-spill-writer",
                                            daemon=True)
            self._writer.start()

    # -- WARM: adaptive recompression ----------------------------------------

    def recompress_partition(self, part: Partition) -> int:
        """Apply the WARM transition to one partition; returns bytes freed."""
        freed = part.recompress()
        if freed > 0:
            with self.lock:
                self.recompressions += 1
                self.recompressed_bytes += freed
        return freed

    # -- COLD: spill / drop ---------------------------------------------------

    def evict(self, table_name: str, part: Partition) -> int:
        """Transition one resident partition to the cold tier.  In spill
        mode the segment is serialized now and flushed by the write-behind
        thread; in drop mode the blocks are simply released.  Returns
        resident bytes freed."""
        with self.lock:
            if not part.resident:
                return 0
            if self.mode == "spill":
                # chaos seam "spill.write": the segment write silently
                # vanishes (never reaches disk); only armed for partitions
                # with lineage — the read side then degrades to
                # recompute-from-lineage, never to data loss
                trip = None
                if self.chaos is not None and part.lineage is not None:
                    trip = self.chaos.fire("spill.write")
                payload = serialize_partition(part.index, part._columns)
                path = os.path.join(
                    self.dir,
                    f"spill-{next(self._seq):06d}-{table_name}"
                    f"-p{part.index}.shk")
                part.spill_ref = SpillRef(path, len(payload))
                self._live.add(path)
                self.spills += 1
                self.spill_bytes += len(payload)
                self.spill_write_bytes += len(payload)
                if trip is None:
                    self._pending[path] = payload
                    if self._writer is not None:
                        self._queue.put((path, payload))
                    else:
                        self._flush_one(path, payload)
            else:
                part.spill_ref = None
                self.drops += 1
            part.storage = self
            freed = part.release_columns()
            self.released_bytes += freed
            return freed

    def fault_in(self, part: Partition) -> None:
        """Bring a cold partition back: segment read (verify checksum) with
        recompute-from-lineage fallback on a lost or corrupt file."""
        with self.lock:
            if part.resident:
                return
            columns = None
            ref = part.spill_ref
            if ref is not None:
                # chaos seam "spill.read": kind "lost" pretends the file
                # vanished, "corrupt" flips a payload byte so the checksum
                # rejects it; armed only with lineage to recompute from
                trip = None
                if self.chaos is not None and part.lineage is not None:
                    trip = self.chaos.fire("spill.read")
                if trip is not None and trip.kind != "corrupt":
                    data = None
                    self.spill_lost += 1
                else:
                    data = self._pending.get(ref.path)
                    if data is None:
                        try:
                            with open(ref.path, "rb") as f:
                                data = f.read()
                        except OSError:
                            self.spill_lost += 1
                    if trip is not None and data is not None:
                        data = data[:-1] + bytes([data[-1] ^ 0xFF])
                if data is not None:
                    try:
                        _, columns = deserialize_partition(data)
                        self.spill_reads += 1
                        self.spill_read_bytes += len(data)
                    except SpillCorrupt:
                        self.spill_corrupt += 1
                self._forget(part)
            if columns is None:
                if part.lineage is None:
                    raise RuntimeError(
                        "cold partition lost its spill segment and has no "
                        "lineage to recompute from")
                self.lineage_faults += 1
                columns = part.lineage()
            part.restore_columns(columns)

    def _forget(self, part: Partition) -> None:
        """Retire a partition's segment (fault-in consumed it, or the table
        was dropped): release the path, payload bytes, and the file."""
        ref = part.spill_ref
        if ref is None:
            return
        part.spill_ref = None
        self._pending.pop(ref.path, None)
        self._live.discard(ref.path)
        self.spill_bytes -= ref.nbytes
        try:
            os.remove(ref.path)
        except OSError:
            pass

    # -- COLD: shuffle blocks -------------------------------------------------

    def spill_shuffle(self, key: Tuple, batch) -> Optional[SpillRef]:
        """Write one shuffle block to the cold tier (spill mode only —
        dropping shuffle output mid-query forces recompute storms, so drop
        mode never evicts shuffle blocks).  Same write-behind path as
        partition segments; the block key lands in the file name for
        operator forensics."""
        if self.mode != "spill":
            return None
        # chaos seam "spill.write": a lost shuffle segment degrades to
        # FetchFailed -> lineage recompute on the read side, always safe
        trip = self.chaos.fire("spill.write") if self.chaos is not None \
            else None
        payload = serialize_batch(batch)
        path = os.path.join(
            self.dir,
            f"shuf-{next(self._seq):06d}"
            f"-s{key[1]}-m{key[2]}-b{key[3]}.shk")
        with self.lock:
            self._live.add(path)
            self.shuffle_spills += 1
            self.spills += 1
            self.spill_bytes += len(payload)
            self.spill_write_bytes += len(payload)
            if trip is None:
                self._pending[path] = payload
                if self._writer is not None:
                    self._queue.put((path, payload))
                else:
                    self._flush_one(path, payload)
        return SpillRef(path, len(payload))

    def fault_shuffle(self, ref: SpillRef):
        """Read one spilled shuffle block back; returns None when the
        segment is lost or corrupt — the caller reports the map output
        missing (FetchFailed) and the scheduler recomputes it from lineage,
        the same fault contract as partition segments."""
        # chaos seam "spill.read" (shuffle side): both kinds surface as a
        # missing segment — the caller raises FetchFailed and the scheduler
        # recomputes the map output from lineage
        if self.chaos is not None:
            trip = self.chaos.fire("spill.read")
            if trip is not None:
                with self.lock:
                    self.shuffle_lost += 1
                    if trip.kind == "corrupt":
                        self.spill_corrupt += 1
                    else:
                        self.spill_lost += 1
                return None
        with self.lock:
            data = self._pending.get(ref.path)
        if data is None:
            try:
                with open(ref.path, "rb") as f:
                    data = f.read()
            except OSError:
                with self.lock:
                    self.shuffle_lost += 1
                    self.spill_lost += 1
                return None
        try:
            batch = deserialize_batch(data)
        except SpillCorrupt:
            with self.lock:
                self.spill_corrupt += 1
                self.shuffle_lost += 1
            return None
        with self.lock:
            self.shuffle_faults += 1
            self.spill_reads += 1
            self.spill_read_bytes += len(data)
        return batch

    def forget_shuffle(self, ref: SpillRef) -> None:
        """Retire one shuffle segment (its shuffle finished, or its block
        was recomputed): release path, pending payload, and file."""
        with self.lock:
            self._pending.pop(ref.path, None)
            self._live.discard(ref.path)
            self.spill_bytes -= ref.nbytes
        try:
            os.remove(ref.path)
        except OSError:
            pass

    # -- write-behind ---------------------------------------------------------

    def _flush_one(self, path: str, payload: bytes) -> None:
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
        with self.lock:
            if path in self._live:
                os.replace(tmp, path)
                self._pending.pop(path, None)
            else:
                # faulted in (or dropped) before the flush landed
                try:
                    os.remove(tmp)
                except OSError:
                    pass

    def _writer_loop(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                self._flush_one(*item)
            finally:
                self._queue.task_done()

    def flush(self) -> None:
        """Block until every enqueued segment write has landed (tests and
        deterministic chaos injection)."""
        self._queue.join()

    # -- reporting / lifecycle ------------------------------------------------

    def stats(self) -> Dict[str, int]:
        with self.lock:
            return {
                "mode": self.mode,
                "spills": self.spills,
                "drops": self.drops,
                "spill_bytes": self.spill_bytes,
                "spill_write_bytes": self.spill_write_bytes,
                "spill_reads": self.spill_reads,
                "spill_read_bytes": self.spill_read_bytes,
                "spill_lost": self.spill_lost,
                "spill_corrupt": self.spill_corrupt,
                "lineage_faults": self.lineage_faults,
                "shuffle_spills": self.shuffle_spills,
                "shuffle_faults": self.shuffle_faults,
                "shuffle_lost": self.shuffle_lost,
                "recompressions": self.recompressions,
                "recompressed_bytes": self.recompressed_bytes,
                "released_bytes": self.released_bytes,
            }

    def shutdown(self) -> None:
        if self._writer is not None:
            join_s = (self.policy.spill_join_timeout_s
                      if self.policy is not None else 10.0)
            self._queue.put(None)
            self._writer.join(timeout=join_s)
            self._writer = None
        with self.lock:
            for path in list(self._live):
                try:
                    os.remove(path)
                except OSError:
                    pass
            self._live.clear()
            self._pending.clear()
        if self._own_dir:
            shutil.rmtree(self.dir, ignore_errors=True)
