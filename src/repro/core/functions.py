"""Expression builders for the SharkFrame fluent API (DESIGN.md §7).

These construct the *same* Expr / aggregate AST the SQL parser emits, so a
fluent query and its SQL-text twin bind to identical logical plans:

    from repro.core.functions import col, sum_, count

    sess.table("uservisits") \\
        .filter(col("visitDate") > 10500) \\
        .group_by(col("destURL")) \\
        .agg(sum_(col("adRevenue")).alias("rev"), count().alias("n"))

Aggregate builders return the parser's `_AggExpr` node; `.alias(name)` (from
`Expr`) attaches the output column name, exactly like `AS name` in SQL.
Names with a trailing underscore (`sum_`, `min_`, ...) avoid shadowing
Python built-ins.
"""

from __future__ import annotations

from typing import Any, Optional

from .expr import Col, Expr, Func, Lit
from .plan import AggFunc
from .sql import _AggExpr

__all__ = [
    "col", "lit", "sum_", "avg", "min_", "max_", "count", "count_distinct",
    "substr", "lower", "upper", "length", "abs_", "sqrt", "log", "exp",
    "floor", "ceil", "year",
]


def col(name: str) -> Col:
    """Reference a column by name."""
    return Col(name)


def lit(value: Any) -> Lit:
    """A literal constant (int, float, str, bool)."""
    return Lit(value)


def _expr(e) -> Expr:
    return e if isinstance(e, Expr) else Lit(e)


# -- aggregates ---------------------------------------------------------------


def sum_(e) -> _AggExpr:
    return _AggExpr(AggFunc.SUM, _expr(e), False)


def avg(e) -> _AggExpr:
    return _AggExpr(AggFunc.AVG, _expr(e), False)


def min_(e) -> _AggExpr:
    return _AggExpr(AggFunc.MIN, _expr(e), False)


def max_(e) -> _AggExpr:
    return _AggExpr(AggFunc.MAX, _expr(e), False)


def count(e: Optional[Expr] = None) -> _AggExpr:
    """COUNT(*) when called with no argument, else COUNT(expr)."""
    return _AggExpr(AggFunc.COUNT, None if e is None else _expr(e), False)


def count_distinct(e) -> _AggExpr:
    return _AggExpr(AggFunc.COUNT, _expr(e), True)


# -- scalar functions (same names the SQL dialect accepts) --------------------


def substr(e, start: int, length: int) -> Func:
    """1-based substring, matching SQL SUBSTR(s, start, len)."""
    return Func("SUBSTR", (_expr(e), Lit(start), Lit(length)))


def lower(e) -> Func:
    return Func("LOWER", (_expr(e),))


def upper(e) -> Func:
    return Func("UPPER", (_expr(e),))


def length(e) -> Func:
    return Func("LENGTH", (_expr(e),))


def abs_(e) -> Func:
    return Func("ABS", (_expr(e),))


def sqrt(e) -> Func:
    return Func("SQRT", (_expr(e),))


def log(e) -> Func:
    return Func("LOG", (_expr(e),))


def exp(e) -> Func:
    return Func("EXP", (_expr(e),))


def floor(e) -> Func:
    return Func("FLOOR", (_expr(e),))


def ceil(e) -> Func:
    return Func("CEIL", (_expr(e),))


def year(e) -> Func:
    return Func("YEAR", (_expr(e),))
