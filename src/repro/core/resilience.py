"""Resilience policy layer (DESIGN.md §16).

One `ResiliencePolicy` object owns every failure-handling decision the
engine used to scatter across hardcoded constants: how errors are
classified (retryable infrastructure fault vs deterministic application
error), how retries back off, when a hung task is reaped and relaunched,
when a flaky worker is quarantined from scheduling, and when a fleet
replica's circuit breaker stops routing to it.  The policy is *consumed*
by `Scheduler._run_tasks`, `BlockManager.wait_shuffle`, `StorageManager`,
`MeshContext`, and `SharkFleet`; it makes no decisions at a distance — each
layer asks the policy and acts locally, so the decision points stay
greppable.

Error classification (the satellite bugfix this layer exists for): the
seed scheduler retried *any* task exception up to the attempt cap, so a
deterministic application error — a bad expression on one partition —
surfaced late, with a retry-mangled traceback, after burning every worker.
`is_retryable` draws the line: infrastructure faults (`WorkerLost`,
`FetchFailed`, `DeviceLost`, `SpillCorrupt`, `ShuffleWaitTimeout`,
`ReplicaLost`) retry with deterministic exponential backoff; anything else
is presumed deterministic and fails fast with the ORIGINAL traceback after
at most `app_error_probes` cross-worker probes (the probe distinguishes
"this partition's data is poison" from "that worker's environment is
poison" — a deterministic task failing identically elsewhere is an
application bug).

The hung-task reaper covers the case speculation structurally cannot:
speculative backups need completed-task durations to estimate a straggler
threshold, so a stage whose *every* task hangs (e.g. a worker wedged on a
lock) deadlocked the seed scheduler forever.  With `task_deadline_s` set,
a task running past the deadline is abandoned (its future is dropped, so a
late result is never observed; late shuffle writes are discarded by the
BlockManager's exactly-once released-shuffle guard) and relaunched on
another worker — even when zero tasks have completed.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Set


class ShuffleWaitTimeout(TimeoutError):
    """`BlockManager.wait_shuffle` gave up: names the shuffle and the map
    splits still missing, so lineage/fleet layers can act on it (the seed
    raised a bare timeout naming nothing).  Subclasses TimeoutError for
    back-compat with callers that catch the old type."""

    def __init__(self, shuffle_id: int, missing_maps: List[int],
                 waited_s: float):
        super().__init__(
            f"shuffle {shuffle_id} wait timed out after {waited_s:.1f}s; "
            f"map splits still missing: {missing_maps}")
        self.shuffle_id = shuffle_id
        self.missing_maps = missing_maps
        self.waited_s = waited_s


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Every failure-handling knob in one frozen, printable object."""

    # task retry (Scheduler._run_tasks)
    max_task_attempts: int = 8          # per-split attempt cap
    max_stage_retries: int = 6          # FetchFailed -> lineage retry cap
    app_error_probes: int = 1           # cross-worker probes before fail-fast
    # deterministic exponential backoff between retryable failures
    backoff_base_s: float = 0.005
    backoff_factor: float = 2.0
    backoff_max_s: float = 0.25
    # hung-task reaper (None = off; speculation remains the straggler path)
    task_deadline_s: Optional[float] = None
    # flaky-worker quarantine
    quarantine_threshold: int = 3       # consecutive failures -> quarantine
    quarantine_probe_s: float = 0.5     # probation delay before re-admission
    # shuffle wait (BlockManager.wait_shuffle)
    shuffle_wait_timeout_s: float = 30.0
    # fleet (SharkFleet / FleetHandle)
    fleet_poll_s: float = 0.02
    fleet_reroute_limit: int = 4
    breaker_failure_threshold: int = 3
    breaker_reset_s: float = 0.25
    # mesh (MeshContext dispatch retry budget)
    mesh_max_retries: int = 3
    # storage (StorageManager.shutdown writer join)
    spill_join_timeout_s: float = 10.0

    def backoff(self, n_failures: int) -> float:
        """Delay before the n-th retry of one task (deterministic schedule):
        the first retry is immediate — the common single-kill chaos case
        must not pay latency — then base * factor^(n-2), capped."""
        if n_failures <= 1:
            return 0.0
        return min(self.backoff_base_s
                   * self.backoff_factor ** (n_failures - 2),
                   self.backoff_max_s)

    def is_retryable(self, exc: BaseException) -> bool:
        """Infrastructure faults retry; deterministic application errors do
        not.  Lazy imports keep this module dependency-free (runtime,
        storage, and the cluster tier all import *us*)."""
        if isinstance(exc, ShuffleWaitTimeout):
            return True
        if getattr(exc, "shark_retryable", False):
            return True  # escape hatch for user-defined infra errors
        from .runtime import FetchFailed, WorkerLost
        if isinstance(exc, (FetchFailed, WorkerLost)):
            return True
        from .storage import SpillCorrupt
        if isinstance(exc, SpillCorrupt):
            return True
        try:
            from ..cluster.mesh import DeviceLost
            from ..cluster.fleet import ReplicaLost
        except ImportError:           # cluster tier not importable here
            return False
        return isinstance(exc, (DeviceLost, ReplicaLost))

    def describe(self) -> str:
        pairs = ", ".join(f"{f.name}={getattr(self, f.name)}"
                          for f in dataclasses.fields(self))
        return f"ResiliencePolicy({pairs})"


class WorkerHealth:
    """Per-worker health scores with quarantine + probed re-admission.

    A worker accumulating `quarantine_threshold` CONSECUTIVE failures is
    quarantined: `excluded()` reports it and `_pick_worker` skips it.  After
    `quarantine_probe_s` the worker enters *probation* — it becomes
    schedulable again, but a single probe task decides: success re-admits
    (score reset), failure re-quarantines with a fresh clock.  Any success
    anywhere resets the consecutive-failure count (the score is about
    flakiness NOW, not history)."""

    def __init__(self, policy: ResiliencePolicy):
        self.policy = policy
        self.lock = threading.Lock()
        self.failures: Dict[int, int] = {}      # consecutive failures
        self.quarantined: Dict[int, float] = {}  # worker -> quarantine time
        self.quarantines = 0
        self.readmissions = 0

    def record_failure(self, worker: int, now: Optional[float] = None
                       ) -> bool:
        """Returns True when this failure (newly) quarantines the worker."""
        now = time.monotonic() if now is None else now
        with self.lock:
            n = self.failures.get(worker, 0) + 1
            self.failures[worker] = n
            if worker in self.quarantined:
                # failed its probation probe: fresh quarantine clock
                self.quarantined[worker] = now
                self.quarantines += 1
                return True
            if n >= self.policy.quarantine_threshold:
                self.quarantined[worker] = now
                self.quarantines += 1
                return True
            return False

    def record_success(self, worker: int) -> None:
        with self.lock:
            self.failures[worker] = 0
            if self.quarantined.pop(worker, None) is not None:
                self.readmissions += 1

    def excluded(self, now: Optional[float] = None) -> Set[int]:
        """Workers the scheduler must not pick: quarantined AND not yet due
        for their probation probe."""
        now = time.monotonic() if now is None else now
        probe = self.policy.quarantine_probe_s
        with self.lock:
            return {w for w, t in self.quarantined.items()
                    if now - t < probe}

    def forget(self, worker: int) -> None:
        """The worker left the cluster (killed): drop its health state."""
        with self.lock:
            self.failures.pop(worker, None)
            self.quarantined.pop(worker, None)

    def stats(self) -> Dict[str, int]:
        with self.lock:
            return {"quarantines": self.quarantines,
                    "readmissions": self.readmissions,
                    "quarantined_now": len(self.quarantined)}


class CircuitBreaker:
    """Per-replica breaker for SharkFleet routing (CLOSED / OPEN /
    HALF_OPEN).  `breaker_failure_threshold` consecutive failures open it;
    after `breaker_reset_s` ONE probe query is admitted (half-open): its
    success re-closes the breaker, its failure re-opens with a fresh clock.
    `routable()` is side-effect-free (the routing filter); `on_route()`
    consumes the half-open probe slot."""

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, policy: ResiliencePolicy):
        self.policy = policy
        self.lock = threading.Lock()
        self.state = self.CLOSED
        self.failures = 0
        self.opened_at = 0.0
        self._probe_inflight = False
        self.opens = 0
        self.closes = 0

    def routable(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        with self.lock:
            if self.state == self.CLOSED:
                return True
            if self.state == self.OPEN:
                return now - self.opened_at >= self.policy.breaker_reset_s
            return not self._probe_inflight      # HALF_OPEN

    def on_route(self, now: Optional[float] = None) -> None:
        """A query was just routed here: if the breaker was open-and-due,
        this query IS the half-open probe."""
        now = time.monotonic() if now is None else now
        with self.lock:
            if (self.state == self.OPEN
                    and now - self.opened_at >= self.policy.breaker_reset_s):
                self.state = self.HALF_OPEN
                self._probe_inflight = True
            elif self.state == self.HALF_OPEN:
                self._probe_inflight = True

    def record_success(self) -> None:
        with self.lock:
            if self.state != self.CLOSED:
                self.closes += 1
            self.state = self.CLOSED
            self.failures = 0
            self._probe_inflight = False

    def record_failure(self, now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self.lock:
            self.failures += 1
            if self.state == self.HALF_OPEN:
                self.state = self.OPEN          # probe failed: re-open
                self.opened_at = now
                self.opens += 1
            elif (self.state == self.CLOSED
                    and self.failures >= self.policy.breaker_failure_threshold):
                self.state = self.OPEN
                self.opened_at = now
                self.opens += 1
            self._probe_inflight = False

    def stats(self) -> Dict[str, object]:
        with self.lock:
            return {"state": self.state, "failures": self.failures,
                    "opens": self.opens, "closes": self.closes}


def describe_counters(counters: Dict[str, int], health: WorkerHealth,
                      policy: ResiliencePolicy,
                      extra: Optional[Sequence[str]] = None) -> str:
    """Shared `describe_resilience()` rendering: policy line, counter line,
    health line, plus caller-specific extra lines (breakers, trips)."""
    lines = [policy.describe()]
    if counters:
        lines.append("events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(counters.items())))
    else:
        lines.append("events: none")
    hs = health.stats()
    lines.append(f"workers: quarantines={hs['quarantines']} "
                 f"readmissions={hs['readmissions']} "
                 f"quarantined_now={hs['quarantined_now']}")
    if extra:
        lines.extend(extra)
    return "\n".join(lines)
