"""Architecture assembly: every assigned arch builds from ModelConfig.

Families:
  dense  — scanned stack of [norm->GQA->res, norm->MLP->res] blocks
  moe    — MLP replaced by routed experts (optionally MLA attention,
           optional dense layer 0 — DeepSeek-V2-Lite)
  ssm    — scanned Mamba2 (SSD) blocks
  hybrid — groups of [1 SHARED attention slot + k Mamba2 blocks] (Zamba2)
  vlm    — groups of [self layers + 1 gated cross-attn layer] over stub
           image embeddings (Llama-3.2-Vision)
  encdec — bidirectional encoder over stub frames + causal decoder with
           cross-attention (Whisper)

All layer stacks run under jax.lax.scan (stacked params, leading L axis) so
compile time stays bounded; bodies are jax.checkpoint'd when cfg.remat.
Three entry points per arch: loss_fn (train), prefill_fn, decode_fn, plus
cache_specs/input_specs used by the multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import MLAConfig, ModelConfig, ShapeConfig
from ..parallel.sharding import BATCH_AXES, act_shard, maybe_shard
from . import attention as att
from . import mamba2 as m2
from . import moe as moe_mod
from .common import (Params, Specs, chunked_softmax_xent, embed_init,
                     embed_lookup, mlp_apply, mlp_init, norm_apply,
                     norm_init)

AUX_LOSS_WEIGHT = 0.01


# ===========================================================================
# Parameter init
# ===========================================================================

def _decoder_layer_init(key, cfg: ModelConfig, n: int,
                        use_moe: bool) -> Tuple[Params, Specs]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = norm_init(cfg.norm, cfg.d_model, n)
    p["ln2"], s["ln2"] = norm_init(cfg.norm, cfg.d_model, n)
    if cfg.mla is not None:
        p["attn"], s["attn"] = att.mla_init(
            k1, cfg.d_model, cfg.n_heads, cfg.mla.kv_lora, cfg.mla.nope_dim,
            cfg.mla.rope_dim, cfg.mla.v_dim, n)
    else:
        p["attn"], s["attn"] = att.gqa_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, n,
            cfg.qkv_bias)
    if use_moe:
        p["moe"], s["moe"] = moe_mod.moe_init(k2, cfg.d_model, cfg.moe, n)
    else:
        p["mlp"], s["mlp"] = mlp_init(k2, cfg.mlp, cfg.d_model, cfg.d_ff, n)
    return p, s


def init_params(cfg: ModelConfig, key) -> Tuple[Params, Specs]:
    keys = jax.random.split(key, 16)
    p: Params = {}
    s: Specs = {}
    p["embed"], s["embed"] = embed_init(keys[0], cfg.vocab, cfg.d_model)
    p["final_norm"], s["final_norm"] = norm_init(cfg.norm, cfg.d_model)
    if not cfg.tie_embeddings:
        from .common import dense_init
        p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab)
        s["lm_head"] = P(None, "model")

    fam = cfg.family
    if fam in ("dense",):
        p["layers"], s["layers"] = _decoder_layer_init(
            keys[2], cfg, cfg.n_layers, use_moe=False)
    elif fam == "moe":
        n_moe = cfg.n_layers - (1 if cfg.moe.first_dense else 0)
        p["layers"], s["layers"] = _decoder_layer_init(
            keys[2], cfg, n_moe, use_moe=True)
        if cfg.moe.first_dense:
            dense_cfg = dataclasses.replace(cfg, d_ff=cfg.moe.dense_d_ff)
            p["layer0"], s["layer0"] = _decoder_layer_init(
                keys[3], dense_cfg, None, use_moe=False)
    elif fam == "ssm":
        p["layers"], s["layers"] = {}, {}
        p["layers"]["ln"], s["layers"]["ln"] = norm_init(
            cfg.norm, cfg.d_model, cfg.n_layers)
        p["layers"]["mamba"], s["layers"]["mamba"] = m2.mamba2_init(
            keys[2], cfg.d_model, cfg.ssm, cfg.n_layers)
    elif fam == "hybrid":
        per = cfg.attn_every  # group = 1 shared-attn slot + (per-1) mamba
        n_groups = cfg.n_layers // per
        n_group_mamba = per - 1
        n_tail = cfg.n_layers - n_groups * per
        gp, gs = {}, {}
        gp["ln"], gs["ln"] = norm_init(cfg.norm, cfg.d_model,
                                       n_groups * n_group_mamba)
        gp["mamba"], gs["mamba"] = m2.mamba2_init(
            keys[2], cfg.d_model, cfg.ssm, n_groups * n_group_mamba)
        p["group_mamba"] = jax.tree.map(
            lambda a: a.reshape((n_groups, n_group_mamba) + a.shape[1:]), gp)
        s["group_mamba"] = jax.tree.map(
            lambda sp: P(None, *sp), gs,
            is_leaf=lambda x: isinstance(x, P))
        if n_tail:
            tp, ts = {}, {}
            tp["ln"], ts["ln"] = norm_init(cfg.norm, cfg.d_model, n_tail)
            tp["mamba"], ts["mamba"] = m2.mamba2_init(
                keys[3], cfg.d_model, cfg.ssm, n_tail)
            p["tail_mamba"], s["tail_mamba"] = tp, ts
        ap, asx = {}, {}
        ap["ln"], asx["ln"] = norm_init(cfg.norm, cfg.d_model)
        ap["attn"], asx["attn"] = att.gqa_init(
            keys[4], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
        ap["ln2"], asx["ln2"] = norm_init(cfg.norm, cfg.d_model)
        ap["mlp"], asx["mlp"] = mlp_init(keys[5], cfg.mlp, cfg.d_model,
                                         cfg.d_ff)
        p["shared_attn"], s["shared_attn"] = ap, asx
    elif fam == "vlm":
        per = cfg.cross_every
        n_groups = cfg.n_layers // per
        n_self = per - 1
        sp_, ss_ = _decoder_layer_init(keys[2], cfg, n_groups * n_self,
                                       use_moe=False)
        p["self_layers"] = jax.tree.map(
            lambda a: a.reshape((n_groups, n_self) + a.shape[1:]), sp_)
        s["self_layers"] = jax.tree.map(
            lambda sp: P(None, *sp), ss_, is_leaf=lambda x: isinstance(x, P))
        cp, cs = {}, {}
        cp["ln"], cs["ln"] = norm_init(cfg.norm, cfg.d_model, n_groups)
        cp["attn"], cs["attn"] = att.gqa_init(
            keys[3], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            n_groups)
        cp["gate"] = jnp.zeros((n_groups,), jnp.float32)
        cs["gate"] = P(None)
        cp["ln_mlp"], cs["ln_mlp"] = norm_init(cfg.norm, cfg.d_model,
                                               n_groups)
        cp["mlp"], cs["mlp"] = mlp_init(keys[4], cfg.mlp, cfg.d_model,
                                        cfg.d_ff, n_groups)
        cp["mlp_gate"] = jnp.zeros((n_groups,), jnp.float32)
        cs["mlp_gate"] = P(None)
        p["cross_layers"], s["cross_layers"] = cp, cs
    elif fam == "encdec":
        ep, es = {}, {}
        ep["ln1"], es["ln1"] = norm_init(cfg.norm, cfg.d_model,
                                         cfg.enc_layers)
        ep["attn"], es["attn"] = att.gqa_init(
            keys[2], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            cfg.enc_layers)
        ep["ln2"], es["ln2"] = norm_init(cfg.norm, cfg.d_model,
                                         cfg.enc_layers)
        ep["mlp"], es["mlp"] = mlp_init(keys[3], cfg.mlp, cfg.d_model,
                                        cfg.d_ff, cfg.enc_layers)
        p["encoder"], s["encoder"] = ep, es
        p["enc_final_norm"], s["enc_final_norm"] = norm_init(cfg.norm,
                                                             cfg.d_model)
        dp, ds = _decoder_layer_init(keys[4], cfg, cfg.n_layers,
                                     use_moe=False)
        dp["ln_cross"], ds["ln_cross"] = norm_init(cfg.norm, cfg.d_model,
                                                   cfg.n_layers)
        dp["cross"], ds["cross"] = att.gqa_init(
            keys[5], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            cfg.n_layers)
        p["layers"], s["layers"] = dp, ds
    else:
        raise ValueError(fam)
    return p, s


# ===========================================================================
# Block applications (full sequence)
# ===========================================================================

def _attn_full(cfg: ModelConfig, lp: Params, x, positions, return_kv=False):
    if cfg.mla is not None:
        m = cfg.mla
        return att.mla_attention(lp, x, positions, cfg.n_heads, m.nope_dim,
                                 m.rope_dim, m.v_dim, cfg.kv_chunk,
                                 return_kv=return_kv,
                                 seq_shard=cfg.attn_seq_shard)
    return att.self_attention(lp, x, positions, cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, cfg.rope_theta, cfg.kv_chunk,
                              return_kv=return_kv,
                              scores_dtype=cfg.attn_scores_dtype,
                              chunk_remat=cfg.attn_chunk_remat,
                              impl=cfg.attn_impl,
                              seq_shard=cfg.attn_seq_shard)


def _decoder_block_full(cfg: ModelConfig, lp: Params, x, positions,
                        use_moe: bool, return_kv=False):
    h = norm_apply(cfg.norm, x, lp["ln1"])
    if return_kv:
        a, kv = _attn_full(cfg, lp["attn"], h, positions, True)
    else:
        a = _attn_full(cfg, lp["attn"], h, positions, False)
        kv = None
    x = x + a
    h = norm_apply(cfg.norm, x, lp["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        apply = (moe_mod.moe_apply_ep if cfg.moe_impl == "ep_shardmap"
                 else moe_mod.moe_apply)
        y, stats = apply(lp["moe"], h, cfg.moe, return_stats=True)
        # aux: penalize load imbalance via the dropped-assignment fraction
        # (the expert_load vector is also the PDE heavy-hitter statistic)
        aux = stats["frac_dropped"]
    else:
        y = mlp_apply(cfg.mlp, lp["mlp"], h)
    x = x + y
    x = act_shard(x, "hidden_seq" if cfg.seq_parallel_residual else "hidden")
    return x, kv, aux


def _scan_blocks(cfg: ModelConfig, layers: Params, x, positions,
                 use_moe: bool, collect_kv: bool):
    def body(carry, lp):
        xx, aux_sum = carry
        xx, kv, aux = _decoder_block_full(cfg, lp, xx, positions, use_moe,
                                          collect_kv)
        return (xx, aux_sum + aux), kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), kvs = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                 layers)
    return x, kvs, aux


# ===========================================================================
# Full-sequence forward (shared by train and prefill)
# ===========================================================================

def _backbone_full(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                   extra: Dict[str, jnp.ndarray], collect_kv: bool):
    """Returns (final hidden states, caches-if-collecting, aux loss)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_lookup(params["embed"], tokens)
    x = act_shard(x, "hidden_seq" if cfg.seq_parallel_residual else "hidden")
    caches: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam == "dense":
        x, kvs, aux = _scan_blocks(cfg, params["layers"], x, positions,
                                   False, collect_kv)
        if collect_kv:
            caches["k"], caches["v"] = kvs
    elif fam == "moe":
        if cfg.moe.first_dense:
            dense_cfg = dataclasses.replace(cfg, d_ff=cfg.moe.dense_d_ff)
            x, kv0, _ = _decoder_block_full(dense_cfg, params["layer0"], x,
                                            positions, False, collect_kv)
            if collect_kv:
                caches["kv0"] = kv0
        x, kvs, aux = _scan_blocks(cfg, params["layers"], x, positions,
                                   True, collect_kv)
        if collect_kv:
            if cfg.mla is not None:
                caches["ckv"], caches["kr"] = kvs
            else:
                caches["k"], caches["v"] = kvs
    elif fam == "ssm":
        def body(carry, lp):
            xx = carry
            h = norm_apply(cfg.norm, xx, lp["ln"])
            if collect_kv:
                y, st, cst = m2.mamba2_forward(lp["mamba"], h, cfg.d_model,
                                               cfg.ssm, return_state=True)
                return xx + y, (st, cst)
            y = m2.mamba2_forward(lp["mamba"], h, cfg.d_model, cfg.ssm)
            return xx + y, None
        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, sts = jax.lax.scan(body_fn, x, params["layers"])
        if collect_kv:
            caches["ssm"], caches["conv"] = sts
    elif fam == "hybrid":
        x, caches, aux = _hybrid_full(cfg, params, x, positions, collect_kv)
    elif fam == "vlm":
        x, caches, aux = _vlm_full(cfg, params, x, positions,
                                   extra["image_embeds"], collect_kv)
    elif fam == "encdec":
        enc = _encoder_full(cfg, params, extra["frames"])
        x, caches, aux = _encdec_decoder_full(cfg, params, x, positions, enc,
                                              collect_kv)
    else:
        raise ValueError(fam)

    x = norm_apply(cfg.norm, x, params["final_norm"])
    return x, caches, aux


def _hybrid_full(cfg: ModelConfig, params, x, positions, collect_kv):
    per = cfg.attn_every
    n_groups = cfg.n_layers // per
    caches: Dict[str, Any] = {}
    ap = params["shared_attn"]

    def group_body(carry, gp):
        xx = carry
        # shared attention slot (params closed over — shared across groups)
        h = norm_apply(cfg.norm, xx, ap["ln"])
        if collect_kv:
            a, kv = att.self_attention(
                ap["attn"], h, positions, cfg.n_heads, cfg.n_kv_heads,
                cfg.hd, cfg.rope_theta, cfg.kv_chunk, return_kv=True)
        else:
            a = att.self_attention(
                ap["attn"], h, positions, cfg.n_heads, cfg.n_kv_heads,
                cfg.hd, cfg.rope_theta, cfg.kv_chunk)
            kv = None
        xx = xx + a
        h = norm_apply(cfg.norm, xx, ap["ln2"])
        xx = xx + mlp_apply(cfg.mlp, ap["mlp"], h)

        def mamba_body(c2, lp):
            h2 = norm_apply(cfg.norm, c2, lp["ln"])
            if collect_kv:
                y, st, cst = m2.mamba2_forward(lp["mamba"], h2, cfg.d_model,
                                               cfg.ssm, return_state=True)
                return c2 + y, (st, cst)
            y = m2.mamba2_forward(lp["mamba"], h2, cfg.d_model, cfg.ssm)
            return c2 + y, None

        xx, sts = jax.lax.scan(mamba_body, xx, gp)
        xx = act_shard(xx, "hidden")
        return xx, (kv, sts)

    body_fn = jax.checkpoint(group_body) if cfg.remat else group_body
    x, ys = jax.lax.scan(body_fn, x, params["group_mamba"])
    if collect_kv:
        kvs, sts = ys
        caches["attn_k"], caches["attn_v"] = kvs
        caches["group_ssm"], caches["group_conv"] = sts

    if "tail_mamba" in params:
        def tail_body(carry, lp):
            h2 = norm_apply(cfg.norm, carry, lp["ln"])
            if collect_kv:
                y, st, cst = m2.mamba2_forward(lp["mamba"], h2, cfg.d_model,
                                               cfg.ssm, return_state=True)
                return carry + y, (st, cst)
            y = m2.mamba2_forward(lp["mamba"], h2, cfg.d_model, cfg.ssm)
            return carry + y, None
        tail_fn = jax.checkpoint(tail_body) if cfg.remat else tail_body
        x, tst = jax.lax.scan(tail_fn, x, params["tail_mamba"])
        if collect_kv:
            caches["tail_ssm"], caches["tail_conv"] = tst
    return x, caches, jnp.zeros((), jnp.float32)


def _vlm_full(cfg: ModelConfig, params, x, positions, image_embeds,
              collect_kv):
    caches: Dict[str, Any] = {}

    def group_body(carry, gp):
        xx = carry
        sp, cp = gp

        def self_body(c2, lp):
            y, kv, _ = _decoder_block_full(cfg, lp, c2, positions, False,
                                           collect_kv)
            return y, kv

        xx, kvs = jax.lax.scan(self_body, xx, sp)
        # gated cross-attention layer
        h = norm_apply(cfg.norm, xx, cp["ln"])
        ca = att.cross_attention(cp["attn"], h, image_embeds, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.hd)
        xx = xx + jnp.tanh(cp["gate"]).astype(xx.dtype) * ca
        h = norm_apply(cfg.norm, xx, cp["ln_mlp"])
        y = mlp_apply(cfg.mlp, cp["mlp"], h)
        xx = xx + jnp.tanh(cp["mlp_gate"]).astype(xx.dtype) * y
        xx = act_shard(xx, "hidden")
        ckv = att.cross_kv(cp["attn"], image_embeds, cfg.n_kv_heads,
                           cfg.hd) if collect_kv else None
        return xx, (kvs, ckv)

    body_fn = jax.checkpoint(group_body) if cfg.remat else group_body
    x, ys = jax.lax.scan(body_fn, x,
                         (params["self_layers"], params["cross_layers"]))
    if collect_kv:
        kvs, ckv = ys
        caches["k"], caches["v"] = kvs          # (G, S_len, B, ...)? no: see scan
        caches["xk"], caches["xv"] = ckv
    return x, caches, jnp.zeros((), jnp.float32)


def _encoder_full(cfg: ModelConfig, params, frames):
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = frames.astype(jnp.bfloat16)

    def body(carry, lp):
        xx = carry
        h = norm_apply(cfg.norm, xx, lp["ln1"])
        a = att.self_attention(lp["attn"], h, positions, cfg.n_heads,
                               cfg.n_kv_heads, cfg.hd, cfg.rope_theta,
                               cfg.kv_chunk, causal=False)
        xx = xx + a
        h = norm_apply(cfg.norm, xx, lp["ln2"])
        xx = xx + mlp_apply(cfg.mlp, lp["mlp"], h)
        return xx, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return norm_apply(cfg.norm, x, params["enc_final_norm"])


def _encdec_decoder_full(cfg: ModelConfig, params, x, positions, enc,
                         collect_kv):
    caches: Dict[str, Any] = {}

    def body(carry, lp):
        xx = carry
        h = norm_apply(cfg.norm, xx, lp["ln1"])
        if collect_kv:
            a, kv = att.self_attention(lp["attn"], h, positions, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.hd, cfg.rope_theta,
                                       cfg.kv_chunk, return_kv=True)
        else:
            a = att.self_attention(lp["attn"], h, positions, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd, cfg.rope_theta,
                                   cfg.kv_chunk)
            kv = None
        xx = xx + a
        h = norm_apply(cfg.norm, xx, lp["ln_cross"])
        xx = xx + att.cross_attention(lp["cross"], h, enc, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd)
        h = norm_apply(cfg.norm, xx, lp["ln2"])
        xx = xx + mlp_apply(cfg.mlp, lp["mlp"], h)
        ckv = att.cross_kv(lp["cross"], enc, cfg.n_kv_heads, cfg.hd) \
            if collect_kv else None
        return xx, (kv, ckv)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, ys = jax.lax.scan(body_fn, x, params["layers"])
    if collect_kv:
        kvs, ckv = ys
        caches["k"], caches["v"] = kvs
        caches["xk"], caches["xv"] = ckv
    return x, caches, jnp.zeros((), jnp.float32)


# ===========================================================================
# Public: train loss
# ===========================================================================

def _unembed(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["lm_head"]


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    h, _, aux = _backbone_full(cfg, params, batch["tokens"],
                               batch, collect_kv=False)
    loss = chunked_softmax_xent(h, _unembed(cfg, params), batch["labels"],
                                cfg.loss_chunks)
    return loss + AUX_LOSS_WEIGHT * aux


# ===========================================================================
# Public: prefill — full-seq forward that also materializes caches
# ===========================================================================

def prefill_fn(cfg: ModelConfig, params: Params,
               batch: Dict[str, jnp.ndarray], max_seq: int):
    """Returns (last-position logits, caches sized to max_seq)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h, kv, _ = _backbone_full(cfg, params, tokens, batch, collect_kv=True)
    logits = (h[:, -1:, :] @ _unembed(cfg, params)).astype(jnp.float32)
    caches = _grow_caches(cfg, kv, b, s, max_seq)
    return logits, caches


def _pad_time(a: jnp.ndarray, time_axis: int, max_seq: int) -> jnp.ndarray:
    pad = [(0, 0)] * a.ndim
    pad[time_axis] = (0, max_seq - a.shape[time_axis])
    return jnp.pad(a, pad)


def _grow_caches(cfg: ModelConfig, kv: Dict[str, Any], b, s, max_seq):
    """Prefill emits tight (seq=s) caches; pad the time axis to max_seq so
    decode can write new entries in place."""
    out = dict(kv)
    fam = cfg.family
    # scanned kv stacks have shape (L, B, S, heads, hd); time axis = 2.
    # vlm stacks are (G, n_self, B, S, heads, hd); time axis = 3.
    t_axis = 3 if fam == "vlm" else 2
    if cfg.kv_cache_quant and "k" in out and fam in ("dense", "moe"):
        # int8 KV cache (perf variant kv_int8): quantize the prefill cache
        kq, ks = att.quantize_kv(out.pop("k"))
        vq, vs = att.quantize_kv(out.pop("v"))
        out["k"] = _pad_time(kq, t_axis, max_seq)
        out["v"] = _pad_time(vq, t_axis, max_seq)
        out["k_scale"] = _pad_time(ks, t_axis, max_seq)
        out["v_scale"] = _pad_time(vs, t_axis, max_seq)
        return out
    for name in ("k", "v"):
        if name in out:
            out[name] = _pad_time(out[name], t_axis, max_seq)
    if "ckv" in out:   # MLA: (L, B, S, lora) / (L, B, S, rope)
        out["ckv"] = _pad_time(out["ckv"], 2, max_seq)
        out["kr"] = _pad_time(out["kr"], 2, max_seq)
    if "kv0" in out and out["kv0"] is not None:  # unscanned layer0 (B,S,..)
        a0, b0 = out.pop("kv0")   # (k, v) for GQA; (c_kv, k_rope) for MLA
        out["k0"] = _pad_time(a0, 1, max_seq)
        out["v0"] = _pad_time(b0, 1, max_seq)
    if "attn_k" in out:  # hybrid shared attention (G, B, S, kv, hd)
        out["attn_k"] = _pad_time(out["attn_k"], 2, max_seq)
        out["attn_v"] = _pad_time(out["attn_v"], 2, max_seq)
    return out


# ===========================================================================
# Public: decode — one token against caches
# ===========================================================================

def decode_fn(cfg: ModelConfig, params: Params, token: jnp.ndarray,
              caches: Dict[str, Any], cur_len: jnp.ndarray):
    """token: (B, 1) int32; cur_len: scalar count of valid cache entries.
    Returns (logits (B,1,V) fp32, updated caches)."""
    b = token.shape[0]
    x = embed_lookup(params["embed"], token)
    fam = cfg.family
    new_caches = dict(caches)

    if fam in ("dense", "moe"):
        use_moe = fam == "moe"
        if fam == "moe" and cfg.moe.first_dense:
            dense_cfg = dataclasses.replace(cfg, d_ff=cfg.moe.dense_d_ff)
            if cfg.mla is not None:
                x, (new_caches["k0"], new_caches["v0"]) = \
                    _decoder_block_decode(
                        dense_cfg, params["layer0"], x, None, None,
                        caches["k0"], caches["v0"], cur_len, False)
            else:
                x, (new_caches["k0"], new_caches["v0"]) = \
                    _decoder_block_decode(
                        dense_cfg, params["layer0"], x, caches["k0"],
                        caches["v0"], None, None, cur_len, False)
        if cfg.mla is not None:
            def body(carry, inp):
                xx = carry
                lp, ckv, kr = inp
                y, (ckv2, kr2) = _decoder_block_decode(
                    cfg, lp, xx, None, None, ckv, kr, cur_len, use_moe)
                return y, (ckv2, kr2)
            x, (ckv_new, kr_new) = jax.lax.scan(
                body, x, (params["layers"], caches["ckv"], caches["kr"]))
            new_caches["ckv"], new_caches["kr"] = ckv_new, kr_new
        elif cfg.kv_cache_quant:
            def body(carry, inp):
                xx = carry
                lp, ck, cks, cv, cvs = inp
                h = norm_apply(cfg.norm, xx, lp["ln1"])
                a, (ck2, cks2, cv2, cvs2) = att.decode_attention_q8(
                    lp["attn"], h, ck, cks, cv, cvs, cur_len, cfg.n_heads,
                    cfg.n_kv_heads, cfg.hd, cfg.rope_theta)
                xx = xx + a
                h = norm_apply(cfg.norm, xx, lp["ln2"])
                if use_moe:
                    y = moe_mod.moe_apply(lp["moe"], h, cfg.moe,
                                          dropless=True)
                else:
                    y = mlp_apply(cfg.mlp, lp["mlp"], h)
                return xx + y, (ck2, cks2, cv2, cvs2)
            x, (k_new, ks_new, v_new, vs_new) = jax.lax.scan(
                body, x, (params["layers"], caches["k"], caches["k_scale"],
                          caches["v"], caches["v_scale"]))
            new_caches["k"], new_caches["k_scale"] = k_new, ks_new
            new_caches["v"], new_caches["v_scale"] = v_new, vs_new
        else:
            def body(carry, inp):
                xx = carry
                lp, ck, cv = inp
                y, (ck2, cv2) = _decoder_block_decode(
                    cfg, lp, xx, ck, cv, None, None, cur_len, use_moe)
                return y, (ck2, cv2)
            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], caches["k"], caches["v"]))
            new_caches["k"], new_caches["v"] = k_new, v_new
    elif fam == "ssm":
        def body(carry, inp):
            xx = carry
            lp, st, cst = inp
            h = norm_apply(cfg.norm, xx, lp["ln"])
            y, st2, cst2 = m2.mamba2_decode(lp["mamba"], h, st, cst,
                                            cfg.d_model, cfg.ssm)
            return xx + y, (st2, cst2)
        x, (ssm_new, conv_new) = jax.lax.scan(
            body, x, (params["layers"], caches["ssm"], caches["conv"]))
        new_caches["ssm"], new_caches["conv"] = ssm_new, conv_new
    elif fam == "hybrid":
        x, new_caches = _hybrid_decode(cfg, params, x, caches, cur_len)
    elif fam == "vlm":
        x, new_caches = _vlm_decode(cfg, params, x, caches, cur_len)
    elif fam == "encdec":
        x, new_caches = _encdec_decode(cfg, params, x, caches, cur_len)
    else:
        raise ValueError(fam)

    x = norm_apply(cfg.norm, x, params["final_norm"])
    logits = (x @ _unembed(cfg, params)).astype(jnp.float32)
    return logits, new_caches


def _decoder_block_decode(cfg: ModelConfig, lp, x, ck, cv, ckv, kr, cur_len,
                          use_moe: bool):
    h = norm_apply(cfg.norm, x, lp["ln1"])
    if cfg.mla is not None and ckv is not None:
        m = cfg.mla
        a, cache = att.mla_decode(lp["attn"], h, ckv, kr, cur_len,
                                  cfg.n_heads, m.nope_dim, m.rope_dim,
                                  m.v_dim)
    else:
        a, cache = att.decode_attention(lp["attn"], h, ck, cv, cur_len,
                                        cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                        cfg.rope_theta)
    x = x + a
    h = norm_apply(cfg.norm, x, lp["ln2"])
    if use_moe:
        y = moe_mod.moe_apply(lp["moe"], h, cfg.moe, dropless=True)
    else:
        y = mlp_apply(cfg.mlp, lp["mlp"], h)
    return x + y, cache


def _hybrid_decode(cfg: ModelConfig, params, x, caches, cur_len):
    ap = params["shared_attn"]
    new = dict(caches)

    def group_body(carry, inp):
        xx = carry
        gp, ck, cv, sts, csts = inp
        h = norm_apply(cfg.norm, xx, ap["ln"])
        a, (ck2, cv2) = att.decode_attention(
            ap["attn"], h, ck, cv, cur_len, cfg.n_heads, cfg.n_kv_heads,
            cfg.hd, cfg.rope_theta)
        xx = xx + a
        h = norm_apply(cfg.norm, xx, ap["ln2"])
        xx = xx + mlp_apply(cfg.mlp, ap["mlp"], h)

        def mamba_body(c2, minp):
            lp, st, cst = minp
            h2 = norm_apply(cfg.norm, c2, lp["ln"])
            y, st2, cst2 = m2.mamba2_decode(lp["mamba"], h2, st, cst,
                                            cfg.d_model, cfg.ssm)
            return c2 + y, (st2, cst2)

        xx, (st2, cst2) = jax.lax.scan(mamba_body, xx, (gp, sts, csts))
        return xx, (ck2, cv2, st2, cst2)

    x, (k2, v2, s2, c2) = jax.lax.scan(
        group_body, x,
        (params["group_mamba"], caches["attn_k"], caches["attn_v"],
         caches["group_ssm"], caches["group_conv"]))
    new["attn_k"], new["attn_v"] = k2, v2
    new["group_ssm"], new["group_conv"] = s2, c2

    if "tail_mamba" in params:
        def tail_body(carry, inp):
            lp, st, cst = inp
            h2 = norm_apply(cfg.norm, carry, lp["ln"])
            y, st2, cst2 = m2.mamba2_decode(lp["mamba"], h2, st, cst,
                                            cfg.d_model, cfg.ssm)
            return carry + y, (st2, cst2)
        x, (ts2, tc2) = jax.lax.scan(
            tail_body, x, (params["tail_mamba"], caches["tail_ssm"],
                           caches["tail_conv"]))
        new["tail_ssm"], new["tail_conv"] = ts2, tc2
    return x, new


def _vlm_decode(cfg: ModelConfig, params, x, caches, cur_len):
    new = dict(caches)

    def group_body(carry, inp):
        xx = carry
        sp, cp, ck, cv, xk, xv = inp

        def self_body(c2, sinp):
            lp, ck1, cv1 = sinp
            y, (ck2, cv2) = _decoder_block_decode(cfg, lp, c2, ck1, cv1,
                                                  None, None, cur_len, False)
            return y, (ck2, cv2)

        xx, (ck2, cv2) = jax.lax.scan(self_body, xx, (sp, ck, cv))
        h = norm_apply(cfg.norm, xx, cp["ln"])
        ca = att.cross_attention_cached(cp["attn"], h, xk, xv, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd)
        xx = xx + jnp.tanh(cp["gate"]).astype(xx.dtype) * ca
        h = norm_apply(cfg.norm, xx, cp["ln_mlp"])
        y = mlp_apply(cfg.mlp, cp["mlp"], h)
        xx = xx + jnp.tanh(cp["mlp_gate"]).astype(xx.dtype) * y
        return xx, (ck2, cv2)

    x, (k2, v2) = jax.lax.scan(
        group_body, x,
        (params["self_layers"], params["cross_layers"], caches["k"],
         caches["v"], caches["xk"], caches["xv"]))
    new["k"], new["v"] = k2, v2
    return x, new


def _encdec_decode(cfg: ModelConfig, params, x, caches, cur_len):
    new = dict(caches)

    def body(carry, inp):
        xx = carry
        lp, ck, cv, xk, xv = inp
        h = norm_apply(cfg.norm, xx, lp["ln1"])
        a, (ck2, cv2) = att.decode_attention(
            lp["attn"], h, ck, cv, cur_len, cfg.n_heads, cfg.n_kv_heads,
            cfg.hd, cfg.rope_theta)
        xx = xx + a
        h = norm_apply(cfg.norm, xx, lp["ln_cross"])
        xx = xx + att.cross_attention_cached(lp["cross"], h, xk, xv,
                                             cfg.n_heads, cfg.n_kv_heads,
                                             cfg.hd)
        h = norm_apply(cfg.norm, xx, lp["ln2"])
        xx = xx + mlp_apply(cfg.mlp, lp["mlp"], h)
        return xx, (ck2, cv2)

    x, (k2, v2) = jax.lax.scan(
        body, x, (params["layers"], caches["k"], caches["v"], caches["xk"],
                  caches["xv"]))
    new["k"], new["v"] = k2, v2
    return x, new
