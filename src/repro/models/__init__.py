"""Model zoo: composable layers + the 10 assigned architectures.

Import submodules directly (repro.models.lm etc.); this package init stays
empty to avoid import cycles with repro.configs.
"""
