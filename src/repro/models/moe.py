"""Mixture-of-Experts layer: top-k routing with capacity, shared experts,
expert-parallel sharding — and PDE-style load statistics.

Dispatch is permutation-based (TPU-friendly, no per-row scatter loops):
token->expert assignments sort by expert id, each assignment computes its
slot within the expert's capacity buffer, and `.at[].set(mode='drop')`
materializes an (E, C, d) buffer that batched-matmuls through the experts on
the MXU.  Experts shard over the `model` axis (EP); GSPMD turns the
gather/scatter into the expert all-to-all.

Shark tie-in (DESIGN.md §4): router counts per expert are exactly the
paper's "heavy hitters" statistic; `router_stats` exposes them so the PDE
layer can re-select capacity factor / dispatch strategy from observed load
(the §3.1 re-planning idea applied to expert routing).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..parallel.compat import get_abstract_mesh
from .common import Params, Specs, stacked_dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int            # per-expert FFN width
    n_shared: int = 0        # always-on shared experts (DeepSeek style)
    capacity_factor: float = 1.25
    first_dense: bool = False  # layer 0 uses a dense MLP (DeepSeek-V2)
    dense_d_ff: int = 0


def moe_init(key, d_model: int, cfg: MoEConfig, n_layers: Optional[int] = None,
             dtype=jnp.bfloat16) -> Tuple[Params, Specs]:
    ks = jax.random.split(key, 7)
    e = cfg.num_experts

    def experts(k, i, o):
        if n_layers is None:
            return stacked_dense_init(k, e, i, o, dtype)
        flat = stacked_dense_init(k, n_layers * e, i, o, dtype)
        return flat.reshape(n_layers, e, i, o)

    lead = () if n_layers is None else (None,)
    p = {
        "router": (stacked_dense_init(ks[0], n_layers, d_model, e, jnp.float32)
                   if n_layers is not None else
                   jax.random.normal(ks[0], (d_model, e), jnp.float32) * 0.02),
        "w_gate": experts(ks[1], d_model, cfg.d_expert),
        "w_up": experts(ks[2], d_model, cfg.d_expert),
        "w_down": experts(ks[3], cfg.d_expert, d_model),
    }
    s = {
        "router": P(*lead, None, None),
        "w_gate": P(*lead, "model", None, None),
        "w_up": P(*lead, "model", None, None),
        "w_down": P(*lead, "model", None, None),
    }
    if cfg.n_shared > 0:
        sh_ff = cfg.d_expert * cfg.n_shared
        mk = (lambda k, i, o: stacked_dense_init(k, n_layers, i, o, dtype)
              if n_layers is not None else
              stacked_dense_init(k, 1, i, o, dtype)[0])
        p["shared_gate"] = mk(ks[4], d_model, sh_ff)
        p["shared_up"] = mk(ks[5], d_model, sh_ff)
        p["shared_down"] = mk(ks[6], sh_ff, d_model)
        s["shared_gate"] = P(*lead, None, "model")
        s["shared_up"] = P(*lead, None, "model")
        s["shared_down"] = P(*lead, "model", None)
    return p, s


def moe_apply(p: Params, x: jnp.ndarray, cfg: MoEConfig,
              return_stats: bool = False, dropless: bool = False):
    """x: (B, S, D) -> (B, S, D).  Permutation dispatch with capacity drop.

    `dropless=True` sizes every expert's buffer to the worst case (one slot
    per token) so nothing drops — used at decode, where token counts are tiny
    and batch-dependent drops would break prefill/decode equivalence."""
    with jax.named_scope("moe"):
        return _moe_apply(p, x, cfg, return_stats, dropless)


def _moe_apply(p, x, cfg, return_stats=False, dropless=False):
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])           # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                      # (T, k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    cap = t if dropless else int(max(1, round(t * k / e
                                              * cfg.capacity_factor)))

    # flatten assignments, sort by expert, slot = rank within expert run
    flat_e = topi.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    first_idx = jnp.searchsorted(sorted_e, sorted_e, side="left")
    slot_sorted = jnp.arange(t * k) - first_idx               # rank in run
    slot = jnp.zeros_like(slot_sorted).at[order].set(slot_sorted)

    tok_idx = jnp.repeat(jnp.arange(t), k)                    # (T*k,)
    keep = slot < cap
    # scatter tokens into (E, C, D); dropped assignments go nowhere
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, jnp.where(keep, slot, cap)].set(
        xf[tok_idx], mode="drop")
    buf = jax.lax.with_sharding_constraint(buf, P("model", None, None)) \
        if _in_mesh() else buf

    # expert FFN: batched matmul over the expert axis (MXU)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # (E, C, D)

    # gather back, weight, combine over k
    gathered = out_buf[flat_e, jnp.where(keep, slot, 0)]      # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered.astype(jnp.float32) \
        * topw.reshape(-1)[:, None]
    yf = jnp.zeros((t, d), jnp.float32).at[tok_idx].add(weighted)
    y = yf.astype(x.dtype).reshape(b, s, d)

    if cfg.n_shared > 0:
        sg = xf @ p["shared_gate"]
        su = xf @ p["shared_up"]
        sh = (jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su) \
            @ p["shared_down"]
        y = y + sh.reshape(b, s, d)

    if return_stats:
        load = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32),
                       axis=(0, 1))                           # per-expert count
        frac_dropped = 1.0 - jnp.sum(keep) / (t * k)
        return y, {"expert_load": load, "frac_dropped": frac_dropped,
                   "router_entropy": -jnp.mean(
                       jnp.sum(gates * jnp.log(gates + 1e-9), -1))}
    return y


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map (perf variant `moe_ep`)
# ---------------------------------------------------------------------------
#
# The GSPMD path above lets the partitioner derive communication for the
# token scatter/gather; measured on the dry-run it all-gathers the full
# token buffer to every expert shard (≈22 GB/layer wire on phi3.5-moe
# train_4k — 87% of the step's collective time).  This path makes the
# communication explicit and minimal: tokens are split along the `model`
# axis; each device routes its own T/16 tokens, exchanges exactly the
# per-expert capacity buffers with two all_to_alls, and computes only its
# local experts.  Wire per layer ≈ 2 x send-buffer ≈ 2 x T_dev*k*D*2B —
# ~60x less than the GSPMD-derived pattern.

def moe_apply_ep(p: Params, x: jnp.ndarray, cfg: MoEConfig,
                 return_stats: bool = False):
    """Expert-parallel MoE with explicit all_to_all dispatch.

    x: (B, S, D).  Requires a mesh with a `model` axis whose size divides
    both S and num_experts; falls back to the GSPMD path otherwise."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return moe_apply(p, x, cfg, return_stats=return_stats)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    ep = sizes["model"]
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    if e % ep != 0 or s % ep != 0:
        return moe_apply(p, x, cfg, return_stats=return_stats)
    e_loc = e // ep
    baxes = tuple(a for a in ("pod", "data") if a in sizes)
    t_dev = (b // max(1, _prod(sizes, baxes))) * (s // ep)
    cap_src = int(max(1, round(t_dev * k / e * cfg.capacity_factor)))

    def block(xb, router, w_gate, w_up, w_down):
        # xb: (B_loc, S/ep, D); router (D, E); w_* (E_loc, D, F)
        bl, sl, _ = xb.shape
        t = bl * sl
        xf = xb.reshape(t, d)
        logits = xf.astype(jnp.float32) @ router
        gates = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(gates, k)
        topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
        flat_e = topi.reshape(-1)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        first_idx = jnp.searchsorted(sorted_e, sorted_e, side="left")
        slot_sorted = jnp.arange(t * k) - first_idx
        slot = jnp.zeros_like(slot_sorted).at[order].set(slot_sorted)
        tok_idx = jnp.repeat(jnp.arange(t), k)
        keep = slot < cap_src
        send = jnp.zeros((e, cap_src, d), xb.dtype)
        send = send.at[flat_e, jnp.where(keep, slot, cap_src)].set(
            xf[tok_idx], mode="drop")
        # (E, C, D) -> (ep, E_loc, C, D) -> a2a -> (ep, E_loc, C, D) where
        # leading dim is now the SOURCE device
        send = send.reshape(ep, e_loc, cap_src, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # local experts over ep*cap tokens each
        buf = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep * cap_src, d)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xb.dtype) * u
        out = jnp.einsum("ecf,efd->ecd", h, w_down)
        out = out.reshape(e_loc, ep, cap_src, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(out, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(e, cap_src, d)
        gathered = back[flat_e, jnp.where(keep, slot, 0)]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        weighted = gathered.astype(jnp.float32) * topw.reshape(-1)[:, None]
        yf = jnp.zeros((t, d), jnp.float32).at[tok_idx].add(weighted)
        return yf.astype(xb.dtype).reshape(bl, sl, d)

    from jax.experimental.shard_map import shard_map
    x_spec = P(baxes if baxes else None, "model", None)
    y = shard_map(
        block, mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=x_spec, check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared > 0:
        xf = x.reshape(b * s, d)
        sg = xf @ p["shared_gate"]
        su = xf @ p["shared_up"]
        sh = (jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su) \
            @ p["shared_down"]
        y = y + sh.reshape(b, s, d)

    if return_stats:
        # load statistics from a cheap replicated router pass (PDE heavy
        # hitters); dropped fraction is per-shard, approximate here
        logits = (x.reshape(-1, d).astype(jnp.float32) @ p["router"])
        topw, topi = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        load = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32),
                       axis=(0, 1))
        return y, {"expert_load": load,
                   "frac_dropped": jnp.zeros((), jnp.float32),
                   "router_entropy": jnp.zeros((), jnp.float32)}
    return y


def _prod(sizes, axes) -> int:
    out = 1
    for a in axes:
        out *= sizes[a]
    return out


def load_balance_loss(logits_gates_load) -> jnp.ndarray:
    """Switch-style aux loss from (gates, load)."""
    gates, load = logits_gates_load
    e = gates.shape[-1]
    me = jnp.mean(gates, axis=0)
    pe = load / jnp.maximum(jnp.sum(load), 1.0)
    return e * jnp.sum(me * pe)


def _in_mesh() -> bool:
    try:
        from jax.interpreters import pxla
        env = pxla.thread_resources.env
        return env.physical_mesh.devices.size > 1
    except Exception:
        return False
