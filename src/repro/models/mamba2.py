"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Training/prefill uses the chunked SSD algorithm: the sequence splits into
chunks; within a chunk the computation is a masked (attention-like) matmul —
MXU-friendly — and a lax.scan carries the (H, P, N) state across chunks,
giving O(S) work with matmul-dominated inner loops.  Decode is the linear
recurrence  state' = da * state + dt * (B outer x);  y = C . state'.

Layer = [in_proj -> short causal conv (cached at decode) -> SSD -> gated
RMSNorm -> out_proj], matching the Mamba2 block.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import Params, Specs, rmsnorm, stacked_dense_init


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    d_conv: int = 4
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


def mamba2_init(key, d_model: int, cfg: SSMConfig,
                n_layers: Optional[int] = None, dtype=jnp.bfloat16
                ) -> Tuple[Params, Specs]:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    g, n = cfg.ngroups, cfg.d_state
    # in_proj emits [z (di), x (di), B (g*n), C (g*n), dt (nh)]
    proj_out = 2 * di + 2 * g * n + nh
    ks = jax.random.split(key, 4)
    mk = (lambda k, i, o: stacked_dense_init(k, n_layers, i, o, dtype)
          if n_layers is not None else
          stacked_dense_init(k, 1, i, o, dtype)[0])
    lead = () if n_layers is None else (None,)

    def vec(shape_tail, val=0.0):
        shape = shape_tail if n_layers is None else (n_layers,) + shape_tail
        return jnp.full(shape, val, jnp.float32)

    conv_dim = di + 2 * g * n
    p = {
        "in_proj": mk(ks[0], d_model, proj_out),
        "conv_w": (jax.random.normal(ks[1], ((n_layers or 1), conv_dim,
                                             cfg.d_conv), jnp.float32) * 0.1
                   ).astype(dtype) if n_layers is not None else
                  (jax.random.normal(ks[1], (conv_dim, cfg.d_conv),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": vec((conv_dim,)),
        "A_log": vec((nh,), 0.0),     # A = -exp(A_log)
        "D": vec((nh,), 1.0),
        "dt_bias": vec((nh,), 0.0),
        "norm_w": vec((di,), 1.0),
        "out_proj": mk(ks[3], di, d_model),
    }
    s = {
        "in_proj": P(*lead, None, "model"),
        "conv_w": P(*lead, "model", None),
        "conv_b": P(*lead, "model"),
        "A_log": P(*lead, None), "D": P(*lead, None),
        "dt_bias": P(*lead, None),
        "norm_w": P(*lead, "model"),
        "out_proj": P(*lead, "model", None),
    }
    return p, s


def _split_proj(zxbcdt: jnp.ndarray, d_inner: int, g: int, n: int, nh: int):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    B = zxbcdt[..., 2 * d_inner:2 * d_inner + g * n]
    C = zxbcdt[..., 2 * d_inner + g * n:2 * d_inner + 2 * g * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * g * n:]
    return z, x, B, C, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv over sequence.  xbc: (B,S,C); w: (C,K)."""
    k = w.shape[-1]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # K=4: unrolled taps, fuses into one VPU expression
        out = out + pad[:, i:i + xbc.shape[1], :].astype(jnp.float32) \
            * w[:, i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                chunk: int, init_state: Optional[jnp.ndarray] = None):
    """SSD scan.  x: (b,s,h,p); dt: (b,s,h) (post-softplus); A: (h) (<0);
    B, C: (b,s,g,n).  Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p_ = x.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    if s % chunk != 0:
        # pad with dt=0 rows: state passes through unchanged, outputs dropped
        pad = chunk - s % chunk
        pz = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = pz(x), pz(dt), pz(B), pz(C)
        s = s + pad
    nc = s // chunk
    hg = h // g  # heads per B/C group

    xc = x.reshape(b, nc, chunk, h, p_)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    dA = dtc * A[None, None, None, :]                  # (b,nc,c,h) negative
    dA_cum = jnp.cumsum(dA, axis=2)                    # within-chunk cumsum

    # intra-chunk (quadratic within chunk, matmul form)
    # L[i,j] = exp(dA_cum[i] - dA_cum[j]) for i >= j
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]  # (b,nc,c,c,h)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    # scores: C_i . B_j
    CB = jnp.einsum("bzcgn,bzdgn->bzcdg", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))            # (b,nc,c,c,g)
    CB = jnp.repeat(CB, hg, axis=-1)                   # (b,nc,c,c,h)
    M = CB * L * dtc[:, :, None, :, :]                 # weight by dt_j
    y_intra = jnp.einsum("bzcdh,bzdhp->bzchp", M, xc.astype(jnp.float32))

    # chunk summary states: S_z = sum_j exp(dA_cum[last]-dA_cum[j]) dt_j B_j x_j
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)      # (b,nc,c,h)
    B_h = jnp.repeat(Bc.astype(jnp.float32), hg, axis=3) \
        .reshape(b, nc, chunk, h, n)                    # per-head B
    contrib = jnp.einsum("bzch,bzchn,bzchp->bzhpn",
                         (decay_to_end * dtc), B_h,
                         xc.astype(jnp.float32))        # (b,nc,h,p,n)

    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))         # (b,nc,h)

    def scan_body(state, inp):
        contrib_z, decay_z, Cz, dAc_z = inp
        # inter-chunk contribution: y_j += C_j . (decay_into_chunk * state)
        state_in = state                                # (b,h,p,n)
        decay_from_start = jnp.exp(dAc_z)               # (b,c,h)
        Cz_h = jnp.repeat(Cz, hg, axis=2).reshape(
            Cz.shape[0], Cz.shape[1], h, n)
        y_inter = jnp.einsum("bchn,bhpn,bch->bchp",
                             Cz_h.astype(jnp.float32), state_in,
                             decay_from_start)
        state_out = state_in * decay_z[:, :, None, None] + contrib_z
        return state_out, y_inter

    state0 = init_state if init_state is not None \
        else jnp.zeros((b, h, p_, n), jnp.float32)
    contrib_t = contrib.transpose(1, 0, 2, 3, 4)
    decay_t = chunk_decay.transpose(1, 0, 2)
    C_t = Cc.transpose(1, 0, 2, 3, 4)
    dAcum_t = dA_cum.transpose(1, 0, 2, 3)
    final_state, y_inter = jax.lax.scan(
        scan_body, state0, (contrib_t, decay_t, C_t, dAcum_t))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)          # (b,nc,c,h,p)

    y = (y_intra + y_inter).reshape(b, s, h, p_)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y[:, :s_orig].astype(x.dtype), final_state


def mamba2_forward(p: Params, x: jnp.ndarray, d_model: int, cfg: SSMConfig,
                   return_state: bool = False):
    """Full-sequence Mamba2 block (train / prefill).  x: (B,S,D)."""
    b, s, _ = x.shape
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    g, n = cfg.ngroups, cfg.d_state
    zxbcdt = x @ p["in_proj"]
    z, xs, B, C, dt = _split_proj(zxbcdt, di, g, n, nh)
    xbc_raw = jnp.concatenate([xs, B, C], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, B, C = (xbc[..., :di], xbc[..., di:di + g * n],
                xbc[..., di + g * n:])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(
        xs.reshape(b, s, nh, cfg.headdim), dt, A,
        B.reshape(b, s, g, n), C.reshape(b, s, g, n), p["D"],
        min(cfg.chunk, s))
    y = y.reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_w"])
    out = y @ p["out_proj"]
    if return_state:
        conv_state = xbc_raw[:, -(cfg.d_conv - 1):, :]  # last K-1 raw inputs
        return out, state, conv_state
    return out


def mamba2_decode(p: Params, x: jnp.ndarray, ssm_state: jnp.ndarray,
                  conv_state: jnp.ndarray, d_model: int, cfg: SSMConfig):
    """Single-token step.  x: (B,1,D); ssm_state: (B,H,P,N) fp32;
    conv_state: (B, d_conv-1, conv_dim)."""
    b = x.shape[0]
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    g, n = cfg.ngroups, cfg.d_state
    zxbcdt = x @ p["in_proj"]
    z, xs, B, C, dt = _split_proj(zxbcdt, di, g, n, nh)
    xbc_new = jnp.concatenate([xs, B, C], axis=-1)      # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)  # (B,K,conv)
    w = p["conv_w"]                                     # (conv_dim, K)
    conv_out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                          w.astype(jnp.float32)) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv_state = window[:, 1:, :]
    xs, B, C = (xbc[..., :di], xbc[..., di:di + g * n],
                xbc[..., di + g * n:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A[None, :])                        # (B,H)
    xh = xs.reshape(b, nh, cfg.headdim).astype(jnp.float32)
    Bh = jnp.repeat(B.reshape(b, g, n), nh // g, axis=1)  # (B,H,N)
    Ch = jnp.repeat(C.reshape(b, g, n), nh // g, axis=1)
    state = ssm_state * da[:, :, None, None] \
        + dt[:, :, None, None] * xh[..., :, None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_w"])
    return y @ p["out_proj"], state, new_conv_state
