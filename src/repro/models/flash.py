"""Flash-semantics attention with a hand-written backward (custom_vjp).

The autodiff backward of blockwise attention stashes per-chunk probabilities
and materializes f32 cotangents of every score/prob tensor — measured at
~60% of phi3-medium train-step HBM traffic (EXPERIMENTS.md §Perf).  This
implementation is the flash-attention strategy expressed in XLA:

  forward:  online-softmax over KV chunks; saves only (O, L=m+log l);
  backward: recomputes scores/probs per chunk in bf16, accumulates
            dQ (f32 carry) and per-chunk dK/dV; no stash, no f32
            score-sized tensors anywhere.

On TPU the same math runs as the Pallas kernel (kernels/flash_attention.py)
with tiles held in VMEM; this XLA form is the portable fallback the dry-run
measures, and the kernel's oracle.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _grouped(q, k, v):
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    return q.reshape(b, s, n_kv, g, hd), k, v, n_kv, g


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def flash_attention(q, k, v, q_positions, kv_chunk: int = 1024,
                    causal: bool = True):
    """q: (B,S,H,hd) bf16; k,v: (B,T,KV,hd) bf16 -> (B,S,H,hd) bf16."""
    o, _ = _flash_fwd_impl(q, k, v, q_positions, kv_chunk, causal)
    return o


def _chunks(x, n_chunks, kv_chunk):
    b, t, kvh, hd = x.shape
    return x.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)


def _flash_fwd_impl(q, k, v, q_positions, kv_chunk, causal):
    b, s, h, hd = q.shape
    qg, k, v, n_kv, g = _grouped(q, k, v)
    t = k.shape[1]
    kv_chunk = min(kv_chunk, t)
    assert t % kv_chunk == 0, (t, kv_chunk)
    n_chunks = t // kv_chunk
    scale = jnp.asarray(1.0 / (hd ** 0.5), jnp.bfloat16)
    qs = (qg.astype(jnp.bfloat16) * scale)
    kc = _chunks(k, n_chunks, kv_chunk)
    vc = _chunks(v, n_chunks, kv_chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, idx = xs
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)
        s_blk = jnp.einsum("bsgxd,bcgd->bsgxc", qs, kb.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
        if causal:
            mask = kpos[None, None, None, None, :] \
                <= q_positions[:, :, None, None, None]
            s_blk = jnp.where(mask, s_blk, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None]).astype(jnp.bfloat16)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bsgxc,bcgd->bsgxd", p, vb.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, n_kv, g), jnp.float32)
    acc0 = jnp.zeros((b, s, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (kc, vc, jnp.arange(n_chunks)))
    l_safe = jnp.maximum(l, 1e-30)
    o = (acc / l_safe[..., None]).reshape(b, s, h, hd).astype(q.dtype)
    lse = m + jnp.log(l_safe)                      # (b, s, n_kv, g)
    return o, lse


def _flash_fwd(q, k, v, q_positions, kv_chunk, causal):
    o, lse = _flash_fwd_impl(q, k, v, q_positions, kv_chunk, causal)
    return o, (q, k, v, q_positions, o, lse)


def _flash_bwd(kv_chunk, causal, res, d_o):
    q, k, v, q_positions, o, lse = res
    b, s, h, hd = q.shape
    qg, k, v, n_kv, g = _grouped(q, k, v)
    t = k.shape[1]
    kv_chunk = min(kv_chunk, t)
    n_chunks = t // kv_chunk
    scale = jnp.asarray(1.0 / (hd ** 0.5), jnp.bfloat16)
    qs = qg.astype(jnp.bfloat16) * scale
    d_og = d_o.reshape(b, s, n_kv, g, hd).astype(jnp.bfloat16)
    og = o.reshape(b, s, n_kv, g, hd).astype(jnp.bfloat16)
    # delta_i = sum_d dO_i * O_i  (f32, small)
    delta = jnp.einsum("bsgxd,bsgxd->bsgx", d_og.astype(jnp.float32),
                       og.astype(jnp.float32))
    kc = _chunks(k, n_chunks, kv_chunk)
    vc = _chunks(v, n_chunks, kv_chunk)

    def body(dq_acc, xs):
        kb, vb, idx = xs
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)
        s_blk = jnp.einsum("bsgxd,bcgd->bsgxc", qs, kb.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
        if causal:
            mask = kpos[None, None, None, None, :] \
                <= q_positions[:, :, None, None, None]
            s_blk = jnp.where(mask, s_blk, NEG_INF)
        p = jnp.exp(s_blk - lse[..., None]).astype(jnp.bfloat16)  # true probs
        # dV_c = P^T dO ; dP = dO V^T ; dS = P*(dP - delta); dQ += dS K;
        # dK_c = dS^T Q
        dv = jnp.einsum("bsgxc,bsgxd->bcgd", p, d_og,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bsgxd,bcgd->bsgxc", d_og, vb.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        ds = (p.astype(jnp.float32) * (dp - delta[..., None])
              ).astype(jnp.bfloat16)
        dq_acc = dq_acc + jnp.einsum("bsgxc,bcgd->bsgxd", ds,
                                     kb.astype(jnp.bfloat16),
                                     preferred_element_type=jnp.float32)
        dk = jnp.einsum("bsgxc,bsgxd->bcgd", ds, qs,
                        preferred_element_type=jnp.float32)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((b, s, n_kv, g, hd), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        jax.checkpoint(body), dq0, (kc, vc, jnp.arange(n_chunks)))
    scale32 = jnp.asarray(1.0 / (hd ** 0.5), jnp.float32)
    dq = (dq * scale32).reshape(b, s, h, hd).astype(q.dtype)
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, t, n_kv, hd).astype(k.dtype)
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, t, n_kv, hd).astype(v.dtype)
    return dq, dk, dv, None


flash_attention.defvjp(_flash_fwd, _flash_bwd)
