"""Attention variants: GQA (RoPE, optional QKV bias), MLA (DeepSeek-V2
latent compression), and cross-attention (VLM / encoder-decoder).

Self-attention uses blockwise online-softmax over KV chunks (flash-attention
semantics in pure JAX): scores for one (queries x kv-chunk) tile exist at a
time, so 32k-token prefill never materializes an S x S matrix.  GQA never
materializes repeated K/V heads — queries reshape to (kv_groups, q_per_kv)
and contract against the raw KV tensors.

Decode attends one query against the full KV cache with a length mask; MLA
caches only the compressed (c_kv, k_rope) streams, decompressing per step.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..parallel.compat import get_abstract_mesh
from .common import (Params, Specs, apply_rope, dense_init,
                     stacked_dense_init)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA parameters
# ---------------------------------------------------------------------------

def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             n: Optional[int] = None, qkv_bias: bool = False,
             dtype=jnp.bfloat16) -> Tuple[Params, Specs]:
    ks = jax.random.split(key, 4)
    mk = (lambda k, i, o: dense_init(k, i, o, dtype)) if n is None else \
         (lambda k, i, o: stacked_dense_init(k, n, i, o, dtype))
    lead = () if n is None else (None,)
    p = {"wq": mk(ks[0], d_model, n_heads * head_dim),
         "wk": mk(ks[1], d_model, n_kv * head_dim),
         "wv": mk(ks[2], d_model, n_kv * head_dim),
         "wo": mk(ks[3], n_heads * head_dim, d_model)}
    s = {"wq": P(*lead, None, "model"), "wk": P(*lead, None, "model"),
         "wv": P(*lead, None, "model"), "wo": P(*lead, "model", None)}
    if qkv_bias:
        for nm, width in (("bq", n_heads * head_dim), ("bk", n_kv * head_dim),
                          ("bv", n_kv * head_dim)):
            p[nm] = jnp.zeros((width,) if n is None else (n, width), dtype)
            s[nm] = P(*lead, "model")
    return p, s


def _project_qkv(p: Params, x: jnp.ndarray, n_heads: int, n_kv: int,
                 head_dim: int):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (q.reshape(b, s, n_heads, head_dim),
            k.reshape(b, s, n_kv, head_dim),
            v.reshape(b, s, n_kv, head_dim))


# ---------------------------------------------------------------------------
# Blockwise causal attention (training / prefill)
# ---------------------------------------------------------------------------

def _blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         q_positions: jnp.ndarray, kv_chunk: int,
                         causal: bool, kv_offset: int = 0,
                         scores_dtype: str = "f32",
                         chunk_remat: bool = False) -> jnp.ndarray:
    """q: (B,S,H,hd); k,v: (B,T,KV,hd).  Online softmax over KV chunks.

    scores_dtype="bf16" (perf variant): score/probability tensors — the
    dominant HBM traffic of non-fused attention — are kept in bf16; the
    online-softmax statistics (m, l) and the output accumulator stay f32,
    so softmax normalization keeps full precision.

    chunk_remat=True (perf variant): checkpoints the per-KV-chunk body so
    the scan backward recomputes scores/probs per chunk instead of stashing
    a (n_chunks, B, S, H, C) residual buffer — the flash-attention backward
    strategy expressed in XLA."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    n_kv = k.shape[2]
    g = h // n_kv
    sdt = jnp.bfloat16 if scores_dtype == "bf16" else jnp.float32
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = (q.reshape(b, s, n_kv, g, hd).astype(jnp.float32) * scale) \
        .astype(sdt)

    kv_chunk = min(kv_chunk, t)
    t_orig = t
    if t % kv_chunk != 0:
        pad = kv_chunk - t % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    n_chunks = t // kv_chunk
    kc = k.reshape(b, n_chunks, kv_chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, idx = xs
        kpos = idx * kv_chunk + jnp.arange(kv_chunk) + kv_offset
        # kb: (b, chunk, kv_groups, hd); queries grouped per kv head.
        # score/prob tensors live in sdt (bf16 halves the dominant HBM
        # traffic of non-fused attention); softmax stats stay f32.
        scores = jnp.einsum("bsgxd,bcgd->bsgxc", qg, kb.astype(sdt),
                            preferred_element_type=sdt)
        if causal:
            mask = kpos[None, None, None, None, :] \
                <= q_positions[:, :, None, None, None]
            scores = jnp.where(mask, scores, jnp.asarray(NEG_INF, sdt))
        if t != t_orig:  # mask KV padding (non-multiple chunk lengths)
            valid = (kpos < t_orig)[None, None, None, None, :]
            scores = jnp.where(valid, scores, jnp.asarray(NEG_INF, sdt))
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1).astype(jnp.float32))
        p = jnp.exp(scores - m_new[..., None].astype(sdt))
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bsgxc,bcgd->bsgxd", p, vb.astype(sdt),
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, n_kv, g), jnp.float32)
    acc0 = jnp.zeros((b, s, n_kv, g, hd), jnp.float32)
    body_fn = jax.checkpoint(body) if chunk_remat else body
    (m, l, acc), _ = jax.lax.scan(
        body_fn, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, s, h, hd).astype(q.dtype)


def self_attention(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                   n_heads: int, n_kv: int, head_dim: int, rope_theta: float,
                   kv_chunk: int = 1024, causal: bool = True,
                   return_kv: bool = False, scores_dtype: str = "f32",
                   chunk_remat: bool = False, impl: str = "blockwise",
                   seq_shard: bool = False):
    """Full-sequence causal self-attention (train / prefill)."""
    with jax.named_scope("attention"):
        return _self_attention(p, x, positions, n_heads, n_kv, head_dim,
                               rope_theta, kv_chunk, causal, return_kv,
                               scores_dtype, chunk_remat, impl, seq_shard)


def _self_attention(p, x, positions, n_heads, n_kv, head_dim, rope_theta,
                    kv_chunk, causal, return_kv, scores_dtype="f32",
                    chunk_remat=False, impl="blockwise", seq_shard=False):
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if seq_shard:
        # context parallelism: queries shard over `model` along the sequence
        # axis (K/V stay whole — they are GQA-small); score tensors then
        # shard 16-ways even when head counts don't divide the mesh.
        from ..parallel.sharding import BATCH_AXES, maybe_shard
        q = maybe_shard(q, P(BATCH_AXES, "model", None, None))
    if impl == "flash" and k.shape[1] % min(kv_chunk, k.shape[1]) == 0:
        from .flash import flash_attention
        out = flash_attention(q, k, v, positions, kv_chunk, causal)
    else:
        out = _blockwise_attention(q, k, v, positions, kv_chunk, causal,
                                   scores_dtype=scores_dtype,
                                   chunk_remat=chunk_remat)
    if seq_shard:
        from ..parallel.sharding import BATCH_AXES, maybe_shard
        out = maybe_shard(out, P(BATCH_AXES, "model", None, None))
    y = out.reshape(b, s, n_heads * head_dim) @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


def _decode_q_constraint(qg, n_kv: int, head_dim: int):
    """Match the KV cache layout rule (launch/specs.cache_pspecs): when kv
    heads don't divide the model axis, caches shard head_dim; constrain q the
    same way so the score contraction runs as local partial dots + a small
    all-reduce instead of GSPMD gathering the cache (perf iteration C3)."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return qg
    msize = dict(zip(mesh.axis_names, mesh.axis_sizes))["model"]
    if n_kv % msize == 0 or head_dim % msize != 0:
        return qg
    from ..parallel.sharding import BATCH_AXES, maybe_shard
    return maybe_shard(qg, P(BATCH_AXES, None, None, "model"))


def decode_attention(p: Params, x: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, cur_len: jnp.ndarray,
                     n_heads: int, n_kv: int, head_dim: int,
                     rope_theta: float):
    """One-token decode: x (B,1,D); cache (B,Smax,KV,hd); cur_len scalar =
    number of valid cache entries (the new token is written at cur_len).

    The cache is consumed at its storage dtype (bf16) with f32 accumulation
    inside the dots — decode is KV-bandwidth-bound, so upcasting the cache
    to f32 would double the dominant traffic term (perf iteration C1)."""
    with jax.named_scope("attention"):
        b, s1, d = x.shape
        q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim)
        pos = jnp.full((b, 1), cur_len, jnp.int32)
        if rope_theta > 0:
            q = apply_rope(q, pos, rope_theta)
            k = apply_rope(k, pos, rope_theta)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, cur_len, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, cur_len, 0, 0))
        t = cache_k.shape[1]
        g = n_heads // n_kv
        scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
        qg = (q.reshape(b, n_kv, g, head_dim).astype(jnp.float32)
              * scale).astype(cache_k.dtype)
        qg = _decode_q_constraint(qg, n_kv, head_dim)
        scores = jnp.einsum("bgxd,btgd->bgxt", qg, cache_k,
                            preferred_element_type=jnp.float32)
        mask = jnp.arange(t)[None, None, None, :] <= cur_len
        scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(cache_v.dtype)
        out = jnp.einsum("bgxt,btgd->bgxd", w, cache_v,
                         preferred_element_type=jnp.float32)
        y = out.reshape(b, 1, n_heads * head_dim).astype(x.dtype) @ p["wo"]
        return y, (cache_k, cache_v)


# -- int8-quantized KV cache (perf variant `kv_int8`) ------------------------
#
# Shark's S3.2 insight applied to the KV store: compression is a bandwidth
# optimization.  K/V quantize symmetrically per (token, head) to int8 at
# prefill/append; scores factor exactly as (q . k_q) * k_scale, so the dot
# streams int8 and the dequant rides the scale multiply — halving the
# decode-dominant cache read traffic and the cache HBM footprint.

def quantize_kv(x: jnp.ndarray):
    """x: (..., hd) -> (int8 values, bf16 per-(...)-scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return q, s[..., 0].astype(jnp.bfloat16)


def decode_attention_q8(p: Params, x: jnp.ndarray, cache_k: jnp.ndarray,
                        k_scale: jnp.ndarray, cache_v: jnp.ndarray,
                        v_scale: jnp.ndarray, cur_len: jnp.ndarray,
                        n_heads: int, n_kv: int, head_dim: int,
                        rope_theta: float):
    """Decode against an int8 cache.  cache_k/v: (B,Smax,KV,hd) int8;
    k_scale/v_scale: (B,Smax,KV) bf16."""
    with jax.named_scope("attention"):
        b, s1, d = x.shape
        q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim)
        pos = jnp.full((b, 1), cur_len, jnp.int32)
        if rope_theta > 0:
            q = apply_rope(q, pos, rope_theta)
            k = apply_rope(k, pos, rope_theta)
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache_k = jax.lax.dynamic_update_slice(cache_k, kq,
                                               (0, cur_len, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(k_scale, ks, (0, cur_len, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, vq,
                                               (0, cur_len, 0, 0))
        v_scale = jax.lax.dynamic_update_slice(v_scale, vs, (0, cur_len, 0))
        t = cache_k.shape[1]
        g = n_heads // n_kv
        scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
        qg = (q.reshape(b, n_kv, g, head_dim).astype(jnp.float32)
              * scale).astype(jnp.bfloat16)
        qg = _decode_q_constraint(qg, n_kv, head_dim)
        # (q . k_q) * s_k — the int8 stream converts in-register on TPU
        raw = jnp.einsum("bgxd,btgd->bgxt", qg,
                         cache_k.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
        scores = raw * k_scale.transpose(0, 2, 1)[:, :, None, :] \
            .astype(jnp.float32)
        mask = jnp.arange(t)[None, None, None, :] <= cur_len
        scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        wv = (w * v_scale.transpose(0, 2, 1)[:, :, None, :]
              .astype(jnp.float32)).astype(jnp.bfloat16)
        out = jnp.einsum("bgxt,btgd->bgxd", wv,
                         cache_v.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
        y = out.reshape(b, 1, n_heads * head_dim).astype(x.dtype) @ p["wo"]
        return y, (cache_k, k_scale, cache_v, v_scale)


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers, enc-dec decoders)
# ---------------------------------------------------------------------------

def cross_attention(p: Params, x: jnp.ndarray, kv_src: jnp.ndarray,
                    n_heads: int, n_kv: int, head_dim: int,
                    kv_chunk: int = 512):
    """x: (B,S,D) queries; kv_src: (B,T,D) encoder/image states."""
    b, s, d = x.shape
    t = kv_src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (kv_src @ p["wk"]).reshape(b, t, n_kv, head_dim)
    v = (kv_src @ p["wv"]).reshape(b, t, n_kv, head_dim)
    positions = jnp.zeros((b, s), jnp.int32)
    out = _blockwise_attention(q, k, v, positions, min(kv_chunk, t),
                               causal=False)
    return out.reshape(b, s, n_heads * head_dim) @ p["wo"]


def cross_attention_cached(p: Params, x: jnp.ndarray, k: jnp.ndarray,
                           v: jnp.ndarray, n_heads: int, n_kv: int,
                           head_dim: int):
    """Decode-time cross-attention against precomputed encoder K/V."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    g = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    qg = q.reshape(b, s, n_kv, g, head_dim).astype(jnp.float32) * scale
    scores = jnp.einsum("bsgxd,btgd->bsgxt", qg, k.astype(jnp.float32))
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bsgxt,btgd->bsgxd", w, v.astype(jnp.float32))
    return out.reshape(b, s, n_heads * head_dim).astype(x.dtype) @ p["wo"]


def cross_kv(p: Params, kv_src: jnp.ndarray, n_kv: int, head_dim: int):
    b, t, _ = kv_src.shape
    k = (kv_src @ p["wk"]).reshape(b, t, n_kv, head_dim)
    v = (kv_src @ p["wv"]).reshape(b, t, n_kv, head_dim)
    return k, v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2), naive/faithful mode
# ---------------------------------------------------------------------------

def mla_init(key, d_model: int, n_heads: int, kv_lora: int, nope_dim: int,
             rope_dim: int, v_dim: int, n: Optional[int] = None,
             dtype=jnp.bfloat16) -> Tuple[Params, Specs]:
    ks = jax.random.split(key, 6)
    mk = (lambda k, i, o: dense_init(k, i, o, dtype)) if n is None else \
         (lambda k, i, o: stacked_dense_init(k, n, i, o, dtype))
    lead = () if n is None else (None,)
    p = {
        "wq": mk(ks[0], d_model, n_heads * (nope_dim + rope_dim)),
        "wdkv": mk(ks[1], d_model, kv_lora),
        "wkr": mk(ks[2], d_model, rope_dim),
        "wuk": mk(ks[3], kv_lora, n_heads * nope_dim),
        "wuv": mk(ks[4], kv_lora, n_heads * v_dim),
        "wo": mk(ks[5], n_heads * v_dim, d_model),
        "kv_norm": jnp.ones((kv_lora,) if n is None else (n, kv_lora),
                            jnp.float32),
    }
    s = {
        "wq": P(*lead, None, "model"), "wdkv": P(*lead, None, None),
        "wkr": P(*lead, None, None), "wuk": P(*lead, None, "model"),
        "wuv": P(*lead, None, "model"), "wo": P(*lead, "model", None),
        "kv_norm": P(*lead, None),
    }
    return p, s


def _mla_qkv(p: Params, x: jnp.ndarray, positions, n_heads, nope_dim,
             rope_dim, v_dim):
    from .common import rmsnorm
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, nope_dim + rope_dim)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    q_rope = apply_rope(q_rope, positions, 10000.0)
    c_kv = rmsnorm(x @ p["wdkv"], p["kv_norm"])          # (b,s,lora)
    k_rope = (x @ p["wkr"]).reshape(b, s, 1, rope_dim)
    k_rope = apply_rope(k_rope, positions, 10000.0)
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                  n_heads: int, nope_dim: int, rope_dim: int, v_dim: int,
                  kv_chunk: int = 1024, return_kv: bool = False,
                  seq_shard: bool = False):
    """Training/prefill MLA.  Decompresses K/V per KV-chunk inside the
    blockwise loop, so full (S, H, nope+v) tensors never materialize.

    seq_shard: context-parallel queries (same rationale as GQA — MLA's 16
    heads don't divide a model=16 mesh once grouped, and the score tensors
    are the traffic hotspot)."""
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, positions, n_heads,
                                            nope_dim, rope_dim, v_dim)
    if seq_shard:
        from ..parallel.sharding import BATCH_AXES, maybe_shard
        q_nope = maybe_shard(q_nope, P(BATCH_AXES, "model", None, None))
        q_rope = maybe_shard(q_rope, P(BATCH_AXES, "model", None, None))
    scale = 1.0 / jnp.sqrt(jnp.asarray(nope_dim + rope_dim, jnp.float32))
    kv_chunk = min(kv_chunk, s)
    assert s % kv_chunk == 0
    n_chunks = s // kv_chunk
    wuk = p["wuk"].reshape(-1, n_heads, nope_dim)
    wuv = p["wuv"].reshape(-1, n_heads, v_dim)

    ckv_c = c_kv.reshape(b, n_chunks, kv_chunk, -1).transpose(1, 0, 2, 3)
    krope_c = k_rope.reshape(b, n_chunks, kv_chunk, rope_dim) \
        .transpose(1, 0, 2, 3)

    qn = q_nope.astype(jnp.float32) * scale
    qr = q_rope.astype(jnp.float32) * scale

    def body(carry, xs):
        m, l, acc = carry
        ckv, kr, idx = xs
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)
        k_nope = jnp.einsum("bcl,lhd->bchd", ckv, wuk)     # decompress K
        v = jnp.einsum("bcl,lhv->bchv", ckv, wuv)          # decompress V
        sc = jnp.einsum("bshd,bchd->bshc", qn, k_nope.astype(jnp.float32))
        sc = sc + jnp.einsum("bshr,bcr->bshc", qr, kr.astype(jnp.float32))
        mask = kpos[None, None, None, :] <= positions[:, :, None, None]
        sc = jnp.where(mask, sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        pr = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(pr, axis=-1)
        pv = jnp.einsum("bshc,bchv->bshv", pr, v.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, n_heads), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, n_heads), jnp.float32)
    acc0 = jnp.zeros((b, s, n_heads, v_dim), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (ckv_c, krope_c, jnp.arange(n_chunks)))
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(x.dtype)
    if seq_shard:
        from ..parallel.sharding import BATCH_AXES, maybe_shard
        out = maybe_shard(out, P(BATCH_AXES, "model", None, None))
    y = out.reshape(b, s, n_heads * v_dim) @ p["wo"]
    if return_kv:
        return y, (c_kv, k_rope[:, :, 0, :])
    return y


def mla_decode(p: Params, x: jnp.ndarray, cache_ckv: jnp.ndarray,
               cache_kr: jnp.ndarray, cur_len: jnp.ndarray, n_heads: int,
               nope_dim: int, rope_dim: int, v_dim: int):
    """One-token MLA decode against the compressed cache
    (cache_ckv: (B,Smax,lora); cache_kr: (B,Smax,rope))."""
    b = x.shape[0]
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, pos, n_heads, nope_dim,
                                            rope_dim, v_dim)
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_kv.astype(cache_ckv.dtype), (0, cur_len, 0))
    cache_kr = jax.lax.dynamic_update_slice(
        cache_kr, k_rope[:, :, 0, :].astype(cache_kr.dtype), (0, cur_len, 0))
    t = cache_ckv.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(nope_dim + rope_dim, jnp.float32))
    wuk = p["wuk"].reshape(-1, n_heads, nope_dim)
    wuv = p["wuv"].reshape(-1, n_heads, v_dim)
    # absorbed-score trick for decode: q_nope^T (c_kv W_uk) = (q_nope W_uk^T) c_kv
    q_abs = jnp.einsum("bshd,lhd->bshl", q_nope.astype(jnp.float32), wuk)
    sc = jnp.einsum("bshl,btl->bsht", q_abs,
                    cache_ckv.astype(jnp.float32)) * scale
    sc = sc + jnp.einsum("bshr,btr->bsht",
                         q_rope.astype(jnp.float32) * scale,
                         cache_kr.astype(jnp.float32))
    mask = jnp.arange(t)[None, None, None, :] <= cur_len
    sc = jnp.where(mask, sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    # attention over compressed V, decompress after weighting (absorbed-V)
    ctx = jnp.einsum("bsht,btl->bshl", w, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bshl,lhv->bshv", ctx, wuv)
    y = out.reshape(b, 1, n_heads * v_dim).astype(x.dtype) @ p["wo"]
    return y, (cache_ckv, cache_kr)
