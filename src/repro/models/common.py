"""Shared model components: norms, RoPE, MLPs, embeddings, chunked loss.

Everything is pure-JAX pytrees (no flax): params are nested dicts, and each
init function returns (params, specs) where specs mirrors params with
PartitionSpecs (logical sharding rules resolved in repro.parallel).
Layer stacks carry a leading L axis and run under jax.lax.scan to keep HLO
size and compile time bounded at 40-80 layer depth.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]
Specs = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.bfloat16,
               scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else (1.0 / np.sqrt(in_dim))
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def stacked_dense_init(key, n: int, in_dim: int, out_dim: int,
                       dtype=jnp.bfloat16,
                       scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else (1.0 / np.sqrt(in_dim))
    return (jax.random.normal(key, (n, in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5
            ) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def norm_apply(kind: str, x: jnp.ndarray, p: Params) -> jnp.ndarray:
    if kind == "rms":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def norm_init(kind: str, dim: int, n: Optional[int] = None,
              dtype=jnp.float32) -> Tuple[Params, Specs]:
    shape = (dim,) if n is None else (n, dim)
    spec = P(None) if n is None else P(None, None)
    p = {"w": jnp.ones(shape, dtype)}
    s = {"w": spec}
    if kind == "ln":
        p["b"] = jnp.zeros(shape, dtype)
        s["b"] = spec
    return p, s


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # hd/2
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, kind: str, d_model: int, d_ff: int, n: Optional[int] = None,
             dtype=jnp.bfloat16) -> Tuple[Params, Specs]:
    k1, k2, k3 = jax.random.split(key, 3)

    def mk(k, i, o):
        if n is None:
            return dense_init(k, i, o, dtype)
        return stacked_dense_init(k, n, i, o, dtype)

    lead = () if n is None else (None,)
    if kind == "swiglu":
        p = {"gate": mk(k1, d_model, d_ff), "up": mk(k2, d_model, d_ff),
             "down": mk(k3, d_ff, d_model)}
        s = {"gate": P(*lead, None, "model"), "up": P(*lead, None, "model"),
             "down": P(*lead, "model", None)}
        return p, s
    # gelu MLP
    p = {"fc": mk(k1, d_model, d_ff), "proj": mk(k2, d_ff, d_model),
         "fc_b": (jnp.zeros((d_ff,) if n is None else (n, d_ff), dtype)),
         "proj_b": (jnp.zeros((d_model,) if n is None else (n, d_model),
                              dtype))}
    s = {"fc": P(*lead, None, "model"), "proj": P(*lead, "model", None),
         "fc_b": P(*lead, "model"), "proj_b": P(*lead, None)}
    return p, s


def mlp_apply(kind: str, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "swiglu":
        g = x @ p["gate"]
        u = x @ p["up"]
        return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) \
            @ p["down"]
    h = x @ p["fc"] + p["fc_b"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return h @ p["proj"] + p["proj_b"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype=jnp.bfloat16
               ) -> Tuple[Params, Specs]:
    p = {"tok": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                 * 0.02).astype(dtype)}
    return p, {"tok": P("model", None)}


def embed_lookup(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


# ---------------------------------------------------------------------------
# Chunked cross-entropy: never materializes (B, S, V) logits.
# ---------------------------------------------------------------------------

def chunked_softmax_xent(h: jnp.ndarray, unembed: jnp.ndarray,
                         labels: jnp.ndarray, num_chunks: int = 8
                         ) -> jnp.ndarray:
    """Mean next-token CE.  h: (B, S, D) final hidden states, unembed
    (D, V), labels (B, S).  Scans over sequence chunks so peak logits memory
    is (B, S/num_chunks, V); XLA rematerializes chunk logits in backward."""
    b, s, d = h.shape
    assert s % num_chunks == 0, (s, num_chunks)
    # (scoped for HLO traffic attribution)
    cs = s // num_chunks
    h_chunks = h.reshape(b, num_chunks, cs, d).transpose(1, 0, 2, 3)
    l_chunks = labels.reshape(b, num_chunks, cs).transpose(1, 0, 2)

    def body(carry, xs):
        hc, lc = xs
        logits = (hc @ unembed).astype(jnp.float32)        # (B, cs, V)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None],
                                   axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (h_chunks, l_chunks))
    return total / (b * s)


def full_softmax_xent(h: jnp.ndarray, unembed: jnp.ndarray,
                      labels: jnp.ndarray) -> jnp.ndarray:
    logits = (h @ unembed).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
