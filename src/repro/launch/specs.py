"""ShapeDtypeStruct stand-ins and PartitionSpec trees for every
(architecture x input-shape) dry-run cell.  Nothing here allocates device
memory: params/opt/caches come from jax.eval_shape.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import lm
from ..parallel.sharding import filter_spec
from ..training import AdamWConfig, init_opt_state, zero1_specs

BATCH = ("pod", "data")


def param_shapes_and_specs(cfg: ModelConfig):
    """Abstract param tree + PartitionSpecs, with zero allocation."""
    captured = {}

    def build(key):
        p, s = lm.init_params(cfg, key)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def input_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one cell's model inputs."""
    b, s = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        out = {"tokens": sd((b, s), jnp.int32),
               "labels": sd((b, s), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": sd((b, s), jnp.int32)}
    elif shape.kind == "decode":
        out = {"token": sd((b, 1), jnp.int32),
               "cur_len": sd((), jnp.int32)}
    else:
        raise ValueError(shape.kind)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["image_embeds"] = sd((b, cfg.n_frontend_tokens, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.family == "encdec" and shape.kind != "decode":
        out["frames"] = sd((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return out


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, shard_batch: bool
                 ) -> Dict[str, P]:
    bax = BATCH if shard_batch else None
    if shape.kind == "train":
        out = {"tokens": P(bax, None), "labels": P(bax, None)}
    elif shape.kind == "prefill":
        out = {"tokens": P(bax, None)}
    else:
        out = {"token": P(bax, None), "cur_len": P()}
    if cfg.family == "vlm" and shape.kind != "decode":
        out["image_embeds"] = P(bax, None, None)
    if cfg.family == "encdec" and shape.kind != "decode":
        out["frames"] = P(bax, None, None)
    return out


def cache_shapes(cfg: ModelConfig, b: int, max_seq: int,
                 prefill_len: int = 64):
    """Abstract KV/state-cache tree via eval_shape of prefill (so the specs
    can never drift from what prefill actually produces)."""
    params_sh, _ = param_shapes_and_specs(cfg)
    batch = dict(input_specs(
        cfg, ShapeConfig("tmp", "prefill", prefill_len, b)))

    def run(params, bt):
        _, caches = lm.prefill_fn(cfg, params, bt, max_seq)
        return caches

    return jax.eval_shape(run, params_sh, batch)


def cache_pspecs(cfg: ModelConfig, caches, shard_batch: bool,
                 shard_time: bool, model_size: int = 16) -> Any:
    """PartitionSpecs per cache leaf, keyed by cache name + rank.

    Layout rules (perf iteration C1b, EXPERIMENTS.md §Perf): batch over
    (pod, data) when it divides; KV heads over `model` when the head count
    divides, else HEAD_DIM over `model` — NEVER the time axis for decode
    caches: a dynamic-index update into a time-sharded buffer forces GSPMD
    to rewrite the whole cache per step (measured 15x traffic blowup).
    long_500k (batch=1) is the exception: no new-token axis fits, so time
    shards and attention pays a partial-softmax all-reduce instead."""
    bax = BATCH if shard_batch else None
    tax = "model" if shard_time else None
    fam = cfg.family

    def heads_or_hd(kv: int, hd: int):
        """(head_entry, hd_entry) for a (..., KV, hd) cache."""
        if shard_time:
            return None, None
        if kv % model_size == 0:
            return "model", None
        if hd % model_size == 0:
            return None, "model"
        return None, None

    def spec_for(name: str, leaf) -> P:
        nd = leaf.ndim
        if name in ("k", "v"):
            he, de = heads_or_hd(leaf.shape[-2], leaf.shape[-1])
            if fam == "vlm":      # (G, n_self, B, T, KV, hd)
                return P(None, None, bax, tax, he, de)
            # (L, B, T, KV, hd)
            return P(None, bax, tax, he, de)
        if name in ("attn_k", "attn_v"):   # (G, B, T, KV, hd)
            he, de = heads_or_hd(leaf.shape[-2], leaf.shape[-1])
            return P(None, bax, tax, he, de)
        if name in ("k_scale", "v_scale"):  # (L, B, T, KV)
            he = "model" if (not shard_time
                             and leaf.shape[-1] % model_size == 0) else None
            return P(None, bax, tax, he)
        if name in ("ckv", "kr"):          # (L, B, T, lora|rope)
            return P(None, bax, tax, None)
        if name in ("k0", "v0"):           # (B, T, lora|rope) or (B,T,KV,hd)
            if nd == 3:
                return P(bax, tax, None)
            return P(bax, tax, None if shard_time else "model", None)
        if name in ("xk", "xv"):           # (L|G, B, T_src, KV, hd)
            return P(None, bax, None, "model", None)
        if name == "ssm":                  # (L, B, H, P, N)
            return P(None, bax, "model", None, None)
        if name == "conv":                 # (L, B, K-1, conv_dim)
            return P(None, bax, None, "model")
        if name == "group_ssm":            # (G, per, B, H, P, N)
            return P(None, None, bax, "model", None, None)
        if name == "group_conv":           # (G, per, B, K-1, conv)
            return P(None, None, bax, None, "model")
        if name == "tail_ssm":             # (T, B, H, P, N)
            return P(None, bax, "model", None, None)
        if name == "tail_conv":
            return P(None, bax, None, "model")
        raise KeyError(f"no cache spec rule for {name!r} (rank {nd})")

    return {name: spec_for(name, leaf) for name, leaf in caches.items()}


# ---------------------------------------------------------------------------
# Assembled per-cell lowering inputs
# ---------------------------------------------------------------------------

def _axis_size(mesh, entry) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(entry, 1)


def sanitize_spec(mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Explicit in/out shardings must divide exactly (GSPMD pads only for
    constraints).  Entries that don't divide are RELOCATED to the largest
    other unsharded dim that does divide, else dropped.  E.g. a (V, D)
    embedding with V=50280 on a model=16 mesh moves 'model' to D."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, e in enumerate(entries):
        if e is None:
            continue
        n = _axis_size(mesh, e)
        if n <= 1 or shape[i] % n == 0:
            continue
        entries[i] = None
        candidates = [j for j, e2 in enumerate(entries)
                      if e2 is None and shape[j] % n == 0 and shape[j] >= n]
        if candidates:
            j = max(candidates, key=lambda j_: shape[j_])
            entries[j] = e
    return P(*entries)


def shardings(mesh, spec_tree, shape_tree=None):
    axes = tuple(mesh.axis_names)

    if shape_tree is None:
        return jax.tree.map(
            lambda sp: NamedSharding(mesh, filter_spec(sp, axes)),
            spec_tree, is_leaf=lambda x: isinstance(x, P))

    def one(sp, leaf):
        sp = filter_spec(sp, axes)
        sp = sanitize_spec(mesh, sp, tuple(leaf.shape))
        return NamedSharding(mesh, sp)

    return jax.tree.map(one, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               opt: Optional[AdamWConfig] = None, microbatches: int = 1):
    """Returns (fn, arg_shapes, in_shardings, out_shardings) ready to lower.

    train  -> train_step(params, opt_state, batch)
    prefill-> prefill(params, batch)             (max_seq == seq_len)
    decode -> decode(params, token, caches, cur_len) with cache len seq_len
    """
    from ..training import make_train_step

    n_data = 1
    for ax, size in zip(mesh.axis_names, mesh.devices.shape):
        if ax in BATCH:
            n_data *= size
    shard_batch = shape.global_batch % n_data == 0 and shape.global_batch >= n_data
    shard_time = (not shard_batch) and shape.kind == "decode"

    params_sh, params_specs = param_shapes_and_specs(cfg)
    p_shard = shardings(mesh, params_specs, params_sh)
    batch_sh = input_specs(cfg, shape)
    batch_spec = input_pspecs(cfg, shape, shard_batch)
    b_shard = shardings(mesh, batch_spec, batch_sh)

    if shape.kind == "train":
        opt = opt or AdamWConfig()
        opt_sh = jax.eval_shape(init_opt_state, params_sh)
        opt_specs = zero1_specs(params_specs, params_sh)
        o_shard = shardings(mesh, opt_specs, opt_sh)
        fn = make_train_step(cfg, opt, microbatches)
        metrics_shard = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), {"loss": 0, "grad_norm": 0,
                                                 "lr_scale": 0})
        return (fn, (params_sh, opt_sh, batch_sh),
                (p_shard, o_shard, b_shard),
                (p_shard, o_shard, metrics_shard))

    if shape.kind == "prefill":
        def fn(params, batch):
            return lm.prefill_fn(cfg, params, batch, shape.seq_len)
        logits_sh, caches_sh = jax.eval_shape(fn, params_sh, batch_sh)
        msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        c_specs = cache_pspecs(cfg, caches_sh, shard_batch, False, msize)
        c_shard = shardings(mesh, c_specs, caches_sh)
        logits_shard = shardings(
            mesh, P(BATCH if shard_batch else None, None, "model"),
            logits_sh)
        return (fn, (params_sh, batch_sh), (p_shard, b_shard),
                (logits_shard, c_shard))

    # decode
    caches_sh = cache_shapes(cfg, shape.global_batch, shape.seq_len)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    c_specs = cache_pspecs(cfg, caches_sh, shard_batch, shard_time, msize)
    c_shard = shardings(mesh, c_specs, caches_sh)

    def fn(params, token, caches, cur_len):
        return lm.decode_fn(cfg, params, token, caches, cur_len)

    logits_sh = jax.ShapeDtypeStruct(
        (shape.global_batch, 1, cfg.vocab), jnp.float32)
    logits_shard = shardings(
        mesh, P(BATCH if shard_batch else None, None, "model"), logits_sh)
    return (fn,
            (params_sh, batch_sh["token"], caches_sh, batch_sh["cur_len"]),
            (p_shard, b_shard["token"], c_shard, b_shard["cur_len"]),
            (logits_shard, c_shard))
