import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (no allocation), print
memory_analysis / cost_analysis, and write the roofline record.

The two lines ABOVE the docstring must run before any jax import: jax locks
the device count at first init, and the production meshes need 512 host
devices.

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
    python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all [--jobs 4]    # orchestrate subprocesses
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import List, Optional, Tuple

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# cells skipped by design: long_500k needs sub-quadratic attention
# (DESIGN.md §4); only ssm/hybrid run it.


def cell_list() -> List[Tuple[str, str]]:
    from ..configs import ARCH_NAMES, SHAPES, get_config
    cells = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue
            cells.append((arch, shape.name))
    return cells


PERF_OVERRIDES = {
    # measured perf-variant knobs (see EXPERIMENTS.md §Perf)
    "scores_bf16": {"attn_scores_dtype": "bf16"},
    "moe_ep": {"moe_impl": "ep_shardmap"},
    "kv_int8": {"kv_cache_quant": True},
    "flash": {"attn_impl": "flash"},
    "attn_remat": {"attn_chunk_remat": True},
    "seq_shard": {"attn_seq_shard": True},
    "seq_res": {"seq_parallel_residual": True},
}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, microbatches: int = 1,
             variant: str = "baseline", perf: str = "") -> dict:
    import dataclasses

    import jax
    from ..configs import SHAPES, get_config
    from .hlo_analysis import analyze_compiled, parse_collectives
    from .mesh import make_production_mesh
    from .specs import build_cell

    cfg = get_config(arch)
    if perf:
        over = {}
        for k in perf.split(","):
            over.update(PERF_OVERRIDES[k.strip()])
        cfg = dataclasses.replace(cfg, **over)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    t0 = time.time()
    fn, arg_shapes, in_sh, out_sh = build_cell(cfg, shape, mesh,
                                               microbatches=microbatches)
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    from ..parallel.compat import set_mesh
    with set_mesh(mesh):
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    n_chips = mesh.devices.size
    analysis = analyze_compiled(compiled, default_group=2)
    mem = analysis["memory"]
    print(f"[{arch} x {shape_name} x {mesh_name}] lower={t_lower:.1f}s "
          f"compile={t_compile:.1f}s")
    print("  memory_analysis:", json.dumps(mem))
    print("  cost_analysis: flops/device=%.3e bytes/device=%.3e"
          % (analysis["roofline"]["flops"], analysis["roofline"]["hbm_bytes"]))
    print("  collectives:", json.dumps(analysis["roofline"]["counts"]))

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "chips": int(n_chips),
        "microbatches": microbatches,
        "lower_s": t_lower, "compile_s": t_compile,
        **analysis,
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}__{variant}.json".replace(
        "/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def orchestrate(jobs: int, multi_pod_too: bool, out_dir: str,
                only_missing: bool = True) -> int:
    cells = cell_list()
    meshes = [False, True] if multi_pod_too else [False]
    work = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "multipod_2x16x16" if mp else "pod_16x16"
            fname = os.path.join(
                out_dir, f"{arch}__{shape}__{mesh_name}__baseline.json")
            if only_missing and os.path.exists(fname):
                continue
            work.append((arch, shape, mp))
    print(f"{len(work)} cells to run ({len(cells)} cells x "
          f"{len(meshes)} meshes, skipping existing)")
    procs: List[Tuple[subprocess.Popen, tuple]] = []
    failures = []
    idx = 0
    while idx < len(work) or procs:
        while idx < len(work) and len(procs) < jobs:
            arch, shape, mp = work[idx]
            idx += 1
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", out_dir]
            if mp:
                cmd.append("--multi-pod")
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True)
            procs.append((p, (arch, shape, mp)))
        for i, (p, meta) in enumerate(list(procs)):
            if p.poll() is not None:
                out, _ = p.communicate()
                tail = "\n".join(out.splitlines()[-12:])
                status = "OK" if p.returncode == 0 else f"FAIL({p.returncode})"
                print(f"--- {meta} {status} ---\n{tail}\n")
                if p.returncode != 0:
                    failures.append(meta)
                procs.remove((p, meta))
        time.sleep(1.0)
    if failures:
        print("FAILED CELLS:", failures)
        return 1
    print("ALL CELLS PASSED")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--perf", default="",
                    help="comma-separated perf knobs: scores_bf16, moe_ep, "
                         "kv_int8, flash")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()

    if args.all:
        sys.exit(orchestrate(args.jobs, not args.single_pod_only, args.out))
    try:
        variant = args.variant
        if args.perf and variant == "baseline":
            variant = args.perf.replace(",", "+")
        run_cell(args.arch, args.shape, args.multi_pod, args.out,
                 args.microbatches, variant, args.perf)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
