"""Whole-program HLO cost analysis with loop-trip-count multiplicities.

XLA's built-in `compiled.cost_analysis()` counts each `while` body ONCE
(verified empirically: a scanned 4-layer matmul reports 1/4 the FLOPs of the
unrolled version).  Our models scan over layers, KV chunks, loss chunks and
microbatches, so aggregate numbers from cost_analysis are off by orders of
magnitude.  This module re-derives program costs from the partitioned HLO
text itself:

  1. split the module into computations (keeping each header's parameter
     types — scheduled HLO prints operands as bare names, so every
     computation gets a symbol table name -> shape),
  2. build the computation call graph: while bodies/conditions weighted by
     `known_trip_count` from backend_config, calls/fusions/to_apply weight 1,
  3. propagate execution multiplicity from ENTRY,
  4. per executed instruction, accumulate
       - dot FLOPs: 2 * numel(result) * prod(lhs contracting dims)
       - elementwise/reduce FLOPs: numel(result) (first-order)
       - HBM traffic: operand + result bytes at fusion/op boundaries
       - collective wire bytes (ring model; group size from replica_groups)
     each scaled by the computation's multiplicity.

All quantities are PER DEVICE: the input is the SPMD-partitioned module.
CPU-backend HLO stands in for TPU HLO structurally (same partitioner, same
collectives); fusion granularity differs, so traffic is a structural
estimate, while dot FLOPs and collective bytes are exact for the partitioned
program.  Methodology caveats are recorded in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|"
    r"u4|pred|c64|c128)\[([\d,]*)\]")

_INSTR_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=([%\w.\-]+),\s*body=([%\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_TARGET_RE = re.compile(
    r"(?:calls=|to_apply=|branch_computations=\{)([%\w.\-, ]+)\}?")
_OPNAME_RE = re.compile(r"=\s*(?:\([^=]*?\)|[\w\[\],{}\d]+)\s+([\w\-]+)\(")
_COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                   "all-to-all", "collective-permute")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_SCOPE_RE = re.compile(r'op_name="([^"]*)"')


def _scope_of(line: str) -> str:
    m = _SCOPE_RE.search(line)
    if not m:
        return "other"
    name = m.group(1)
    for scope in ("attention", "moe", "mamba"):
        if scope in name:
            return scope + ("_bwd" if "transpose(jvp" in name else "")
    if "transpose(jvp" in name:
        return "backward_other"
    return "other"
_HEADER_PARAM_RE = re.compile(r"([\w.\-]+):\s+((?:\([^)]*\))|[^,()]+)")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "call", "after-all",
             "partition-id", "replica-id", "copy-start", "copy-done",
             "add-dependency", "domain", "opt-barrier"}

# Ops whose operand/result bytes count as HBM traffic.  The TPU fusion model
# assumed here: elementwise chains, converts, copies (aliasing), reshapes
# and transposes fuse into neighboring ops; irreducible traffic happens at
# dot/gather/scatter/reduce/sort/collective boundaries and at explicit
# fusion nodes (which the CPU backend forms around elementwise regions, so
# their boundary bytes stand in for the fused-region traffic).
# dynamic-(update-)slice is special-cased in _line_costs: only the moved
# slice counts, not the aliased full buffer.
_TRAFFIC_OPS = {
    "fusion", "dot", "custom-call", "gather", "scatter", "reduce",
    "reduce-window", "select-and-scatter", "sort", "convolution",
    "rng-bit-generator", "cholesky", "triangular-solve",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_ELEMENTWISE_FLOP_OPS = {
    "add", "multiply", "subtract", "divide", "select", "compare",
    "exponential", "tanh", "maximum", "minimum", "rsqrt", "log", "sqrt",
    "negate", "abs", "power", "and", "or", "xor", "clamp", "floor", "ceil",
    "sign", "logistic", "cosine", "sine", "exponential-minus-one",
    "log-plus-one", "fusion", "reduce", "reduce-window",
}


def _parse_shape(type_str: str) -> Tuple[int, int, List[List[int]]]:
    """(numel, bytes, list of dim-lists) over every array in the type."""
    total_n, total_b = 0, 0
    dims_list: List[List[int]] = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        dl = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dl:
            n *= d
        total_n += n
        total_b += n * _DTYPE_BYTES[dt]
        dims_list.append(dl)
    return total_n, total_b, dims_list


@dataclasses.dataclass
class ProgramCost:
    dot_flops: float = 0.0
    elementwise_flops: float = 0.0
    traffic_bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)
    # attribution by jax.named_scope found in op metadata (attention/moe/...)
    traffic_by_scope: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    wire_by_scope: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    @property
    def flops(self) -> float:
        return self.dot_flops + self.elementwise_flops


@dataclasses.dataclass
class _Comp:
    header: str
    lines: List[str]
    is_entry: bool
    symtab: Dict[str, str] = dataclasses.field(default_factory=dict)
    # effective streamed bytes through pure dtype/layout movement chains:
    # a bf16 tensor produced by converting an int8 array streams int8 bytes
    # from HBM (the convert runs in-register on TPU after the load)
    eff: Dict[str, int] = dataclasses.field(default_factory=dict)


def _split_computations(hlo: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    cur_name = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if (line[:1] not in (" ", "\t") and stripped.endswith("{")
                and not stripped.startswith("HloModule")
                and (stripped.startswith("%") or stripped.startswith("ENTRY")
                     or "->" in stripped)):
            if cur_name is not None:
                comps[cur_name] = cur
            is_entry = stripped.startswith("ENTRY")
            name_part = stripped[len("ENTRY "):] if is_entry else stripped
            cur_name = name_part.split(" ")[0].split("(")[0]
            cur = _Comp(stripped, [], is_entry)
            continue
        if cur is not None:
            cur.lines.append(line)
    if cur_name is not None:
        comps[cur_name] = cur

    # symbol tables: instruction results + header parameters
    for comp in comps.values():
        hdr = comp.header
        if "(" in hdr:
            params = hdr[hdr.index("("):]
            for pm in _HEADER_PARAM_RE.finditer(params):
                comp.symtab["%" + pm.group(1)] = pm.group(2)
        for line in comp.lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            rest = line[line.index("=") + 1:]
            opm = _OPNAME_RE.search(line)
            if opm:
                opn = rest.find(opm.group(1) + "(")
                type_str = rest[:opn] if opn > 0 else rest
            else:
                type_str = rest
            comp.symtab[im.group(1)] = type_str.strip()
    return comps


def _eff_bytes(comp: _Comp, name: str) -> int:
    if name in comp.eff:
        return comp.eff[name]
    t = comp.symtab.get(name, "")
    _, b, _ = _parse_shape(t)
    return b


def _build_eff_maps(comps: Dict[str, _Comp], movement: set) -> None:
    """Sequential per-computation pass: results of pure-movement ops (and
    fusions over pure-movement bodies) inherit min(result, operand) bytes."""
    plain_movement = {"convert", "copy", "bitcast", "transpose", "reshape"}
    for comp in comps.values():
        for line in comp.lines:
            im = _INSTR_RE.match(line)
            opm = _OPNAME_RE.search(line)
            if not im or not opm:
                continue
            op = opm.group(1)
            is_mv = op in plain_movement
            if op == "fusion":
                cm = _CALL_TARGET_RE.search(line)
                is_mv = bool(cm) and cm.group(1).split(",")[0].strip() \
                    in movement
            if not is_mv:
                continue
            rname = im.group(1)
            rb = _eff_bytes(comp, rname)  # own type bytes (eff unset yet)
            opn = line.find(op + "(", line.find("="))
            args = line[opn + len(op) + 1:]
            depth, end = 1, 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(args[:end])
            if operands:
                ob = sum(_eff_bytes(comp, o) for o in operands)
                comp.eff[rname] = min(rb, ob)


def _group_size(line: str, default: int = 2) -> int:
    g = _IOTA_GROUPS_RE.search(line)
    if g:
        return max(int(g.group(2)), 2)
    g2 = _BRACE_GROUPS_RE.search(line)
    if g2:
        return max(len([x for x in g2.group(1).split(",") if x.strip()]), 2)
    return default


def _line_costs(line: str, comp: _Comp, cost: ProgramCost, mult: float,
                skip_traffic: bool) -> None:
    opm = _OPNAME_RE.search(line)
    if not opm:
        return
    op = opm.group(1)
    if op in _SKIP_OPS:
        return
    base_op = op[:-6] if op.endswith("-start") else op

    eq = line.find("=")
    opn = line.find(op + "(", eq)
    result_str = line[eq + 1: opn] if (eq >= 0 and opn > eq) else ""
    rn, rb, _ = _parse_shape(result_str)

    # operand segment: between "op(" and the matching close — approximate
    # with the text up to "), " or end; operands are bare %names here.
    args_start = opn + len(op) + 1
    args_str = line[args_start:]
    depth = 1
    end = 0
    for i, ch in enumerate(args_str):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args_str = args_str[:end]
    operand_names = _OPERAND_RE.findall(args_str)

    if base_op in _COLLECTIVE_OPS and "-done" not in op:
        n = _group_size(line)
        if base_op == "all-reduce":
            wire = 2.0 * rb * (n - 1) / n
        elif base_op == "all-gather":
            wire = rb * (n - 1) / n
        elif base_op == "reduce-scatter":
            wire = rb * (n - 1)
        elif base_op == "all-to-all":
            wire = rb * (n - 1) / n
        else:
            wire = float(rb)
        cost.wire_bytes += wire * mult
        cost.wire_by_op[base_op] += wire * mult
        cost.wire_by_scope[_scope_of(line)] += wire * mult
        cost.collective_count[base_op] += max(int(round(mult)), 1)

    if op == "dot":
        k = 1
        km = _CONTRACT_RE.search(line)
        if km and km.group(1) and operand_names:
            lhs_type = comp.symtab.get(operand_names[0], "")
            _, _, dims_list = _parse_shape(lhs_type)
            if dims_list:
                lhs_dims = dims_list[0]
                for ci in km.group(1).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
        cost.dot_flops += 2.0 * rn * k * mult
    elif op == "convolution":
        cost.dot_flops += 2.0 * rn * mult  # not used by our models
    elif base_op in _ELEMENTWISE_FLOP_OPS:
        cost.elementwise_flops += float(rn) * mult

    if skip_traffic:
        return
    if op == "dynamic-update-slice":
        # in-place on TPU: traffic = the update slice (read + write)
        if len(operand_names) >= 2:
            t = comp.symtab.get(operand_names[1])
            if t:
                _, b, _ = _parse_shape(t)
                cost.traffic_bytes += 2.0 * b * mult
                cost.traffic_by_scope[_scope_of(line)] += 2.0 * b * mult
        return
    if op == "dynamic-slice" or op == "slice":
        cost.traffic_bytes += 2.0 * rb * mult  # read slice + write result
        cost.traffic_by_scope[_scope_of(line)] += 2.0 * rb * mult
        return
    if base_op in _TRAFFIC_OPS:
        ob = 0
        if op == "fusion" and _MOVEMENT_FUSIONS:
            cm0 = _CALL_TARGET_RE.search(line)
            if cm0 and cm0.group(1).split(",")[0].strip() in _MOVEMENT_FUSIONS:
                return  # pure dtype/layout movement: fuses away on TPU
        if op == "fusion" and _FUSION_PARAM_BYTES is not None:
            cm = _CALL_TARGET_RE.search(line)
            rec = _FUSION_PARAM_BYTES.get(
                cm.group(1).split(",")[0].strip()) if cm else None
            if rec is not None:
                per_param = rec.get("params", {})
                if "root_update" in rec:
                    rb = min(rb, 2 * int(rec["root_update"]))  # slice r+w
                for i, name in enumerate(operand_names):
                    full_b = _eff_bytes(comp, name)
                    if not full_b:
                        continue
                    eff = per_param.get(i)
                    ob += min(full_b, eff) if eff is not None else full_b
                cost.traffic_bytes += (rb + ob) * mult
                cost.traffic_by_scope[_scope_of(line)] += (rb + ob) * mult
                return
        for name in operand_names:
            ob += _eff_bytes(comp, name)
        cost.traffic_bytes += (rb + ob) * mult
        cost.traffic_by_scope[_scope_of(line)] += (rb + ob) * mult


_FUSION_PARAM_BYTES: Optional[Dict[str, Dict[int, int]]] = None
_MOVEMENT_FUSIONS: set = set()

_DS_PARAM_RE = re.compile(
    r"=\s*(\S+)\s+dynamic-slice\((%[\w.\-]+)")
_PARAM_DECL_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*\S+\s+parameter\((\d+)\)")


_DUS_RE = re.compile(
    r"=\s*\S+\s+dynamic-update-slice\(\s*(%[\w.\-]+),\s*(%[\w.\-]+)")


_MOVEMENT_OPS = {"convert", "copy", "bitcast", "transpose", "reshape",
                 "broadcast", "parameter", "tuple", "get-tuple-element",
                 "slice", "concatenate", "pad"}


def _pure_movement_fusions(comps: Dict[str, _Comp]) -> set:
    """Fused computations whose every op is dtype-conversion / layout
    movement.  The CPU backend materializes these as standalone fusions; on
    TPU they fuse into their consumers (convert into the MXU dot epilogue,
    transpose into the dot's layout assignment), so they carry no HBM
    traffic of their own."""
    out = set()
    for name, comp in comps.items():
        if "fused" not in name and "wrapped" not in name:
            continue
        ops = []
        for line in comp.lines:
            m = _OPNAME_RE.search(line)
            if m:
                ops.append(m.group(1))
        if ops and all(op in _MOVEMENT_OPS for op in ops):
            out.add(name)
    return out


def _fusion_param_bytes(comps: Dict[str, _Comp]
                        ) -> Dict[str, Dict[str, object]]:
    """Per fused computation:
      'params': param index -> effective streamed bytes when the param is
        consumed only via dynamic-slice (a scan body slicing one layer out
        of stacked weights streams the slice, not the stack);
      'root_update': if the fusion root is a dynamic-update-slice, the
        update-slice bytes (the output buffer is aliased in place — only
        the slice is written)."""
    out: Dict[str, Dict[str, object]] = {}
    for name, comp in comps.items():
        if "fused" not in name and "wrapped" not in name:
            continue
        pidx: Dict[str, int] = {}
        origin: Dict[str, str] = {}   # movement-op result -> source param
        for line in comp.lines:
            pm = _PARAM_DECL_RE.match(line)
            if pm:
                pidx[pm.group(1)] = int(pm.group(2))
                origin[pm.group(1)] = pm.group(1)
                continue
            im = _INSTR_RE.match(line)
            opm = _OPNAME_RE.search(line)
            if im and opm and opm.group(1) in ("bitcast", "copy", "convert",
                                               "reshape", "transpose"):
                ops = _OPERAND_RE.findall(line[line.find(opm.group(1) + "("):])
                if ops and ops[0] in origin:
                    origin[im.group(1)] = origin[ops[0]]
        sliced: Dict[int, int] = {}
        direct_use: Dict[int, bool] = {}
        root_update: Optional[int] = None
        for line in comp.lines:
            dm = _DS_PARAM_RE.search(line)
            if dm and origin.get(dm.group(2)) in pidx:
                _, b, _ = _parse_shape(dm.group(1))
                i = pidx[origin[dm.group(2)]]
                sliced[i] = sliced.get(i, 0) + b
                continue
            du = _DUS_RE.search(line)
            if du:
                # the DUS target buffer aliases the fusion output in place:
                # only the update slice is real traffic.  The target operand
                # may be a parameter directly or reach one through local
                # movement ops (bitcast/copy) — treat both as aliased.
                tgt = origin.get(du.group(1))
                if tgt in pidx:
                    sliced.setdefault(pidx[tgt], 0)
                upd_t = comp.symtab.get(du.group(2), "")
                if not upd_t and du.group(2) in pidx:
                    upd_t = comp.symtab.get(du.group(2), "")
                _, ub, _ = _parse_shape(upd_t)
                if ub:
                    root_update = (root_update or 0) + ub
                continue
            for pname, i in pidx.items():
                if pname in line and "parameter(" not in line \
                        and "bitcast" not in line and " copy(" not in line:
                    direct_use[i] = True
        eff = {i: b for i, b in sliced.items() if not direct_use.get(i)}
        rec: Dict[str, object] = {}
        if eff:
            rec["params"] = eff
        if root_update is not None:
            rec["root_update"] = root_update
        if rec:
            out[name] = rec
    return out


def analyze_hlo_program(hlo: str) -> ProgramCost:
    global _FUSION_PARAM_BYTES, _MOVEMENT_FUSIONS
    comps = _split_computations(hlo)
    _FUSION_PARAM_BYTES = _fusion_param_bytes(comps)
    _MOVEMENT_FUSIONS = _pure_movement_fusions(comps)
    _build_eff_maps(comps, _MOVEMENT_FUSIONS)
    entry = None
    for name, comp in comps.items():
        if comp.is_entry:
            entry = name
            break
    cost = ProgramCost()
    if entry is None:
        return cost

    edges: Dict[str, List[Tuple[str, float, bool]]] = defaultdict(list)
    for name, comp in comps.items():
        for line in comp.lines:
            wm = _WHILE_RE.search(line)
            if wm:
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                cost.while_trip_counts.append(trip)
                edges[name].append((wm.group(1), float(trip), False))
                edges[name].append((wm.group(2), float(trip), False))
                continue
            cm = _CALL_TARGET_RE.search(line)
            if cm:
                via_fusion = " fusion(" in line
                for target in cm.group(1).split(","):
                    target = target.strip()
                    if target in comps:
                        edges[name].append((target, 1.0, via_fusion))

    mult: Dict[str, float] = defaultdict(float)
    fused_only: Dict[str, bool] = {entry: False}
    mult[entry] = 1.0
    for name in _topo_order(entry, edges):
        for callee, w, via_fusion in edges.get(name, ()):
            mult[callee] += mult[name] * w
            prev = fused_only.get(callee, True)
            fused_only[callee] = prev and (via_fusion
                                           or fused_only.get(name, False))

    for name, m in mult.items():
        if m <= 0 or name not in comps:
            continue
        comp = comps[name]
        skip_traffic = fused_only.get(name, False)
        for line in comp.lines:
            _line_costs(line, comp, cost, m, skip_traffic)
    return cost


def _topo_order(entry: str, edges) -> List[str]:
    seen, order = set(), []

    def visit(n, depth=0):
        if n in seen or depth > 500:
            return
        seen.add(n)
        for callee, _, _ in edges.get(n, ()):
            visit(callee, depth + 1)
        order.append(n)

    visit(entry)
    return list(reversed(order))
