"""Roofline report generator: reads experiments/dryrun/*.json, derives the
three roofline terms per (arch x shape x mesh), computes MODEL_FLOPS and the
usefulness ratio, and emits the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.roofline [--out experiments/roofline_table.md]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from ..configs import SHAPES, get_config
from .hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs for the whole cell (GLOBAL, all chips):
    train: 6*N*D; prefill: 2*N*D; decode: 2*N*B (one token per sequence).
    N = active params for MoE."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load_records(out_dir: str, variant: str = "baseline") -> List[Dict]:
    recs = []
    for f in sorted(os.listdir(out_dir)):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(out_dir, f)) as fh:
            r = json.load(fh)
        if r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def enrich(r: Dict) -> Dict:
    rl = r["roofline"]
    mf = model_flops(r["arch"], r["shape"])
    mf_dev = mf / r["chips"]
    hlo = max(rl["flops"], 1.0)
    bound = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
    # roofline fraction: useful-compute time / bound term (how close the
    # dominant term is to pure useful compute at peak)
    useful_s = mf_dev / PEAK_FLOPS
    return {
        **{k: r[k] for k in ("arch", "shape", "mesh", "chips", "variant")},
        "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
        "collective_s": rl["collective_s"], "dominant": rl["dominant"],
        "model_flops_dev": mf_dev, "hlo_flops_dev": hlo,
        "useful_ratio": mf_dev / hlo,
        "bound_s": bound,
        "roofline_fraction": useful_s / bound if bound > 0 else 0.0,
        "counts": rl["counts"],
        "memory_args_gb": r.get("memory", {}).get(
            "argument_size_in_bytes", 0) / 1e9,
        "compile_s": r.get("compile_s", 0.0),
    }


BOTTLENECK_HINT = {
    "compute": "more useful-FLOP fraction (less remat / bigger microbatch)",
    "memory": "fuse attention (Pallas flash) / cut fp32 intermediates",
    "collective": "overlap or shrink collectives (bf16 grads, 1D TP->2D)",
}


def make_table(recs: List[Dict], mesh: str) -> str:
    rows = [e for e in (enrich(r) for r in recs) if e["mesh"] == mesh]
    rows.sort(key=lambda e: (e["arch"], e["shape"]))
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for e in rows:
        out.append(
            f"| {e['arch']} | {e['shape']} | {e['compute_s']:.3f} "
            f"| {e['memory_s']:.3f} | {e['collective_s']:.3f} "
            f"| **{e['dominant']}** | {e['useful_ratio']:.2f} "
            f"| {e['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=RESULTS_DIR)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = load_records(args.dir, args.variant)
    if not recs:
        print("no records found in", args.dir)
        return
    sections = []
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        n = len([r for r in recs if r["mesh"] == mesh])
        sections.append(f"### Mesh {mesh} ({n} cells, variant="
                        f"{args.variant})\n\n" + make_table(recs, mesh))
    # pick hillclimb candidates from single-pod table
    enriched = [enrich(r) for r in recs if r["mesh"] == "pod_16x16"]
    if enriched:
        worst = min(enriched, key=lambda e: e["roofline_fraction"])
        coll = max(enriched, key=lambda e: e["collective_s"]
                   / max(e["bound_s"], 1e-12))
        sections.append(
            "\n### Hillclimb candidates (single-pod)\n"
            f"- worst roofline fraction: {worst['arch']} x {worst['shape']} "
            f"({worst['roofline_fraction']:.4f}, {worst['dominant']}-bound)\n"
            f"- most collective-bound: {coll['arch']} x {coll['shape']} "
            f"(collective {coll['collective_s']:.3f}s)\n"
            f"- hints: " + json.dumps(BOTTLENECK_HINT))
    text = "\n\n".join(sections) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print("wrote", args.out)
    else:
        print(text)


if __name__ == "__main__":
    main()
