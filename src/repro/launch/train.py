"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b-smoke \
        --steps 200 --seq-len 64 --batch 16 --sql-filter "quality > 0.2"

Wires the full stack: Shark SQL engine selects the corpus (map pruning +
columnar store), TokenPipeline serves deterministic batches, the jitted
train_step runs under the requested mesh, CheckpointManager saves async with
the pipeline manifest (lineage), and --simulate-preemption proves the
restart path by killing and resuming mid-run.

On real hardware the same driver runs the full configs on the production
mesh; on CPU use the -smoke variants.
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--sql-filter", default="quality > 0.1")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-preemption", type=int, default=0,
                    help="kill training at this step, then auto-restart")
    ap.add_argument("--mesh", default="none", choices=["none", "debug"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..checkpoint import CheckpointManager
    from ..configs import get_config
    from ..core import SharkSession
    from ..data import TokenPipeline, synthetic_corpus
    from ..models import lm
    from ..training import AdamWConfig, init_opt_state, make_train_step

    cfg = get_config(args.arch)
    sess = SharkSession(num_workers=4, max_threads=4)
    synthetic_corpus(sess, "corpus", cfg.vocab, n_docs=100,
                     mean_doc_len=4 * args.seq_len)
    pipe = TokenPipeline(sess, "corpus", args.seq_len, args.batch,
                         sql_filter=args.sql_filter)
    print(f"corpus: {len(pipe.stream)} tokens selected via SQL "
          f"(pruned {sess.metrics().pruned_partitions} partitions)")

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    start_step = 0
    if args.resume and mgr.latest_step() is not None:
        restored, manifest = mgr.restore_latest(
            {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        start_step = manifest["step"]
        print(f"resumed from checkpoint at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=args.lr),
                                      args.microbatches))
    t0 = time.time()
    step = start_step
    while step < args.steps:
        if args.simulate_preemption and step == args.simulate_preemption:
            print(f"SIMULATED PREEMPTION at step {step} — restarting "
                  f"from checkpoint")
            mgr.wait()
            restored, manifest = mgr.restore_latest(
                {"params": params, "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            step = manifest["step"]  # replay from the checkpointed step
            args.simulate_preemption = 0
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/max(step-start_step+1,1)*1000:.0f} "
                  f"ms/step)")
        if step > 0 and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt_state},
                     {"pipeline": pipe.manifest(step)})
        step += 1
    mgr.save(args.steps, {"params": params, "opt": opt_state},
             {"pipeline": pipe.manifest(args.steps)})
    mgr.wait()
    print("done; final checkpoint at", mgr.latest_step())
    sess.shutdown()


if __name__ == "__main__":
    main()
