"""Roofline-term extraction from compiled dry-run artifacts.

`cost_analysis()` supplies HLO FLOPs and bytes; collective traffic is NOT in
cost_analysis, so we parse the partitioned HLO text and sum the wire bytes
of every collective op.  Wire model (per participating device, ring
algorithms; n = collective group size):

    all-reduce        2 * result_bytes * (n-1)/n     (reduce-scatter + all-gather)
    all-gather        result_bytes * (n-1)/n         (receives all but own shard)
    reduce-scatter    result_bytes * (n-1)           (sends (n-1)/n of input)
    all-to-all        result_bytes * (n-1)/n
    collective-permute result_bytes

Hardware model (TPU v5e, per chip): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link per chip

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")

# `%name = TYPE op-name(` — TYPE may be a tuple
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_BRACE_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _BRACE_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, op: str, wire: float):
        self.wire_bytes += wire
        self.by_op[op] = self.by_op.get(op, 0.0) + wire
        self.counts[op] = self.counts.get(op, 0) + 1


def parse_collectives(hlo_text: str, default_group: int = 2
                      ) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        result_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        n = max(_group_size(line, default_group), 2)
        if op == "all-reduce":
            wire = 2.0 * result_bytes * (n - 1) / n
        elif op == "all-gather":
            wire = result_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            wire = result_bytes * (n - 1)
        elif op == "all-to-all":
            wire = result_bytes * (n - 1) / n
        else:  # collective-permute
            wire = float(result_bytes)
        stats.add(op, wire)
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    by_op: Dict[str, float]
    counts: Dict[str, int]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(flops: float, hbm_bytes: float,
                   coll: CollectiveStats) -> Roofline:
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll.wire_bytes / ICI_BW
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return Roofline(flops, hbm_bytes, coll.wire_bytes, compute_s, memory_s,
                    collective_s, dom, coll.by_op, coll.counts)


def analyze_compiled(compiled, default_group: int = 2) -> Dict:
    """Extract cost + memory + collective analysis from a jax Compiled.

    FLOPs/bytes come from the whole-program HLO walk in hlo_cost.py (XLA's
    cost_analysis counts while bodies once — useless for scanned models);
    the raw cost_analysis dict is kept for reference.
    """
    from .hlo_cost import analyze_hlo_program

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    prog = analyze_hlo_program(hlo)
    coll = CollectiveStats(
        wire_bytes=prog.wire_bytes, by_op=dict(prog.wire_by_op),
        counts=dict(prog.collective_count))
    rl = roofline_terms(prog.flops, prog.traffic_bytes, coll)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                if hasattr(ma, attr):
                    mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    return {"roofline": rl.to_dict(), "memory": mem,
            "program": {"dot_flops": prog.dot_flops,
                        "elementwise_flops": prog.elementwise_flops,
                        "traffic_bytes": prog.traffic_bytes,
                        "while_trip_counts": prog.while_trip_counts,
                        "traffic_by_scope": dict(prog.traffic_by_scope),
                        "wire_by_scope": dict(prog.wire_by_scope)},
            "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float))}}
