"""Production mesh definitions.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count before first jax init.

Axes (DESIGN.md §5):
  single pod:  (data=16, model=16)            = 256 chips (one v5e pod)
  multi pod:   (pod=2, data=16, model=16)     = 512 chips
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from ..parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present;"
            " run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (launch/dryrun.py does this)")
    return make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(data: int = 2, model: int = 2, pod: Optional[int] = None):
    """Small mesh for CPU tests (device count permitting)."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    n = int(np.prod(shape))
    return make_mesh(shape, axes, devices=jax.devices()[:n])
