"""Batched serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b-smoke \
        --batch 4 --prompt-len 32 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..configs import get_config
    from ..models import lm
    from ..serving import ServeEngine

    cfg = get_config(args.arch)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["image_embeds"] = rng.normal(
            size=(args.batch, cfg.n_frontend_tokens, cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "encdec":
        extra["frames"] = rng.normal(
            size=(args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)

    eng = ServeEngine(cfg, params,
                      max_seq=args.prompt_len + args.new_tokens,
                      temperature=args.temperature)
    t0 = time.time()
    out = eng.generate(prompts, args.new_tokens, extra or None)
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tok_s:.1f} tok/s incl. "
          f"prefill+compile)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
