from .optim import AdamWConfig, adamw_update, init_opt_state, zero1_specs
from .schedule import constant, warmup_cosine
from .train_step import make_eval_step, make_train_step

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "zero1_specs",
           "constant", "warmup_cosine", "make_eval_step", "make_train_step"]
