"""AdamW from scratch with mixed precision and ZeRO-1 sharding.

Parameters live in bf16 (compute dtype).  The optimizer state holds fp32
master weights plus fp32 first/second moments; every moment/master tensor is
additionally sharded across the `data` axis (ZeRO-1): with data=16, the
40 GB of fp32 Adam state for a 14B model drops to 2.5 GB per device group.
GSPMD materializes the reduce-scatter/all-gather pattern from the output
shardings alone — the update math below is ordinary jnp.

Gradient clipping is global-norm; weight decay is decoupled (AdamW).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    zero1: bool = True


def init_opt_state(params) -> Dict[str, Any]:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"step": jnp.zeros((), jnp.int32), "master": master,
            "mu": zeros, "nu": jax.tree.map(jnp.copy, zeros)}


def zero1_specs(param_specs, params) -> Dict[str, Any]:
    """Build optimizer-state PartitionSpecs: param spec + `data` sharding on
    the largest still-unsharded dimension of each tensor."""

    def shard_one(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # pick the largest dim whose spec entry is None
        best, best_size = None, 0
        for i, (e, size) in enumerate(zip(entries, leaf.shape)):
            if e is None and size > best_size:
                best, best_size = i, size
        if best is None:
            return P(*entries)
        entries[best] = "data"
        return P(*entries)

    moment_specs = jax.tree.map(
        shard_one, param_specs, params,
        is_leaf=lambda x: isinstance(x, P))
    return {"step": P(), "master": moment_specs,
            "mu": moment_specs, "nu": jax.tree.map(lambda s: s, moment_specs,
                                                   is_leaf=lambda x:
                                                   isinstance(x, P))}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, params, opt_state,
                 lr_scale: jnp.ndarray = 1.0):
    """One AdamW step.  Returns (new_params bf16, new_opt_state)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bias1 = 1.0 - b1 ** step.astype(jnp.float32)
    bias2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu2 = b1 * mu + (1.0 - b1) * g
        nu2 = b2 * nu + (1.0 - b2) * g * g
        mhat = mu2 / bias1
        nhat = nu2 / bias2
        m2 = m - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                       + cfg.weight_decay * m)
        return m2, mu2, nu2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["master"])
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, mu, nu) for g, m, mu, nu
           in zip(flat_g, flat_m, flat_mu, flat_nu)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), new_master, params)
    return new_params, {"step": step, "master": new_master, "mu": new_mu,
                        "nu": new_nu}, gnorm
