"""Partial DAG Execution applied to MoE training (DESIGN.md §4).

Shark's PDE collects per-task statistics while map output materializes and
re-plans the downstream DAG (join strategy, reducer count) between stages.
The exact analogue inside this framework: the MoE router's per-expert load
vector IS the paper's "heavy hitters" statistic, the capacity factor IS the
degree-of-parallelism knob, and the step boundary IS the stage boundary —
training steps are deterministic re-executable tasks, so the plan can change
between steps without correctness risk (the paper's argument §2.3/§3.1).

`MoEReplanner` consumes the expert-load stats that `moe_apply(...,
return_stats=True)` already emits (surfaced through train-step metrics),
maintains a lossy log-encoded history (the paper's 1-byte size encoding),
and re-selects:

  * capacity_factor — sized so the observed p99 expert load fits without
    drops (§3.1.2's "choose reducer count from observed partition sizes");
  * dispatch strategy — below `broadcast_threshold` active experts it
    recommends dense compute of the hot experts (the map-join analogue:
    replicate the small side instead of shuffling).

Changing the capacity factor changes the jitted step's shapes, so the
replanner exposes `bucketed_capacity()` — capacities snap to a small set of
buckets and the runtime keeps one compiled executable per bucket (the same
"select among pre-lowered stage-2 variants" pattern the SQL engine uses for
PDE join selection).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from ..core.stats import decode_size, encode_size

CAPACITY_BUCKETS = (1.0, 1.25, 1.5, 2.0, 3.0)


@dataclasses.dataclass
class MoEPlan:
    capacity_factor: float
    hot_experts: List[int]
    dense_hot: bool
    reason: str


class MoEReplanner:
    def __init__(self, num_experts: int, top_k: int,
                 target_drop_rate: float = 0.0,
                 dense_hot_threshold: float = 0.5,
                 history: int = 16):
        self.num_experts = num_experts
        self.top_k = top_k
        self.dense_hot_threshold = dense_hot_threshold
        self.history = history
        # lossy history: one byte per expert per step (paper §3.1)
        self._codes: List[np.ndarray] = []

    def observe(self, expert_load: np.ndarray) -> None:
        codes = np.array([encode_size(int(x)) for x in expert_load],
                         np.uint8)
        self._codes.append(codes)
        if len(self._codes) > self.history:
            self._codes.pop(0)

    def plan(self, tokens_per_step: int) -> MoEPlan:
        if not self._codes:
            return MoEPlan(1.25, [], False, "no statistics yet: default")
        loads = np.stack([[decode_size(int(c)) for c in row]
                          for row in self._codes])          # (steps, E)
        mean_load = loads.mean(axis=0)
        expected = tokens_per_step * self.top_k / self.num_experts
        peak = float(np.percentile(loads.max(axis=0), 99))
        cf_needed = peak / max(expected, 1.0)
        cf = next((b for b in CAPACITY_BUCKETS if b >= cf_needed),
                  CAPACITY_BUCKETS[-1])
        total = mean_load.sum()
        frac = mean_load / max(total, 1.0)
        hot = [int(i) for i in np.argsort(-frac)
               if frac[i] > self.dense_hot_threshold / self.num_experts * 4]
        dense_hot = bool(hot) and float(frac[hot].sum()) \
            > self.dense_hot_threshold
        return MoEPlan(
            cf, hot[:4], dense_hot,
            f"p99 load {peak:.0f} vs expected {expected:.0f} -> "
            f"cf {cf} (needed {cf_needed:.2f}); "
            f"{len(hot)} heavy-hitter experts carry "
            f"{float(frac[hot].sum()) if hot else 0:.0%}")

    def bucketed_capacity(self, tokens_per_step: int) -> float:
        """Snap to a compile-cache-friendly bucket (one executable each)."""
        return self.plan(tokens_per_step).capacity_factor
