"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, warmup: int = 200, total: int = 10000,
                  floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return warm * cos


def constant(step):
    return 1.0
