"""The jitted training step: loss -> grad -> AdamW, with optional gradient
accumulation (microbatching) via lax.scan.

This is the function the multi-pod dry-run lowers and compiles; its
in/out shardings come from the param/opt specs plus batch_spec on inputs.
Gradient all-reduce across (pod, data) and the ZeRO-1 reduce-scatter are
GSPMD-inserted from the sharding constraints — the collective roofline term
in EXPERIMENTS.md measures exactly these.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import lm
from .optim import AdamWConfig, adamw_update, init_opt_state
from .schedule import warmup_cosine


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With microbatches > 1, the global batch splits along axis 0
    and gradients accumulate in fp32 across a lax.scan (grad accumulation)."""

    def loss_for(params, batch):
        return lm.loss_fn(cfg, params, batch)

    grad_fn = jax.value_and_grad(loss_for)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                acc_loss, acc_g = acc
                l, g = grad_fn(params, mb)
                g32 = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                   acc_g, g)
                return (acc_loss + l, g32), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        lr_scale = warmup_cosine(opt_state["step"] + 1)
        new_params, new_opt, gnorm = adamw_update(opt, grads, params,
                                                  opt_state, lr_scale)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        return lm.loss_fn(cfg, params, batch)
    return eval_step
