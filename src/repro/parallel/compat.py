"""JAX version-compat shims for mesh context APIs.

The model/sharding code targets the modern mesh-context API
(``jax.sharding.set_mesh`` / ``jax.sharding.get_abstract_mesh``).  Older
installs (e.g. jax 0.4.37) expose neither publicly: the concrete mesh
context is tracked by ``jax._src.mesh.thread_resources`` (entered via
``with mesh:``) and the abstract-mesh context manager lives in
``jax._src.mesh``.  Centralizing the lookup here keeps every caller
version-agnostic — use

    from repro.parallel.compat import get_abstract_mesh, set_mesh

instead of touching ``jax.sharding`` directly.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax

try:  # pragma: no cover - trivial import probing
    from jax._src import mesh as _mesh_lib
except Exception:  # pragma: no cover
    _mesh_lib = None


def get_abstract_mesh():
    """Return the mesh of the innermost active mesh context, or None.

    Prefers the public ``jax.sharding.get_abstract_mesh`` when it exists.
    On older JAX, falls back to the internal abstract-mesh context and then
    to the physical mesh entered via ``with mesh:`` (thread_resources).
    The returned object (AbstractMesh or Mesh) always supports ``empty``,
    ``axis_names`` and ``axis_sizes``.
    """
    public = getattr(jax.sharding, "get_abstract_mesh", None)
    if public is not None:
        return public()
    if _mesh_lib is None:
        return None
    getter = getattr(_mesh_lib, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
        if mesh is not None and getattr(mesh, "axis_names", ()):
            return mesh
    phys = _mesh_lib.thread_resources.env.physical_mesh
    if phys is not None and not phys.empty:
        return phys.abstract_mesh
    return None


def current_axis_sizes() -> dict:
    """axis name -> size of the active mesh ({} when no mesh is set)."""
    mesh = get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", True):
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def make_mesh(axis_shapes, axis_names, devices=None) -> jax.sharding.Mesh:
    """`jax.make_mesh` with explicit Auto axis types when supported.

    Newer JAX takes an `axis_types` kwarg (and defaults axes to Auto);
    jax 0.4.37's `jax.make_mesh` predates axis types entirely — every axis
    is implicitly Auto there, so dropping the kwarg is semantically the
    same mesh."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names, devices=devices,
                axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:  # pragma: no cover - AxisType without the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


@contextlib.contextmanager
def set_mesh(mesh: Optional[jax.sharding.Mesh]):
    """Context manager equivalent of ``jax.sharding.set_mesh(mesh)``.

    On new JAX it delegates to the public API.  On older JAX it enters the
    physical mesh context (so bare-PartitionSpec ``with_sharding_constraint``
    resolves axis names) and, when available, the abstract-mesh context (so
    `get_abstract_mesh` agrees with the physical context).
    """
    public = getattr(jax.sharding, "set_mesh", None)
    if public is not None:
        with public(mesh):
            yield mesh
        return
    if mesh is None:
        yield None
        return
    with contextlib.ExitStack() as stack:
        stack.enter_context(mesh)
        if _mesh_lib is not None and hasattr(_mesh_lib, "set_abstract_mesh"):
            stack.enter_context(
                _mesh_lib.set_abstract_mesh(mesh.abstract_mesh))
        yield mesh
