"""Sharding helpers: mesh-aware activation constraints.

Parameters carry explicit PartitionSpecs built at init time (see
models/*.py); activations get constraints through `act_shard`, which filters
the requested axes down to those that exist in the *current* mesh — the same
model code runs unsharded on 1 CPU device, on a (data, model) pod, or on a
(pod, data, model) multi-pod mesh.

Axis convention (DESIGN.md §5):
  pod    — across pods (pure data parallel, gradient all-reduce hierarchy)
  data   — within-pod data parallel + ZeRO-1 optimizer sharding
  model  — tensor/expert parallel (heads, d_ff, vocab, experts)
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from .compat import get_abstract_mesh

BATCH_AXES: Tuple[str, ...] = ("pod", "data")
MODEL_AXIS = "model"


def current_mesh_axes() -> Tuple[str, ...]:
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


def filter_spec(spec: P, axes: Sequence[str]) -> P:
    """Drop mesh axes not present in `axes` from a PartitionSpec."""
    parts = []
    for entry in spec:
        if entry is None:
            parts.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in axes)
            parts.append(kept if kept else None)
        else:
            parts.append(entry if entry in axes else None)
    return P(*parts)


def maybe_shard(x: jax.Array, spec: P) -> jax.Array:
    axes = current_mesh_axes()
    if not axes:
        return x
    return jax.lax.with_sharding_constraint(x, filter_spec(spec, axes))


def batch_spec(*rest) -> P:
    """PartitionSpec with the batch dim over all data-parallel axes."""
    return P(BATCH_AXES, *rest)


def act_shard(x: jax.Array, kind: str) -> jax.Array:
    """Named activation-sharding policies (referenced in EXPERIMENTS.md)."""
    if kind == "hidden":          # (B, S, D)
        return maybe_shard(x, P(BATCH_AXES, None, None))
    if kind == "hidden_seq":      # (B, S, D), sequence-parallel residual
        return maybe_shard(x, P(BATCH_AXES, MODEL_AXIS, None))
    if kind == "hidden_tp":       # (B, S, D) with D sharded (seq-parallel
        return maybe_shard(x, P(BATCH_AXES, None, MODEL_AXIS))
    if kind == "heads":           # (B, S, H, hd)
        return maybe_shard(x, P(BATCH_AXES, None, MODEL_AXIS, None))
    if kind == "ffn":             # (B, S, F)
        return maybe_shard(x, P(BATCH_AXES, None, MODEL_AXIS))
    if kind == "logits":          # (B, S, V)
        return maybe_shard(x, P(BATCH_AXES, None, MODEL_AXIS))
    if kind == "experts":         # (E, C, D)
        return maybe_shard(x, P(MODEL_AXIS, None, None))
    if kind == "seq":             # sequence sharding (long-context decode)
        return maybe_shard(x, P(BATCH_AXES, MODEL_AXIS, None))
    raise ValueError(kind)
