from .sharding import (act_shard, current_mesh_axes, maybe_shard,
                       filter_spec, batch_spec)

__all__ = ["act_shard", "current_mesh_axes", "maybe_shard", "filter_spec",
           "batch_spec"]
