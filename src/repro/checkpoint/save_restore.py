"""Checkpoint/restart with manifest lineage and elastic re-sharding.

Fault-tolerance model for the SPMD side (DESIGN.md §2): a chip failure kills
the whole step, so recovery = restart from the latest checkpoint + replay
the deterministic data pipeline from the manifest's step counter — the
lineage idea applied at pod granularity.

Layout:
    <dir>/step_000123/
        manifest.json        # step, arch, mesh shape, pipeline manifest,
                             # leaf index {key -> file, shape, dtype}
        <key>.npy            # one array per pytree leaf

Saves are atomic (write to .tmp, rename) and optionally asynchronous
(snapshot to host, background thread writes).  Restore is *elastic*: leaves
come back as host numpy; the caller jits them onto whatever mesh the new job
has — a 256-chip checkpoint restores onto 512 chips (or 8) unchanged.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:
    import ml_dtypes
    _HAS_BF16 = True
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    _HAS_BF16 = False
    _BF16 = None


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Dict[str, Any],
                    extra_manifest: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_names(tree)
    index = {}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        logical_dtype = str(arr.dtype)
        if _HAS_BF16 and arr.dtype == _BF16:
            arr = arr.view(np.uint16)  # np.save can't round-trip bf16
        np.save(os.path.join(tmp, fname), arr)
        index[name] = {"file": fname, "shape": list(arr.shape),
                       "dtype": logical_dtype}
    manifest = {"step": step, "leaves": index}
    if extra_manifest:
        manifest.update(extra_manifest)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_checkpoint(directory: str, step: Optional[int] = None,
                       template: Optional[Dict[str, Any]] = None
                       ) -> Tuple[Dict[str, Any], Dict]:
    """Restore the given (or latest) step.  With `template`, leaves are
    reassembled into the template's pytree structure; otherwise a nested
    dict following the saved key paths is returned."""
    if step is None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        step = steps[-1]
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = {}
    for name, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if meta["dtype"] == "bfloat16" and _HAS_BF16:
            arr = arr.view(_BF16)
        arrays[name] = arr
    if template is not None:
        leaves = _flatten_with_names(template)
        restored = [jax.numpy.asarray(arrays[name]).astype(leaf.dtype)
                    if hasattr(leaf, "dtype") else arrays[name]
                    for name, leaf in leaves]
        treedef = jax.tree_util.tree_structure(template)
        return treedef.unflatten(restored), manifest
    nested: Dict[str, Any] = {}
    for name, arr in arrays.items():
        parts = name.split("/")
        d = nested
        for part in parts[:-1]:
            d = d.setdefault(part, {})
        d[parts[-1]] = arr
    return nested, manifest


class CheckpointManager:
    """Async, retention-managed checkpointing."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Dict[str, Any],
             extra_manifest: Optional[Dict] = None) -> None:
        # snapshot to host synchronously (cheap vs. training step), write in
        # the background so the step loop is not blocked on disk
        snapshot = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        if self._thread is not None:
            self._thread.join()

        def work():
            save_checkpoint(self.directory, step, snapshot, extra_manifest)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> Optional[int]:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.directory)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def restore_latest(self, template=None):
        return restore_checkpoint(self.directory, None, template)

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
