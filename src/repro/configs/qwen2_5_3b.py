"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import QWEN25_3B

CONFIG = QWEN25_3B
