"""ModelConfig: one declarative description covers all 10 assigned
architectures (dense / MoE / MLA / SSM / hybrid / VLM / enc-dec)."""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..models.moe import MoEConfig
from ..models.mamba2 import SSMConfig


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 => d_model // n_heads
    norm: str = "rms"                # rms | ln
    mlp: str = "swiglu"              # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: one SHARED attention block slot every `attn_every` slots
    attn_every: int = 0
    # vlm: one gated cross-attention layer every `cross_every` layers
    cross_every: int = 0
    n_frontend_tokens: int = 0       # vlm: projected patch tokens
    # enc-dec
    enc_layers: int = 0
    enc_seq: int = 0                 # whisper frames after conv frontend
    # runtime knobs
    kv_chunk: int = 1024
    loss_chunks: int = 8
    remat: bool = True
    sub_quadratic: bool = False      # supports long_500k decode
    # ---- perf variants (§Perf hillclimbing; defaults = paper-faithful
    # baseline). See EXPERIMENTS.md for the iteration log. ----
    attn_scores_dtype: str = "f32"   # f32 | bf16 (score/prob tensors)
    moe_impl: str = "gspmd"          # gspmd | ep_shardmap (explicit a2a EP)
    kv_cache_quant: bool = False     # int8 KV cache (Shark §3.2 compression)
    attn_impl: str = "blockwise"     # blockwise | flash (Pallas kernel)
    attn_chunk_remat: bool = False   # recompute chunk probs in backward
    attn_seq_shard: bool = False     # context-parallel attention (shard S
                                     # over `model` when heads don't divide)
    seq_parallel_residual: bool = False  # residual stream sharded over S

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND roofline accounting)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            cfg = self.ssm
            di = cfg.d_inner(d)
            nh = cfg.n_heads(d)
            per = d * (2 * di + 2 * cfg.ngroups * cfg.d_state + nh) \
                + di * d + (di + 2 * cfg.ngroups * cfg.d_state) * cfg.d_conv
            return emb + L * per
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.mla is not None:
            m = self.mla
            attn = d * self.n_heads * (m.nope_dim + m.rope_dim) \
                + d * m.kv_lora + d * m.rope_dim \
                + m.kv_lora * self.n_heads * (m.nope_dim + m.v_dim) \
                + self.n_heads * m.v_dim * d
        if self.mlp == "swiglu":
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        if self.family == "moe" and self.moe is not None:
            e = self.moe
            ffn = d * e.num_experts + 3 * d * e.d_expert * e.num_experts \
                + (3 * d * e.d_expert * e.n_shared)
        per = attn + ffn
        total = emb + L * per
        if self.family == "hybrid" and self.ssm is not None:
            cfg = self.ssm
            di = cfg.d_inner(d)
            nh = cfg.n_heads(d)
            mamba_per = d * (2 * di + 2 * cfg.ngroups * cfg.d_state + nh) \
                + di * d
            n_attn_slots = self.n_layers // (self.attn_every or 7)
            n_mamba = self.n_layers - n_attn_slots
            total = emb + n_mamba * (mamba_per + 3 * d * f) + attn  # shared!
        if self.family == "encdec":
            total = emb + (L + self.enc_layers) * per + L * attn  # + cross
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.family != "moe" or self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        e = self.moe
        hd = self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.mla is not None:
            m = self.mla
            attn = d * self.n_heads * (m.nope_dim + m.rope_dim) \
                + d * m.kv_lora + d * m.rope_dim \
                + m.kv_lora * self.n_heads * (m.nope_dim + m.v_dim) \
                + self.n_heads * m.v_dim * d
        ffn_active = 3 * d * e.d_expert * (e.top_k + e.n_shared) \
            + d * e.num_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(emb + L * (attn + ffn_active))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
