"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import DEEPSEEK_V2_LITE

CONFIG = DEEPSEEK_V2_LITE
