"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import PHI3_MEDIUM

CONFIG = PHI3_MEDIUM
