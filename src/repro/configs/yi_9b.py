"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import YI_9B

CONFIG = YI_9B
