"""Architecture configs: one module per assigned arch (--arch <id>).
"""

from .base import MLAConfig, ModelConfig, ShapeConfig, SHAPES
from .registry import ARCH_NAMES, REGISTRY, get_config, smoke_variant

__all__ = ["MLAConfig", "ModelConfig", "ShapeConfig", "SHAPES",
           "ARCH_NAMES", "REGISTRY", "get_config", "smoke_variant"]
