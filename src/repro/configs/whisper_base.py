"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import WHISPER_BASE

CONFIG = WHISPER_BASE
