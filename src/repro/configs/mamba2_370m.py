"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import MAMBA2_370M

CONFIG = MAMBA2_370M
