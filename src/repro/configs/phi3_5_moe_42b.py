"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import PHI35_MOE

CONFIG = PHI35_MOE
