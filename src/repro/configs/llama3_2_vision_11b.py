"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import LLAMA32_VISION

CONFIG = LLAMA32_VISION
