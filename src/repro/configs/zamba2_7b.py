"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import ZAMBA2_7B

CONFIG = ZAMBA2_7B
