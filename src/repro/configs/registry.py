"""All 10 assigned architectures (exact configs from the assignment) plus
reduced smoke variants of each family for CPU tests.

Sources are noted per entry; see DESIGN.md §4 for applicability notes and
the deepseek-v2-lite "160 routed" assignment-text discrepancy.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from ..models.mamba2 import SSMConfig
from ..models.moe import MoEConfig
from .base import MLAConfig, ModelConfig

REGISTRY: Dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


# --- dense -----------------------------------------------------------------

PHI3_MEDIUM = _reg(ModelConfig(
    name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352, norm="rms",
    mlp="swiglu", rope_theta=10000.0))  # [arXiv:2404.14219]

YI_9B = _reg(ModelConfig(
    name="yi-9b", family="dense", n_layers=48, d_model=4096, n_heads=32,
    n_kv_heads=4, d_ff=11008, vocab=64000, norm="rms", mlp="swiglu",
    rope_theta=10000.0))  # [arXiv:2403.04652]

QWEN25_3B = _reg(ModelConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048, n_heads=16,
    n_kv_heads=2, d_ff=11008, vocab=151936, norm="rms", mlp="swiglu",
    qkv_bias=True, tie_embeddings=True,
    rope_theta=1000000.0))  # [hf:Qwen/Qwen2.5-*]

STARCODER2_15B = _reg(ModelConfig(
    name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=4, d_ff=24576, vocab=49152, norm="ln",
    mlp="gelu", qkv_bias=True, rope_theta=100000.0))  # [arXiv:2402.19173]

# --- MoE ---------------------------------------------------------------------

PHI35_MOE = _reg(ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab=32064, norm="rms",
    mlp="swiglu", rope_theta=10000.0,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400)))
# [hf:microsoft/Phi-3.5-MoE-instruct]

DEEPSEEK_V2_LITE = _reg(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400, norm="rms",
    mlp="swiglu", rope_theta=10000.0,
    mla=MLAConfig(kv_lora=512, nope_dim=128, rope_dim=64, v_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  first_dense=True, dense_d_ff=10944)))
# [arXiv:2405.04434] — 64 routed top-6 + 2 shared; see DESIGN.md on the
# assignment text's "160 routed" inconsistency.

# --- SSM ---------------------------------------------------------------------

MAMBA2_370M = _reg(ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024, n_heads=0,
    n_kv_heads=0, d_ff=0, vocab=50280, norm="rms", rope_theta=0.0,
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, ngroups=1, d_conv=4,
                  chunk=256),
    sub_quadratic=True))  # [arXiv:2405.21060]

# --- VLM ---------------------------------------------------------------------

LLAMA32_VISION = _reg(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, norm="rms",
    mlp="swiglu", rope_theta=500000.0, cross_every=5,
    n_frontend_tokens=1601))  # [hf:meta-llama/Llama-3.2-11B-Vision]

# --- hybrid --------------------------------------------------------------------

ZAMBA2_7B = _reg(ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab=32000, norm="rms", mlp="swiglu",
    rope_theta=10000.0, attn_every=7,
    ssm=SSMConfig(d_state=64, expand=2, headdim=112, ngroups=1, d_conv=4,
                  chunk=256),
    sub_quadratic=True))  # [arXiv:2411.15242] 81 slots: 11x(1 shared attn +
# 6 mamba) + 4 mamba; the attention block params are SHARED across slots.

# --- audio enc-dec ---------------------------------------------------------------

WHISPER_BASE = _reg(ModelConfig(
    name="whisper-base", family="encdec", n_layers=6, d_model=512, n_heads=8,
    n_kv_heads=8, d_ff=2048, vocab=51865, norm="ln", mlp="gelu",
    rope_theta=10000.0, enc_layers=6, enc_seq=1500))  # [arXiv:2212.04356]
# conv frontend stubbed: input_specs() provides precomputed frame embeddings.


# --- reduced smoke variants (CPU tests) -------------------------------------------

def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        name=cfg.name + "-smoke", n_layers=2, d_model=64, vocab=256,
        loss_chunks=2, kv_chunk=64)
    if cfg.family in ("dense", "moe", "vlm", "hybrid", "encdec"):
        kw.update(n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads
                                            // max(cfg.n_heads, 1)),
                  d_ff=128, head_dim=16)
    if cfg.family == "moe":
        ne = min(8, cfg.moe.num_experts)
        tk = min(2, cfg.moe.top_k)
        kw.update(moe=dataclasses.replace(
            cfg.moe, d_expert=32, num_experts=ne, top_k=tk, dense_d_ff=64,
            # capacity == worst case so smoke tests are drop-free and the
            # prefill/decode consistency check is exact
            capacity_factor=float(ne) / tk))
    if cfg.mla is not None:
        kw.update(mla=MLAConfig(kv_lora=32, nope_dim=16, rope_dim=8, v_dim=16))
    if cfg.ssm is not None:
        kw.update(ssm=dataclasses.replace(cfg.ssm, d_state=16, headdim=16,
                                          chunk=16))
    if cfg.family == "hybrid":
        kw.update(n_layers=8, attn_every=4)  # 2 groups of (1 attn + 3 mamba)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, enc_seq=32)
    if cfg.family == "vlm":
        kw.update(n_layers=4, cross_every=2, n_frontend_tokens=16)
    return dataclasses.replace(cfg, **kw)


def get_config(name: str) -> ModelConfig:
    if name in REGISTRY:
        return REGISTRY[name]
    if name.endswith("-smoke"):
        return smoke_variant(REGISTRY[name[:-len("-smoke")]])
    raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")


ARCH_NAMES = list(REGISTRY)
