"""Assigned architecture config (see registry.py for the
full definition and source citation)."""

from .registry import STARCODER2_15B

CONFIG = STARCODER2_15B
