"""Dictionary / bit-pack / RLE decode kernels — Pallas TPU.

Shark's columnar compression (§3.2) is a *bandwidth* optimization on TPU:
HBM->VMEM traffic shrinks by the compression ratio, and decode happens in
VMEM right where the consuming scan needs it.  Each kernel streams the
encoded stream tile-by-tile and materializes decoded tiles only in VMEM.

  * dict_decode: codes gather into a (small, fully VMEM-resident) dictionary;
  * bitpack_decode: uint32 words -> per-lane shift/mask unpack (VPU);
  * rle_decode: run values + cumulative ends; each output tile computes its
    run index with a broadcasted compare-and-sum against the (VMEM-resident)
    ends vector — O(tile x runs) VPU ops, no serial scan;
  * fused_decode_scan: dict decode fused directly into the filter+aggregate
    scan — compressed column in, [count,sum,min,max] out, nothing decoded
    ever leaves VMEM (the end-to-end point of the paper's §3.2 + §5 story).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 128


def _dict_decode_kernel(codes_ref, dict_ref, out_ref):
    out_ref[...] = dict_ref[codes_ref[...]]


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def dict_decode(codes: jnp.ndarray, dictionary: jnp.ndarray, *,
                interpret: bool = False, block: int = BLOCK) -> jnp.ndarray:
    n = codes.shape[0]
    d = dictionary.shape[0]
    num_blocks = max(1, -(-n // block))
    padded = num_blocks * block
    c = jnp.zeros((padded,), jnp.int32).at[:n].set(codes.astype(jnp.int32))
    out = pl.pallas_call(
        _dict_decode_kernel,
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), dictionary.dtype),
        interpret=interpret,
    )(c, dictionary)
    return out[:n]


def _bitpack_kernel(words_ref, out_ref, *, bit_width: int, bias: int):
    per_word = 32 // bit_width
    w = words_ref[...]
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * bit_width)
    lanes = (w[:, None] >> shifts[None, :]) & jnp.uint32((1 << bit_width) - 1)
    out_ref[...] = lanes.reshape(-1).astype(jnp.int32) + bias


@functools.partial(jax.jit,
                   static_argnames=("bit_width", "bias", "n", "interpret",
                                    "block_words"))
def bitpack_decode(words: jnp.ndarray, *, bit_width: int, bias: int, n: int,
                   interpret: bool = False,
                   block_words: int = 1024) -> jnp.ndarray:
    per_word = 32 // bit_width
    nw = words.shape[0]
    num_blocks = max(1, -(-nw // block_words))
    padded = num_blocks * block_words
    w = jnp.zeros((padded,), jnp.uint32).at[:nw].set(words)
    out = pl.pallas_call(
        functools.partial(_bitpack_kernel, bit_width=bit_width, bias=bias),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_words,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_words * per_word,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded * per_word,), jnp.int32),
        interpret=interpret,
    )(w)
    return out[:n]


def _rle_kernel(ends_ref, vals_ref, out_ref, *, block: int):
    i = pl.program_id(0)
    pos = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) + i * block
    ends = ends_ref[...]
    # run index of each position: number of run-ends <= pos
    idx = jnp.sum((ends[None, :] <= pos[:, None]).astype(jnp.int32), axis=1)
    idx = jnp.minimum(idx, ends.shape[0] - 1)
    out_ref[...] = vals_ref[idx]


@functools.partial(jax.jit, static_argnames=("n", "interpret", "block"))
def rle_decode(run_values: jnp.ndarray, run_ends: jnp.ndarray, *, n: int,
               interpret: bool = False, block: int = BLOCK) -> jnp.ndarray:
    r = run_values.shape[0]
    num_blocks = max(1, -(-n // block))
    out = pl.pallas_call(
        functools.partial(_rle_kernel, block=block),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((r,), lambda i: (0,)),
                  pl.BlockSpec((r,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((num_blocks * block,),
                                       run_values.dtype),
        interpret=interpret,
    )(run_ends.astype(jnp.int32), run_values)
    return out[:n]


def _fused_decode_scan_kernel(codes_ref, dict_ref, agg_ref, bounds_ref,
                              out_ref):
    dt = out_ref.dtype
    lo = bounds_ref[0]
    hi = bounds_ref[1]
    vals = dict_ref[codes_ref[...]].astype(dt)
    a = agg_ref[...].astype(dt)
    mask = (vals >= lo) & (vals <= hi)
    cnt = jnp.sum(mask.astype(dt))
    s = jnp.sum(jnp.where(mask, a, 0.0))
    mn = jnp.min(jnp.where(mask, a, jnp.inf))
    mx = jnp.max(jnp.where(mask, a, -jnp.inf))
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 128), 1)
    out_ref[...] = jnp.where(lane == 0, cnt,
                             jnp.where(lane == 1, s,
                                       jnp.where(lane == 2, mn,
                                                 jnp.where(lane == 3, mx,
                                                           0.0)))).astype(dt)


@functools.partial(jax.jit, static_argnames=("interpret", "block",
                                             "acc_dtype"))
def fused_decode_scan(codes: jnp.ndarray, dictionary: jnp.ndarray,
                      agg_col: jnp.ndarray, lo, hi, *,
                      interpret: bool = False, block: int = BLOCK,
                      acc_dtype: str = "float32") -> jnp.ndarray:
    """Compressed (dict-coded) filter column + plain aggregate column ->
    [count, sum, min, max]; decode fused into the scan.  `acc_dtype` is
    float32 on TPU; the engine passes float64 in CPU interpret mode to
    match the numpy oracle to rounding."""
    dt = jnp.dtype(acc_dtype)
    n = codes.shape[0]
    d = dictionary.shape[0]
    num_blocks = max(1, -(-n // block))
    padded = num_blocks * block
    # pad codes with a sentinel appended to the dict; NaN fails both bound
    # comparisons, so padding stays excluded even when lo or hi is ±inf
    dict_pad = jnp.concatenate([dictionary.astype(dt),
                                jnp.asarray([jnp.nan], dt)])
    c = jnp.full((padded,), d, jnp.int32).at[:n].set(codes.astype(jnp.int32))
    a = jnp.zeros((padded,), dt).at[:n].set(agg_col.astype(dt))
    bounds = jnp.asarray([lo, hi], dt)
    partials = pl.pallas_call(
        _fused_decode_scan_kernel,
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((d + 1,), lambda i: (0,)),
                  pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, 128), dt),
        interpret=interpret,
    )(c, dict_pad, a, bounds)
    return jnp.stack([jnp.sum(partials[:, 0]), jnp.sum(partials[:, 1]),
                      jnp.min(partials[:, 2]), jnp.max(partials[:, 3])])
