"""Top-k similarity search — Pallas TPU kernel (DESIGN.md §15.3).

The vector-analytics hot path: score n candidate embeddings against one
query vector (dot product) and keep the k best.  The naive form
materializes all n scores to HBM and sorts; this kernel streams candidate
row-tiles HBM->VMEM, computes the (tile x query) dot product on the MXU,
and merges each tile's scores into a running top-k that lives in the
revisited output block for the whole sweep — HBM traffic is one read of
the candidate matrix and one (1, k_pad) result write.

The running merge is rank-selection, not a sort: for the concatenation of
the carried top-k and the tile's scores, element i's rank is the count of
elements that beat it — score strictly greater, or equal score with a
smaller candidate index.  (score, index) pairs are unique, so ranks are a
permutation and a one-hot rank->slot matmul scatters the k best into
slot order.  That tie-break (equal scores keep the smaller row index) is
exactly numpy's stable `argsort(-scores)[:k]`, asserted by the
tests/test_kernels_topk.py parity suite, including k > rows edges.

One caveat on ties: the kernel orders by ITS dot products, whose rounding
can differ from a host-computed score by reduction order (padded MXU
matmul vs BLAS).  Ties in the mathematical score are therefore only
guaranteed to resolve identically when the products are exact (e.g.
integer-valued lanes, the parity tests' tie cases); for continuous data
distinct scores never sit within a reduction-order ulp of each other in
practice, so orderings agree.

Tiling follows colscan/flash_attention: a 1-D grid over row tiles with the
minor dimension padded to 128 lanes; the merge state carries across grid
steps through the constant-index output block (sequential TPU grids
revisit it without flushing).  `acc_dtype` is float32 on TPU and float64
in interpret mode so CPU parity with the float64 numpy oracle holds to
rounding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024       # candidate rows per grid step (8x128 VPU tiles)
LANES = 128

NEG_INF = -jnp.inf


def _topk_kernel(x_ref, q_ref, out_s_ref, out_i_ref, *, n: int,
                 block_rows: int, k_pad: int, num_blocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def init():
        # empty slots: -inf scores with unique indices beyond every real
        # row, so the total order (score desc, index asc) stays strict
        out_s_ref[...] = jnp.full((1, k_pad), NEG_INF, out_s_ref.dtype)
        out_i_ref[...] = (num_blocks * block_rows
                          + jax.lax.broadcasted_iota(jnp.int32, (1, k_pad),
                                                     1))

    x = x_ref[...]                                     # (B, d_pad)
    qv = q_ref[...]                                    # (d_pad, 1)
    s_tile = (x @ qv).T                                # (1, B) MXU dot
    gi = (i * block_rows
          + jax.lax.broadcasted_iota(jnp.int32, (1, block_rows),
                                     1)).astype(jnp.int32)
    s_tile = jnp.where(gi < n, s_tile, NEG_INF)        # mask padding rows

    cs = jnp.concatenate([out_s_ref[...], s_tile], axis=1)   # (1, M)
    ci = jnp.concatenate([out_i_ref[...], gi], axis=1)       # (1, M)
    # rank[i] = |{j : s_j > s_i or (s_j == s_i and idx_j < idx_i)}| —
    # carried entries precede the tile in ci order, so equal scores resolve
    # to the smaller global index exactly like the stable host argsort
    beats = (cs > cs.T) | ((cs == cs.T) & (ci < ci.T))       # (M, M)
    rank = jnp.sum(beats.astype(jnp.int32), axis=1, keepdims=True)  # (M, 1)
    slot = jax.lax.broadcasted_iota(jnp.int32, rank.shape[:1] + (k_pad,), 1)
    sel = rank == slot                                       # (M, k_pad)
    out_s_ref[...] = jnp.sum(jnp.where(sel, cs.T, 0.0), axis=0,
                             keepdims=True)
    out_i_ref[...] = jnp.sum(jnp.where(sel, ci.T, 0), axis=0, keepdims=True,
                             dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "block_rows",
                                             "acc_dtype"))
def topk_similarity(x: jnp.ndarray, q: jnp.ndarray, k: int, *,
                    interpret: bool = False, block_rows: int = BLOCK_ROWS,
                    acc_dtype: str = "float32"):
    """(scores, row indices) of the min(k, n) candidates in `x` (n x d)
    most similar to `q` (d,) by dot product, scores descending, ties by
    ascending row index.  Rows and lanes are zero-padded to whole tiles;
    padding rows are masked to -inf inside the kernel so they never win a
    slot while real rows remain."""
    dt = jnp.dtype(acc_dtype)
    n, d = x.shape
    m = min(int(k), n)
    d_pad = max(LANES, -(-d // LANES) * LANES)
    num_blocks = max(1, -(-n // block_rows))
    padded = num_blocks * block_rows
    k_pad = max(LANES, -(-max(m, 1) // LANES) * LANES)
    xp = jnp.zeros((padded, d_pad), dt).at[:n, :d].set(x.astype(dt))
    qp = jnp.zeros((d_pad, 1), dt).at[:d, 0].set(q.astype(dt))

    out_s, out_i = pl.pallas_call(
        functools.partial(_topk_kernel, n=n, block_rows=block_rows,
                          k_pad=k_pad, num_blocks=num_blocks),
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k_pad), dt),
            jax.ShapeDtypeStruct((1, k_pad), jnp.int32),
        ],
        interpret=interpret,
    )(xp, qp)
    return out_s[0, :m], out_i[0, :m]
