"""Map-side shuffle bucketing (radix partition) — Pallas TPU kernel.

The map half of the memory-based shuffle (paper §5) assigns every row to a
reduce bucket: hash-mix the (pre-folded) key, take it modulo the bucket
count, and histogram the buckets so the scheduler knows each bucket's size
without a second pass.  Host numpy does this with three full-column passes;
the kernel fuses mix + modulo + histogram into one HBM->VMEM stream: the
VPU computes bucket ids for a row tile while the MXU one-hot-matmuls the
same tile into per-tile bucket counts.

TPU has no 64-bit integer lanes, so keys are folded to uint32 host-side
(`fold_keys_u32`: xor of the int64 halves — value-deterministic, which is
all a partitioner needs) and mixed with the 32-bit golden-ratio constant.
The bucket assignment therefore differs from the host partitioner's 64-bit
mix — that is fine: any deterministic assignment is a correct shuffle
partition, and both sides of one shuffle always use the same partitioner
(`shuffle.bucket_by_hash(..., kernel=...)` fixes the route per shuffle, not
per task, so equal keys land in equal buckets everywhere).

Buckets pad to a multiple of 128 for MXU alignment; padding rows take an
out-of-range bucket id so they vanish from the histogram.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024

_GOLDEN32 = np.uint32(2654435761)       # 2^32 / phi, Knuth's constant


def fold_keys_u32(keys: np.ndarray) -> np.ndarray:
    """Host-side fold of int64 key hashes into uint32 lanes the kernel can
    mix: xor of the two 32-bit halves (value-deterministic)."""
    k = np.asarray(keys).astype(np.int64, copy=False).view(np.uint64)
    return ((k ^ (k >> np.uint64(32))) & np.uint64(0xFFFFFFFF)).astype(
        np.uint32)


def mix_u32(h):
    """The radix-partition hash mix, on pre-folded uint32 lanes.  Written in
    ops numpy and jnp share, so the host stride mirror of the cross-device
    exchange (cluster/shard_exec.py) computes bit-identical bucket ids to
    the compiled programs — one hash, three executors (host numpy, shard_map
    XLA, Pallas kernel)."""
    h = h * _GOLDEN32                                   # uint32 wrap-around
    h = h ^ (h >> np.uint32(15))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    return h


def _bucket_ids(keys_ref, *, num_buckets: int, num_buckets_padded: int,
                valid_rows: int, block: int, prog_id):
    b = (mix_u32(keys_ref[...]) % jnp.uint32(num_buckets)).astype(jnp.int32)
    # padding rows -> out-of-range bucket: excluded from the histogram and
    # sliced off the per-row ids by the wrapper
    pos = jax.lax.broadcasted_iota(jnp.int32, (block,), 0) + prog_id * block
    return jnp.where(pos < valid_rows, b, num_buckets_padded)


def _radix_kernel(keys_ref, bucket_ref, counts_ref, *, num_buckets: int,
                  num_buckets_padded: int, valid_rows: int):
    block = keys_ref.shape[0]
    b = _bucket_ids(keys_ref, num_buckets=num_buckets,
                    num_buckets_padded=num_buckets_padded,
                    valid_rows=valid_rows, block=block,
                    prog_id=pl.program_id(0))
    bucket_ref[...] = b
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, num_buckets_padded), 1)
    onehot = (b[:, None] == lanes).astype(counts_ref.dtype)
    ones = jnp.ones((1, block), counts_ref.dtype)
    counts_ref[...] = (ones @ onehot)[None]             # MXU: (1, 1, Bp)


def _radix_ids_kernel(keys_ref, bucket_ref, *, num_buckets: int,
                      num_buckets_padded: int, valid_rows: int):
    bucket_ref[...] = _bucket_ids(
        keys_ref, num_buckets=num_buckets,
        num_buckets_padded=num_buckets_padded, valid_rows=valid_rows,
        block=keys_ref.shape[0], prog_id=pl.program_id(0))


@functools.partial(jax.jit, static_argnames=("num_buckets", "interpret",
                                             "block_rows", "with_counts"))
def radix_partition(keys_u32: jnp.ndarray, *, num_buckets: int,
                    interpret: bool = False,
                    block_rows: int = BLOCK_ROWS,
                    with_counts: bool = True):
    """Returns (bucket_ids[int32, n], counts[int32, num_buckets]) for the
    folded uint32 key hashes; `with_counts=False` skips the histogram
    matmul and returns (bucket_ids, None) — the shuffle partitioner path,
    whose caller only consumes the ids (per-bucket sizes come from the
    materialized pieces via SizeAccumulator)."""
    n = keys_u32.shape[0]
    bp = max(128, -(-num_buckets // 128) * 128)
    num_blocks = max(1, -(-n // block_rows))
    padded = num_blocks * block_rows
    k = jnp.zeros((padded,), jnp.uint32).at[:n].set(keys_u32)
    if not with_counts:
        buckets = pl.pallas_call(
            functools.partial(_radix_ids_kernel, num_buckets=num_buckets,
                              num_buckets_padded=bp, valid_rows=n),
            grid=(num_blocks,),
            in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,))],
            out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((padded,), jnp.int32),
            interpret=interpret,
        )(k)
        return buckets[:n], None
    buckets, counts = pl.pallas_call(
        functools.partial(_radix_kernel, num_buckets=num_buckets,
                          num_buckets_padded=bp, valid_rows=n),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block_rows,), lambda i: (i,)),
                   pl.BlockSpec((1, 1, bp), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((padded,), jnp.int32),
                   jax.ShapeDtypeStruct((num_blocks, 1, bp), jnp.float32)],
        interpret=interpret,
    )(k)
    # per-tile partials are exact small floats (<= block_rows); cast to
    # int32 BEFORE the cross-block sum so totals stay exact past the
    # float32 2^24 integer limit on huge skewed buckets
    total = jnp.sum(counts[:, 0, :num_buckets].astype(jnp.int32), axis=0)
    return buckets[:n], total


def radix_partition_ref(keys_u32: np.ndarray, num_buckets: int):
    """Numpy oracle for the kernel's hash-mix and histogram."""
    k = np.asarray(keys_u32, np.uint32)
    h = (k * _GOLDEN32).astype(np.uint32)
    h = h ^ (h >> np.uint32(15))
    h = (h * np.uint32(0x85EBCA6B)).astype(np.uint32)
    h = h ^ (h >> np.uint32(13))
    b = (h % np.uint32(num_buckets)).astype(np.int32)
    return b, np.bincount(b, minlength=num_buckets).astype(np.int32)
