"""Flash attention — Pallas TPU kernel (forward).

The §Perf cell-A iteration drove attention HBM traffic down to the XLA
floor: per-KV-chunk score/prob tiles still materialize at dot boundaries
(EXPERIMENTS.md §Perf A5).  This kernel is the final step on real TPU:
the (block_q x block_k) score tile, its online-softmax statistics and the
output accumulator live in VMEM scratch for the whole KV sweep — HBM
traffic is exactly Q, K, V reads and O writes.

Grid: (batch*heads, S/block_q, T/block_k), KV innermost (TPU grids are
sequential minor-to-major, so VMEM scratch carries across the KV sweep).
Causal blocks strictly above the diagonal are skipped via pl.when.

`models/flash.py` (the custom_vjp XLA form) is the oracle; on-TPU dispatch
would swap it for this kernel via kernels.ops.  Validated in interpret mode
(tests/test_kernels_flash.py) over shape/dtype sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      block_q: int, block_k: int, causal: bool,
                      n_kv_blocks: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # block row range [qi*bq, qi*bq+bq); col range [ki*bk, ...): skip
        # blocks entirely above the diagonal
        run = ki * block_k <= qi * block_q + block_q - 1

    @pl.when(run if causal else True)
    def body():
        q = q_ref[0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0].astype(jnp.float32)               # (bk, hd)
        s = q @ k.T                                    # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, hd); k, v: (B, H, T, hd) (MHA layout; GQA callers repeat
    or group KV heads).  Returns (B, H, S, hd)."""
    b, h, s, hd = q.shape
    t = k.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    bh = b * h
    qf = q.reshape(bh, s, hd)
    kf = k.reshape(bh, t, hd)
    vf = v.reshape(bh, t, hd)
    n_kv_blocks = t // block_k
    scale = float(1.0 / (hd ** 0.5))

    out = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, block_q=block_q,
                          block_k=block_k, causal=causal,
                          n_kv_blocks=n_kv_blocks, scale=scale),
        grid=(bh, s // block_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bhi, qi, ki: (bhi, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bhi, qi, ki: (bhi, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bhi, qi, ki: (bhi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bhi, qi, ki: (bhi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),   # running max m
            _vmem((block_q, 1), jnp.float32),   # running denom l
            _vmem((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
