"""Segmented reduce-side merge — Pallas TPU kernel (DESIGN.md §11).

The reduce half of an aggregation merges partial states by group: per group
[sum, count, min, max] of one state column.  Shark's reducers do this with
JVM hash tables; per-row scatter is serial poison on TPU vector units, so
the TPU-native form mirrors `groupby_mxu`: each grid step builds a one-hot
tile of the (pre-grouped, host-side `np.unique`) group ids in VMEM, reduces
sum/count on the MXU (one-hot matmul) and min/max on the VPU (masked
tile-wide reductions), emitting per-tile (4, G) partials the wrapper folds
with a tiny final sum/min/max.

Groups are padded to a multiple of 128 so the matmul is MXU-aligned; rows
pad with an out-of-range group id so padding contributes nothing.  Like the
other engine kernels, `acc_dtype` is float32 on TPU and float64 in CPU
interpret mode, where the engine requires parity with the numpy oracle to
rounding; integer states stay on the jitted int64 segmented reduce
(aggregate.CompiledMerge) — float accumulation would round them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024


def _segmerge_kernel(codes_ref, vals_ref, out_ref, *,
                     num_groups_padded: int):
    dt = out_ref.dtype
    codes = codes_ref[...]
    vals = vals_ref[...].astype(dt)
    groups = jax.lax.broadcasted_iota(jnp.int32, (1, num_groups_padded), 1)
    onehot = codes[:, None] == groups                       # (B, Gp) bool
    oh = onehot.astype(dt)
    stacked = jnp.stack([vals, jnp.ones_like(vals)], axis=0)  # (2, B)
    sc = stacked @ oh                                       # MXU: (2, Gp)
    mn = jnp.min(jnp.where(onehot, vals[:, None], jnp.inf), axis=0)
    mx = jnp.max(jnp.where(onehot, vals[:, None], -jnp.inf), axis=0)
    out_ref[...] = jnp.concatenate(
        [sc, mn[None, :], mx[None, :]], axis=0)[None]       # (1, 4, Gp)


@functools.partial(jax.jit, static_argnames=("num_groups", "interpret",
                                             "block_rows", "acc_dtype"))
def segmented_merge(codes: jnp.ndarray, values: jnp.ndarray, *,
                    num_groups: int, interpret: bool = False,
                    block_rows: int = BLOCK_ROWS,
                    acc_dtype: str = "float32") -> jnp.ndarray:
    """Returns (num_groups, 4): per-group [sum, count, min, max] of
    `values` segmented by `codes` (0 <= code < num_groups).  Empty groups
    report count 0 and the ±inf min/max identities."""
    dt = jnp.dtype(acc_dtype)
    n = codes.shape[0]
    gp = max(128, -(-num_groups // 128) * 128)
    num_blocks = max(1, -(-n // block_rows))
    padded = num_blocks * block_rows
    # pad codes to an out-of-range group so padding contributes nothing
    c = jnp.full((padded,), gp, jnp.int32).at[:n].set(codes.astype(jnp.int32))
    v = jnp.zeros((padded,), dt).at[:n].set(values.astype(dt))
    partials = pl.pallas_call(
        functools.partial(_segmerge_kernel, num_groups_padded=gp),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,)),
                  pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 4, gp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, 4, gp), dt),
        interpret=interpret,
    )(c, v)
    sums = jnp.sum(partials[:, 0, :num_groups], axis=0)
    cnts = jnp.sum(partials[:, 1, :num_groups], axis=0)
    mns = jnp.min(partials[:, 2, :num_groups], axis=0)
    mxs = jnp.max(partials[:, 3, :num_groups], axis=0)
    return jnp.stack([sums, cnts, mns, mxs], axis=1)       # (G, 4)
