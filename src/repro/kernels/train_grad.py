"""Batch-gradient training step — Pallas TPU kernel (DESIGN.md §15.2).

One full-batch gradient for the in-engine estimators: logistic regression
(`sigmoid(x @ w) - y` residuals) or linear regression (`x @ w - y`).  The
PDE routes large feature partitions here (`decide_train_backend` ->
"train_grad"); smaller ones take the fused-jit or numpy-oracle routes,
all three producing the same gradient to rounding.

Tiling is the colscan partial-accumulator idiom: a 1-D grid over row
tiles, each grid step computing its tile's contribution
`residual.T @ x_tile` (one MXU matmul, (1, d_pad)) into a per-tile row of
the partials output; the wrapper sums partials on the host side of the
jit.  Zero-padding is self-masking: a padded row has x == 0, and the
gradient weighs each residual by that zero feature row, so padded rows
contribute exactly nothing — no validity mask needed (the nonzero
logistic residual sigmoid(0) - 0 at padded rows is multiplied away).

`acc_dtype` follows the repo convention: float32 on TPU MXU, float64 in
interpret mode so the differential tests against the numpy oracle are
bit-stable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024
LANES = 128


def _grad_kernel(x_ref, y_ref, w_ref, out_ref, *, kind: str):
    x = x_ref[...]                     # (B, d_pad)
    y = y_ref[...]                     # (B, 1)
    w = w_ref[...]                     # (d_pad, 1)
    z = x @ w                          # (B, 1) MXU
    if kind == "logistic":
        r = jax.nn.sigmoid(z) - y
    else:                              # "linear"
        r = z - y
    out_ref[...] = r.T @ x             # (1, d_pad) MXU


@functools.partial(jax.jit, static_argnames=("kind", "interpret",
                                             "block_rows", "acc_dtype"))
def train_grad(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
               kind: str = "logistic", *, interpret: bool = False,
               block_rows: int = BLOCK_ROWS, acc_dtype: str = "float32"):
    """Sum-of-residuals gradient `x.T @ (pred(x @ w) - y)` as a (d,)
    vector, streamed over row tiles.  Callers divide by their row count
    (the kernel returns the unnormalized sum so per-partition partials
    from different splits can be added before normalizing)."""
    if kind not in ("logistic", "linear"):
        raise ValueError(f"train_grad: unknown kind {kind!r}")
    dt = jnp.dtype(acc_dtype)
    n, d = x.shape
    d_pad = max(LANES, -(-d // LANES) * LANES)
    num_blocks = max(1, -(-n // block_rows))
    padded = num_blocks * block_rows
    xp = jnp.zeros((padded, d_pad), dt).at[:n, :d].set(x.astype(dt))
    yp = jnp.zeros((padded, 1), dt).at[:n, 0].set(y.astype(dt))
    wp = jnp.zeros((d_pad, 1), dt).at[:d, 0].set(w.astype(dt))

    partials = pl.pallas_call(
        functools.partial(_grad_kernel, kind=kind),
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, d_pad), dt),
        interpret=interpret,
    )(xp, yp, wp)
    return jnp.sum(partials, axis=0)[:d]
