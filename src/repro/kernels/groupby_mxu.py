"""Group-by aggregation as a one-hot matmul — Pallas TPU kernel.

Hardware adaptation (DESIGN.md §2): Shark's reducers aggregate with JVM hash
tables; per-row scatter is serial poison on a TPU's vector units.  For the
low-cardinality keys that dominate warehouse group-bys (SHIPMODE: 7 groups,
country: ~200 — see §6.3.1/§6.4), the TPU-native algorithm is:

    one_hot(codes) @ values  -> per-group sums      (MXU, 128x128 systolic)
    one_hot(codes) @ ones    -> per-group counts

Each grid step builds the one-hot tile for BLOCK_ROWS rows in VMEM and issues
two fused matmuls; partial (G,2) results land per-tile and the wrapper does
the final (num_blocks, G, 2) -> (G, 2) sum.  G is padded to a multiple of 128
so the matmul is MXU-aligned.  High-cardinality group-bys stay on the
sort/segment-sum engine path (aggregate.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024


def _groupby_kernel(codes_ref, vals_ref, out_ref, *, num_groups_padded: int):
    dt = out_ref.dtype
    codes = codes_ref[...]
    vals = vals_ref[...].astype(dt)
    groups = jax.lax.broadcasted_iota(jnp.int32, (1, num_groups_padded), 1)
    onehot = (codes[:, None] == groups).astype(dt)  # (B, Gp)
    stacked = jnp.stack([vals, jnp.ones_like(vals)], axis=0)  # (2, B)
    out_ref[...] = (stacked @ onehot)[None]  # (1, 2, Gp) on the MXU


@functools.partial(jax.jit, static_argnames=("num_groups", "interpret",
                                             "block_rows", "acc_dtype"))
def groupby_sum(codes: jnp.ndarray, values: jnp.ndarray, *, num_groups: int,
                interpret: bool = False,
                block_rows: int = BLOCK_ROWS,
                acc_dtype: str = "float32") -> jnp.ndarray:
    """Returns (num_groups, 2): per-group [sum, count].  `acc_dtype` is
    float32 on TPU (MXU-native); the engine passes float64 in interpret
    mode on CPU to match the numpy oracle to rounding."""
    dt = jnp.dtype(acc_dtype)
    n = codes.shape[0]
    gp = max(128, -(-num_groups // 128) * 128)
    num_blocks = max(1, -(-n // block_rows))
    padded = num_blocks * block_rows
    # pad codes to an out-of-range group so padding contributes nothing
    c = jnp.full((padded,), gp, jnp.int32).at[:n].set(codes.astype(jnp.int32))
    v = jnp.zeros((padded,), dt).at[:n].set(values.astype(dt))
    partials = pl.pallas_call(
        functools.partial(_groupby_kernel, num_groups_padded=gp),
        grid=(num_blocks,),
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,)),
                  pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 2, gp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, 2, gp), dt),
        interpret=interpret,
    )(c, v)
    summed = jnp.sum(partials, axis=0)  # (2, gp)
    return summed[:, :num_groups].T     # (G, 2) [sum, count]
