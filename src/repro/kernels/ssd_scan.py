"""Mamba2 SSD scan — Pallas TPU kernel (forward).

EXPERIMENTS.md §Perf identifies the SSD chunked scan as the bounding traffic
for the SSM-dominated archs (zamba2-7b, mamba2-370m): the XLA form
materializes the (chunk x chunk) decay/score matrices and the per-chunk
state contributions in HBM.  This kernel runs the whole per-(batch, head)
scan in one grid row: the (c x c) intra-chunk tile, the decay vectors and
the running (p x n) state all live in VMEM scratch; HBM traffic is exactly
x/dt/B/C reads and y writes.

Grid: (batch*heads, n_chunks) — chunk index innermost, so the state scratch
carries the recurrence across the sequential sweep (same pattern as the
flash kernel's KV sweep).  ngroups=1 layout (B/C shared across heads), the
configuration of both assigned SSM archs.

Math per chunk (c = chunk length, p = headdim, n = d_state):
    dA       = dt * A                  (c,)  A < 0
    cum      = cumsum(dA)              (c,)
    L[i, j]  = exp(cum_i - cum_j) * (i >= j)
    y_intra  = ((C B^T) ∘ L ∘ dt_j) x            -- (c,c) @ (c,p) on MXU
    y_inter  = exp(cum) * (C . state)            -- (c,n) @ (n,p)
    state'   = exp(cum_last) * state + B^T (exp(cum_last - cum) dt x)
Oracle: `repro.models.mamba2.ssd_chunked` (pure jnp), itself validated
against the sequential recurrence in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (c, p)
    dt = dt_ref[0].astype(jnp.float32)        # (c,)
    a = a_ref[0, 0]                           # scalar A (negative)
    bmat = b_ref[0].astype(jnp.float32)       # (c, n)
    cmat = c_ref[0].astype(jnp.float32)       # (c, n)

    da = dt * a                               # (c,)
    cum = jnp.cumsum(da)                      # (c,)
    # intra-chunk: masked decay kernel
    seg = cum[:, None] - cum[None, :]         # (c, c)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mask = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = cmat @ bmat.T                        # (c, c) MXU
    m = cb * l_mask * dt[None, :]
    y = m @ x                                 # (c, p) MXU

    # inter-chunk from carried state
    state = state_scr[...]                    # (n, p)
    decay_in = jnp.exp(cum)[:, None]          # (c, 1)
    y = y + decay_in * (cmat @ state)         # (c,n)@(n,p) MXU

    # state update
    last = cum[chunk - 1]
    w = jnp.exp(last - cum) * dt              # (c,)
    contrib = bmat.T @ (w[:, None] * x)       # (n, p) MXU
    state_scr[...] = jnp.exp(last) * state + contrib

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, chunk: int = 128,
             interpret: bool = False) -> jnp.ndarray:
    """x: (B, S, H, P); dt: (B, S, H) post-softplus; a: (H,) negative;
    b, c: (B, S, N) (ngroups=1).  Returns y = SSD(x) WITHOUT the D skip
    (callers add x*D).  S must divide by `chunk`."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    bh = bsz * h
    # per-(batch, head) layout
    xf = x.transpose(0, 2, 1, 3).reshape(bh, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bh, s)
    af = jnp.broadcast_to(a[None, :], (bsz, h)).reshape(bh, 1)
    bf = jnp.broadcast_to(b[:, None], (bsz, h, s, n)).reshape(bh, s, n)
    cf = jnp.broadcast_to(c[:, None], (bsz, h, s, n)).reshape(bh, s, n)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, chunk), lambda i, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, k: (i, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, k: (i, k, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[_vmem((n, p), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)
    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
