"""Jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True unless a real TPU backend is present: this
container is CPU-only, so kernels execute their bodies in interpret mode
(semantics validated against ref.py); on TPU the same calls compile to
Mosaic.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from . import colscan as _colscan
from . import dictdecode as _dd
from . import groupby_mxu as _gb
from . import radix_partition as _rp
from . import segmented_merge as _sm
from . import topk_similarity as _tk
from . import train_grad as _tg


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _interp() -> bool:
    return not on_tpu()


def _acc_ctx(acc_dtype: str):
    """x64 scope for float64 accumulation (CPU interpret parity runs);
    the engine's other kernel call sites wrap in expr._x64() themselves —
    the analytics wrappers below self-wrap so stage/trainer stay simple."""
    return enable_x64() if acc_dtype == "float64" else contextlib.nullcontext()


def colscan(filter_col, agg_col, lo, hi, acc_dtype: str = "float32"):
    """[count, sum, min, max] of agg_col where lo <= filter_col <= hi."""
    return _colscan.colscan(jnp.asarray(filter_col), jnp.asarray(agg_col),
                            lo, hi, interpret=_interp(),
                            acc_dtype=acc_dtype)


def dict_decode(codes, dictionary):
    return _dd.dict_decode(jnp.asarray(codes), jnp.asarray(dictionary),
                           interpret=_interp())


def bitpack_decode(words, bit_width: int, bias: int, n: int):
    return _dd.bitpack_decode(jnp.asarray(words), bit_width=bit_width,
                              bias=bias, n=n, interpret=_interp())


def rle_decode(run_values, run_ends, n: int):
    return _dd.rle_decode(jnp.asarray(run_values), jnp.asarray(run_ends),
                          n=n, interpret=_interp())


def fused_decode_scan(codes, dictionary, agg_col, lo, hi,
                      acc_dtype: str = "float32"):
    return _dd.fused_decode_scan(jnp.asarray(codes), jnp.asarray(dictionary),
                                 jnp.asarray(agg_col), lo, hi,
                                 interpret=_interp(), acc_dtype=acc_dtype)


def groupby_sum(codes, values, num_groups: int, acc_dtype: str = "float32"):
    """(num_groups, 2) per-group [sum, count] via MXU one-hot matmul."""
    return _gb.groupby_sum(jnp.asarray(codes), jnp.asarray(values),
                           num_groups=num_groups, interpret=_interp(),
                           acc_dtype=acc_dtype)


def segmented_merge(codes, values, num_groups: int,
                    acc_dtype: str = "float32"):
    """(num_groups, 4) per-group [sum, count, min, max] — the reduce-side
    merge of one aggregate state column (DESIGN.md §11)."""
    return _sm.segmented_merge(jnp.asarray(codes), jnp.asarray(values),
                               num_groups=num_groups, interpret=_interp(),
                               acc_dtype=acc_dtype)


# -- double-buffered kernel dispatch (DESIGN.md §14) --------------------
#
# JAX dispatch is asynchronous: a jit/Pallas call returns a tracer-backed
# array before the device work completes, and only np.asarray() blocks.
# double_buffer_map exploits that to overlap chunk i+1's dispatch (which
# includes host-side decode/staging of its inputs) with chunk i's compute:
# exactly one launch is kept in flight while the previous result drains.
# DOUBLE_BUFFER.dispatches counts launches so tests can assert the
# chunked path actually ran.

DOUBLE_BUFFER = {"chunk_rows": 131072, "dispatches": 0}


def double_buffer_map(fn, chunks):
    """Map `fn` over `chunks`, keeping one dispatch in flight.

    `fn(chunk)` must return a JAX array (or tuple of them); results are
    materialized to numpy in order.  With one chunk this degenerates to a
    plain call — same arithmetic, same rounding class."""
    out = []
    inflight = None
    for chunk in chunks:
        nxt = fn(chunk)              # async dispatch: returns immediately
        DOUBLE_BUFFER["dispatches"] += 1
        if inflight is not None:
            out.append(jax.tree_util.tree_map(np.asarray, inflight))
        inflight = nxt
    if inflight is not None:
        out.append(jax.tree_util.tree_map(np.asarray, inflight))
    return out


def topk_similarity(x, q, k: int, acc_dtype: str = None):
    """(scores, row indices) of the top-k dot-product matches of query `q`
    in candidate matrix `x` — scores descending, ties by ascending row
    index, matching `np.argsort(-scores, kind="stable")[:k]` exactly
    (DESIGN.md §15.3).  Returns numpy arrays of length min(k, rows)."""
    if acc_dtype is None:
        acc_dtype = "float32" if on_tpu() else "float64"
    with _acc_ctx(acc_dtype):
        s, i = _tk.topk_similarity(jnp.asarray(x), jnp.asarray(q), int(k),
                                   interpret=_interp(), acc_dtype=acc_dtype)
        return np.asarray(s), np.asarray(i)


def train_grad(x, y, w, kind: str = "logistic", acc_dtype: str = None):
    """Unnormalized batch gradient `x.T @ (pred(x @ w) - y)` as a numpy
    (d,) vector — the Pallas route of `pde.decide_train_backend`."""
    if acc_dtype is None:
        acc_dtype = "float32" if on_tpu() else "float64"
    with _acc_ctx(acc_dtype):
        return np.asarray(_tg.train_grad(jnp.asarray(x), jnp.asarray(y),
                                         jnp.asarray(w), kind,
                                         interpret=_interp(),
                                         acc_dtype=acc_dtype))


def radix_partition(keys_u32, num_buckets: int, with_counts: bool = True):
    """(bucket_ids, per-bucket counts) for folded uint32 key hashes — the
    map side of the memory-based shuffle as one fused pass.
    `with_counts=False` skips the histogram matmul (ids-only callers)."""
    return _rp.radix_partition(jnp.asarray(keys_u32),
                               num_buckets=num_buckets,
                               interpret=_interp(),
                               with_counts=with_counts)
