"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests sweep shapes/dtypes and assert_allclose kernel-vs-ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def colscan_ref(filter_col: jnp.ndarray, agg_col: jnp.ndarray,
                lo: float, hi: float) -> jnp.ndarray:
    """Fused filter+aggregate scan: rows where lo <= filter_col <= hi
    contribute to [count, sum, min, max] of agg_col."""
    mask = (filter_col >= lo) & (filter_col <= hi)
    cnt = jnp.sum(mask.astype(jnp.float32))
    s = jnp.sum(jnp.where(mask, agg_col, 0.0).astype(jnp.float32))
    mn = jnp.min(jnp.where(mask, agg_col, jnp.inf).astype(jnp.float32))
    mx = jnp.max(jnp.where(mask, agg_col, -jnp.inf).astype(jnp.float32))
    return jnp.stack([cnt, s, mn, mx])


def dict_decode_ref(codes: jnp.ndarray, dictionary: jnp.ndarray) -> jnp.ndarray:
    return dictionary[codes]


def rle_decode_ref(run_values: jnp.ndarray, run_ends: jnp.ndarray,
                   n: int) -> jnp.ndarray:
    """run_ends are *cumulative* (exclusive) end positions; output length n."""
    pos = jnp.arange(n)
    idx = jnp.searchsorted(run_ends, pos, side="right")
    return run_values[idx]


def bitpack_decode_ref(words: jnp.ndarray, bit_width: int, bias: int,
                       n: int) -> jnp.ndarray:
    per_word = 32 // bit_width
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * bit_width)
    lanes = (words[:, None] >> shifts[None, :]) \
        & jnp.uint32((1 << bit_width) - 1)
    return (lanes.reshape(-1)[:n].astype(jnp.int32) + bias)


def groupby_sum_ref(codes: jnp.ndarray, values: jnp.ndarray,
                    num_groups: int) -> jnp.ndarray:
    """Per-group [sum, count]: the MXU one-hot matmul group-by oracle."""
    onehot = jax.nn.one_hot(codes, num_groups, dtype=jnp.float32)
    sums = onehot.T @ values.astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return jnp.stack([sums, counts], axis=1)  # (G, 2)


def fused_decode_scan_ref(codes: jnp.ndarray, dictionary: jnp.ndarray,
                          agg_col: jnp.ndarray, lo: float, hi: float
                          ) -> jnp.ndarray:
    """Dictionary-decode fused with filter+aggregate: the TPU analogue of
    Shark eliminating the deserialization bottleneck (decode never leaves
    VMEM)."""
    vals = dictionary[codes]
    return colscan_ref(vals, agg_col, lo, hi)
