"""Fused filter+aggregate columnar scan — Pallas TPU kernel.

The paper's hot path (§6.2.1–6.2.2): scan a cached column, apply a range
predicate, aggregate a second column.  Hive burns CPU deserializing rows and
interpreting expression evaluators; Shark's columnar store + compiled
evaluators fix that on the JVM.  The TPU-native form goes further: the
filter, select and aggregate are ONE kernel — each grid step streams a
row-tile of both columns HBM->VMEM, evaluates the predicate on the VPU, and
reduces to per-tile [count, sum, min, max] partials, so filtered data never
round-trips to HBM.

Tiling: rows are processed in (BLOCK_ROWS,) tiles; BLOCK_ROWS is a multiple
of 8*128 so the VPU lanes stay full.  Each tile emits one 128-lane partial
row (lanes 0..3 used); the jit wrapper does the tiny final reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8 * 128  # one full VPU tile of f32 per grid step

LANES = 128  # partial-result row width (TPU lane count)


def _colscan_kernel(filt_ref, agg_ref, bounds_ref, out_ref):
    """One grid step: reduce a row tile to [count, sum, min, max] lanes."""
    dt = out_ref.dtype
    lo = bounds_ref[0]
    hi = bounds_ref[1]
    f = filt_ref[...]
    a = agg_ref[...].astype(dt)
    mask = (f >= lo) & (f <= hi)
    cnt = jnp.sum(mask.astype(dt))
    s = jnp.sum(jnp.where(mask, a, 0.0))
    mn = jnp.min(jnp.where(mask, a, jnp.inf))
    mx = jnp.max(jnp.where(mask, a, -jnp.inf))
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    row = jnp.where(lane == 0, cnt,
                    jnp.where(lane == 1, s,
                              jnp.where(lane == 2, mn,
                                        jnp.where(lane == 3, mx, 0.0))))
    out_ref[...] = row.astype(dt)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows",
                                             "acc_dtype"))
def colscan(filter_col: jnp.ndarray, agg_col: jnp.ndarray,
            lo, hi, *, interpret: bool = False,
            block_rows: int = BLOCK_ROWS,
            acc_dtype: str = "float32") -> jnp.ndarray:
    """Returns [count, sum, min, max] over rows with lo <= filter_col <= hi.

    Inputs are padded to a whole number of tiles; the pad region is filled
    with NaN in the filter column, which fails BOTH bound comparisons — so
    padding is excluded even for one-sided ranges where lo or hi is ±inf
    (an inf fill would satisfy `f <= inf`).  `acc_dtype` is the
    accumulation dtype: float32 on TPU (MXU/VPU-native), float64 when the
    engine runs the kernel in interpret mode on CPU and must match the
    numpy oracle to rounding.
    """
    dt = jnp.dtype(acc_dtype)
    n = filter_col.shape[0]
    num_blocks = max(1, -(-n // block_rows))
    padded = num_blocks * block_rows
    f = jnp.full((padded,), jnp.nan, dt).at[:n].set(filter_col.astype(dt))
    a = jnp.zeros((padded,), dt).at[:n].set(agg_col.astype(dt))
    bounds = jnp.asarray([lo, hi], dt)

    partials = pl.pallas_call(
        _colscan_kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),  # bounds replicated per tile
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_blocks, LANES), dt),
        interpret=interpret,
    )(f, a, bounds)

    cnt = jnp.sum(partials[:, 0])
    s = jnp.sum(partials[:, 1])
    mn = jnp.min(partials[:, 2])
    mx = jnp.max(partials[:, 3])
    return jnp.stack([cnt, s, mn, mx])
