"""Encoded feature pipelines (paper §4.1 Listing 1; DESIGN.md §15.1).

`table_rdd_to_features` turns a SQL result RDD — or a lazy `SharkFrame`
directly — into a `FeatureRDD`: a narrow map on the same lineage graph
whose partitions are NOT dense matrices but pass-through references to the
source's encoded column blocks.  Training consumes them by handing each
block's raw streams (DICT codes + dictionary, FOR/BITPACK codes + bias,
RLE runs) straight into ONE jitted assemble+train step per partition —
the decode is traced into the XLA program, so the host never materializes
a feature column on the encoded path.  That claim is assertable:
`expr.DECODE_COUNTERS["numeric_blocks"]` stays untouched (decode_np is
never reached), and the CI benchmark asserts a zero delta.

Why it matters: a cached FeatureRDD partition is byte-accounted at its
ENCODED size under the MemoryManager (spillable, recompute-from-lineage
on loss), so the working set that fits in cache is the compressed one —
the same in-memory-columnar economics the SQL engine gets, now for the
ML tier.

Dtype policy (ISSUE 9 satellite): feature matrices default to float32 —
the MXU-native lane width, matching the SQL engine's accumulators on TPU
— with a `dtype=` escape hatch (e.g. `np.float64` for the differential
parity tests).  Labels are NEVER silently pushed through float32: the
label column keeps its source dtype end to end (an int64 label stays
int64, exact), and the train step casts it to the compute dtype in-trace.

`as_features_rdd` is the dispatch helper the estimators use to accept a
SharkFrame, a TableRDD + column names, or an already-featurized RDD.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batch import PartitionBatch
from ..core.compression import Encoding
from ..core.expr import ColumnVal
from ..core.frame import SharkFrame
from ..core.rdd import OneToOneDependency, RDD, TaskContext


class FeatureRDD(RDD):
    """Feature partitions that stay encoded.

    compute() selects the feature/label ColumnVals from the parent batch
    WITHOUT touching `.arr`: block-backed columns ride through still
    encoded, so caching this RDD stores (and byte-accounts) compressed
    blocks, and the jitted assemble+train step fuses their decode.

    A user `map_rows` callable is a host-side black box, so that variant
    falls back to the legacy dense layout ('features' matrix + 'label'),
    materialized once at featurization time.
    """

    def __init__(self, parent: RDD, feature_cols: Sequence[str],
                 label_col: Optional[str] = None,
                 map_rows: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 dtype=np.float32):
        self.feature_cols = list(feature_cols)
        self.label_col = label_col
        self.map_rows = map_rows
        self.dtype = np.dtype(dtype)
        super().__init__(parent.ctx, parent.num_partitions,
                         [OneToOneDependency(parent)])

    def compute(self, split: int, tc: TaskContext) -> PartitionBatch:
        batch = self.deps[0].parent.iterator(split, tc)
        for c in self.feature_cols:
            if batch.col(c).is_string:
                raise ValueError(
                    f"feature column {c!r} is a string column; encode it "
                    f"numerically (e.g. dictionary codes via SQL) first")
        if self.map_rows is not None:
            x = np.stack(
                [np.asarray(batch.col(c).arr).astype(self.dtype)
                 for c in self.feature_cols], axis=1) \
                if self.feature_cols else \
                np.zeros((batch.num_rows, 0), self.dtype)
            x = np.asarray(self.map_rows(x), dtype=self.dtype)
            out = {"features": ColumnVal(x)}
            if self.label_col is not None:
                # source dtype preserved: int64 labels stay int64 exactly
                out["label"] = ColumnVal(
                    np.asarray(batch.col(self.label_col).arr))
            return PartitionBatch(out)
        needed = list(self.feature_cols)
        if self.label_col is not None and self.label_col not in needed:
            needed.append(self.label_col)
        return PartitionBatch({c: batch.col(c) for c in needed})


def table_rdd_to_features(rdd, feature_cols: Sequence[str],
                          label_col: Optional[str] = None,
                          map_rows: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                          dtype=np.float32) -> RDD:
    """FeatureRDD over a TableRDD or lazy SharkFrame (compiled via
    `.to_rdd()`, same lineage graph) — the paper's ML pipeline step (2),
    as a narrow map whose partitions stay encoded (module docstring)."""
    if isinstance(rdd, SharkFrame):
        # the frame validates eagerly (FrameBindError naming the column)
        # instead of a raw KeyError inside a partition task
        return rdd.to_features(feature_cols, label_col, map_rows,
                               dtype=dtype)
    return FeatureRDD(rdd, feature_cols, label_col, map_rows, dtype)


def as_features_rdd(data, feature_cols: Optional[Sequence[str]] = None,
                    label_col: Optional[str] = None,
                    map_rows: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                    dtype=np.float32) -> RDD:
    """Normalize an estimator's input to a features RDD.

    * SharkFrame -> featurized via `table_rdd_to_features` (feature_cols
      defaults to every column except `label_col`);
    * RDD with `feature_cols` given -> featurized likewise;
    * RDD without `feature_cols` -> assumed already featurized (a
      FeatureRDD, or legacy partitions carrying 'features' / 'label'),
      returned as-is.
    """
    if isinstance(data, SharkFrame):
        cols = (list(feature_cols) if feature_cols is not None
                else [c for c in data.columns if c != label_col])
        return table_rdd_to_features(data, cols, label_col, map_rows, dtype)
    if feature_cols is not None:
        return table_rdd_to_features(data, feature_cols, label_col,
                                     map_rows, dtype)
    return data


# -- encoded block -> in-trace decode recipes (DESIGN.md §15.1) ----------
#
# A recipe is (static signature, runtime args): the signature keys the
# jitted step cache (encoding scheme + the ints XLA needs at trace time),
# the args are the block's raw streams passed as device arrays — never
# trace constants, so one compiled program serves every partition with the
# same signature and shapes.

def column_recipe(v: ColumnVal) -> Tuple[tuple, tuple]:
    """Recipe handing one column to the jitted step with decode fused
    in-trace.  Materialized columns (and encodings without a fused decode)
    degrade to a dense hand-off of whatever array already exists."""
    if (not v.materialized) and v.block is not None and v.sdict is None:
        enc = v.block.enc
        e = enc.encoding
        if e == Encoding.PLAIN:
            return ("plain",), (enc.data,)
        if e == Encoding.DICT:
            return ("dict",), (enc.codes, enc.dictionary)
        if e == Encoding.FOR:
            return (("for", str(np.dtype(enc.orig_dtype))),
                    (enc.codes, np.int64(enc.bias)))
        if e == Encoding.RLE:
            return ("rle", int(enc.n)), (enc.run_values, enc.run_lengths)
        if e == Encoding.BITPACK:
            return (("bitpack", int(enc.bit_width), int(enc.n),
                     str(np.dtype(enc.orig_dtype))),
                    (enc.words, np.int64(enc.bias)))
    a = np.asarray(v.arr)
    return ("dense",), (a,)


def _decode_in_trace(sig: tuple, args) -> jnp.ndarray:
    """The jnp decode recipes (compression.decode_jnp, inlined so they
    trace INTO the assemble+train program instead of running standalone)."""
    tag = sig[0]
    if tag in ("dense", "plain", "mat"):
        return args[0]
    if tag == "dict":
        codes, dictionary = args
        return dictionary[codes]
    if tag == "for":
        codes, bias = args
        return (codes.astype(jnp.int64) + bias).astype(jnp.dtype(sig[1]))
    if tag == "rle":
        run_values, run_lengths = args
        ends = jnp.cumsum(run_lengths)
        idx = jnp.searchsorted(ends, jnp.arange(sig[1]), side="right")
        return run_values[idx]
    if tag == "bitpack":
        words, bias = args
        width, n, odt = sig[1], sig[2], sig[3]
        per_word = 32 // width
        shifts = jnp.arange(per_word, dtype=jnp.uint32) * jnp.uint32(width)
        lanes = ((words[:, None] >> shifts[None, :])
                 & jnp.uint32((1 << width) - 1))
        flat = lanes.reshape(-1)[:n].astype(jnp.int64) + bias
        return flat.astype(jnp.dtype(odt))
    raise ValueError(sig)


def partition_recipes(batch: PartitionBatch,
                      feature_cols: Optional[Sequence[str]],
                      label_col: Optional[str]):
    """(sigs, col_args, label_sig, label_args) for one feature partition.

    Legacy dense partitions ('features' matrix) get the single ("mat",)
    recipe — already-materialized, handed through as one 2-D array."""
    if "features" in batch.cols:
        x = np.asarray(batch.col("features").arr)
        sigs, col_args = (("mat",),), ((x,),)
        if "label" in batch.cols:
            lsig, largs = column_recipe(batch.col("label"))
        else:
            lsig, largs = None, ()
        return sigs, col_args, lsig, largs
    sigs, col_args = [], []
    for c in feature_cols or []:
        s, a = column_recipe(batch.col(c))
        sigs.append(s)
        col_args.append(a)
    if label_col is not None:
        lsig, largs = column_recipe(batch.col(label_col))
    else:
        lsig, largs = None, ()
    return tuple(sigs), tuple(col_args), lsig, largs


# -- fused assemble+train step cache -------------------------------------

_FUSED_CACHE: dict = {}


def fused_train_step(kind: str, sigs: tuple, label_sig, dtype) -> Callable:
    """One jitted program per (estimator kind, partition signature): decode
    every encoded column, stack the feature matrix, and run the train step
    — all in a single trace, so XLA fuses decode into the matmuls and the
    host never sees a decoded column.

    kinds: "logistic" / "linear" -> summed gradient (d,);
           "kmeans"              -> (per-centroid sums, counts, objective);
           "assemble"            -> (x, y) for routes that need the dense
                                    matrix host-side (the Pallas train_grad
                                    kernel) without paying decode_np.
    """
    key = (kind, sigs, label_sig, str(np.dtype(dtype)))
    fn = _FUSED_CACHE.get(key)
    if fn is not None:
        return fn
    dt = jnp.dtype(str(np.dtype(dtype)))
    dense_mat = bool(sigs) and sigs[0][0] == "mat"

    def step(params, col_args, label_args):
        if dense_mat:
            x = _decode_in_trace(sigs[0], col_args[0]).astype(dt)
        elif sigs:
            x = jnp.stack([_decode_in_trace(s, a).astype(dt)
                           for s, a in zip(sigs, col_args)], axis=1)
        else:
            x = jnp.zeros((0, 0), dt)
        y = (_decode_in_trace(label_sig, label_args).astype(dt)
             if label_sig is not None else None)
        if kind == "assemble":
            return x, y
        if kind == "logistic":
            p = jax.nn.sigmoid(x @ params.astype(dt))
            return x.T @ (p - y)
        if kind == "linear":
            return x.T @ (x @ params.astype(dt) - y)
        if kind == "kmeans":
            c = params.astype(dt)
            x2 = jnp.sum(x * x, axis=1, keepdims=True)
            c2 = jnp.sum(c * c, axis=1)
            d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]
            assign = jnp.argmin(d2, axis=1)
            obj = jnp.sum(jnp.min(d2, axis=1))
            onehot = jax.nn.one_hot(assign, c.shape[0], dtype=dt)
            return onehot.T @ x, jnp.sum(onehot, axis=0), obj
        raise ValueError(kind)

    fn = jax.jit(step)
    _FUSED_CACHE[key] = fn
    return fn


def partition_xy_host(batch: PartitionBatch,
                      feature_cols: Optional[Sequence[str]],
                      label_col: Optional[str], dtype=np.float32):
    """Host-materialized (x, y) — the numpy-oracle route and the loss
    helpers.  Decodes through decode_np (counters bump: this is exactly
    the path the encoded pipeline avoids)."""
    if "features" in batch.cols:
        x = np.asarray(batch.col("features").arr).astype(dtype)
        y = (np.asarray(batch.col("label").arr)
             if "label" in batch.cols else None)
        return x, y
    cols = [np.asarray(batch.col(c).arr).astype(dtype)
            for c in feature_cols or []]
    x = (np.stack(cols, axis=1) if cols
         else np.zeros((batch.num_rows, 0), dtype))
    y = (np.asarray(batch.col(label_col).arr)
         if label_col is not None else None)
    return x, y
