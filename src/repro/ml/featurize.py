"""Feature extraction over TableRDDs and SharkFrames (paper §4.1, Listing 1's
mapRows).

`table_rdd_to_features` turns a SQL result RDD — or a lazy `SharkFrame`
directly — into an RDD of dense feature matrices (one jnp array per
partition), applying an optional user mapRows function — the paper's ML
pipeline step (2).  `as_features_rdd` is the dispatch helper the estimators
(`LogisticRegression.fit(frame, ...)` etc.) use to accept either surface.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import numpy as np

from ..core.batch import PartitionBatch
from ..core.expr import ColumnVal
from ..core.frame import SharkFrame
from ..core.rdd import RDD


def table_rdd_to_features(rdd, feature_cols: Sequence[str],
                          label_col: Optional[str] = None,
                          map_rows: Optional[Callable[[np.ndarray], np.ndarray]] = None
                          ) -> RDD:
    """Each partition becomes a batch with a dense float32 'features' matrix
    (rows x len(feature_cols)) and optional 'label' vector.  Runs as a narrow
    map, extending the SQL lineage graph.  `rdd` may be a TableRDD or a lazy
    SharkFrame (compiled via `.to_rdd()`, same lineage graph)."""

    if isinstance(rdd, SharkFrame):
        # the frame validates eagerly (FrameBindError naming the column)
        # instead of a raw KeyError inside a partition task
        return rdd.to_features(feature_cols, label_col, map_rows)
    cols = list(feature_cols)

    def extract(split: int, batch: PartitionBatch) -> PartitionBatch:
        mats = []
        for c in cols:
            v = batch.col(c)
            arr = np.asarray(v.arr, dtype=np.float32)
            mats.append(arr)
        x = np.stack(mats, axis=1) if mats else np.zeros((batch.num_rows, 0),
                                                         np.float32)
        if map_rows is not None:
            x = np.asarray(map_rows(x), dtype=np.float32)
        out = {"features": ColumnVal(x)}
        if label_col is not None:
            out["label"] = ColumnVal(
                np.asarray(batch.col(label_col).arr, dtype=np.float32))
        return PartitionBatch(out)

    return rdd.map_partitions(extract)


def as_features_rdd(data, feature_cols: Optional[Sequence[str]] = None,
                    label_col: Optional[str] = None,
                    map_rows: Optional[Callable[[np.ndarray], np.ndarray]] = None
                    ) -> RDD:
    """Normalize an estimator's input to a features RDD.

    * SharkFrame -> featurized via `table_rdd_to_features` (feature_cols
      defaults to every column except `label_col`);
    * RDD with `feature_cols` given -> featurized likewise;
    * RDD without `feature_cols` -> assumed already featurized
      (partitions carry 'features' / 'label'), returned as-is.
    """
    if isinstance(data, SharkFrame):
        cols = (list(feature_cols) if feature_cols is not None
                else [c for c in data.columns if c != label_col])
        return table_rdd_to_features(data, cols, label_col, map_rows)
    if feature_cols is not None:
        return table_rdd_to_features(data, feature_cols, label_col, map_rows)
    return data
