"""Feature extraction over TableRDDs (paper §4.1, Listing 1's mapRows).

`table_rdd_to_features` turns a SQL result RDD into an RDD of dense feature
matrices (one jnp array per partition), applying an optional user mapRows
function — the paper's ML pipeline step (2).
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import numpy as np

from ..core.batch import PartitionBatch
from ..core.expr import ColumnVal
from ..core.rdd import RDD


def table_rdd_to_features(rdd: RDD, feature_cols: Sequence[str],
                          label_col: Optional[str] = None,
                          map_rows: Optional[Callable[[np.ndarray], np.ndarray]] = None
                          ) -> RDD:
    """Each partition becomes a batch with a dense float32 'features' matrix
    (rows x len(feature_cols)) and optional 'label' vector.  Runs as a narrow
    map, extending the SQL lineage graph."""

    cols = list(feature_cols)

    def extract(split: int, batch: PartitionBatch) -> PartitionBatch:
        mats = []
        for c in cols:
            v = batch.col(c)
            arr = np.asarray(v.arr, dtype=np.float32)
            mats.append(arr)
        x = np.stack(mats, axis=1) if mats else np.zeros((batch.num_rows, 0),
                                                         np.float32)
        if map_rows is not None:
            x = np.asarray(map_rows(x), dtype=np.float32)
        out = {"features": ColumnVal(x)}
        if label_col is not None:
            out["label"] = ColumnVal(
                np.asarray(batch.col(label_col).arr, dtype=np.float32))
        return PartitionBatch(out)

    return rdd.map_partitions(extract)
