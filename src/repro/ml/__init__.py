"""Machine learning as a first-class citizen (paper §4).

SQL query results become TableRDDs — or stay lazy as SharkFrames — and
feature extraction and iterative algorithms run over the same partitions, on
the same workers, under the same lineage graph: no data export, end-to-end
fault tolerance.  Every estimator's `fit()` accepts a SharkFrame directly
(`clf.fit(frame, feature_cols=[...], label_col="y")`), so the paper's
Listing-1 pipeline is one fluent chain.

The numeric kernels (gradients, distances, centroid updates) are jit-compiled
JAX: on TPU they hit the MXU; on this CPU container they validate semantics.
"""

from .featurize import as_features_rdd, table_rdd_to_features
from .logreg import LogisticRegression
from .linreg import LinearRegression
from .kmeans import KMeans

__all__ = ["as_features_rdd", "table_rdd_to_features", "LogisticRegression",
           "LinearRegression", "KMeans"]
