"""Machine learning as a first-class citizen (paper §4).

SQL query results become TableRDDs — or stay lazy as SharkFrames — and
feature extraction and iterative algorithms run over the same partitions, on
the same workers, under the same lineage graph: no data export, end-to-end
fault tolerance.  Every estimator's `fit()` accepts a SharkFrame directly
(`clf.fit(frame, feature_cols=[...], label_col="y")`), so the paper's
Listing-1 pipeline is one fluent chain.

Analytics are a first-class COMPILED workload (DESIGN.md §15): feature
partitions stay encoded (`FeatureRDD`), each training iteration is a
PDE-scheduled map stage whose per-partition step fuses block decode +
gradient/assignment into one XLA program (or the Pallas `train_grad`
kernel), and the routes/timings land in the same ExecMetrics the SQL
executor uses.  On TPU the steps hit the MXU; on this CPU container they
validate semantics.
"""

from .featurize import FeatureRDD, as_features_rdd, table_rdd_to_features
from .logreg import LogisticRegression
from .linreg import LinearRegression
from .kmeans import KMeans
from .trainer import IterativeTrainer

__all__ = ["FeatureRDD", "IterativeTrainer", "as_features_rdd",
           "table_rdd_to_features", "LogisticRegression",
           "LinearRegression", "KMeans"]
