"""Machine learning as a first-class citizen (paper §4).

SQL query results become TableRDDs; feature extraction and iterative
algorithms run over the same partitions, on the same workers, under the same
lineage graph — no data export, end-to-end fault tolerance.

The numeric kernels (gradients, distances, centroid updates) are jit-compiled
JAX: on TPU they hit the MXU; on this CPU container they validate semantics.
"""

from .featurize import table_rdd_to_features
from .logreg import LogisticRegression
from .linreg import LinearRegression
from .kmeans import KMeans

__all__ = ["table_rdd_to_features", "LogisticRegression", "LinearRegression",
           "KMeans"]
