"""Distributed k-means clustering (paper §6.5, Figure 12; DESIGN.md §15.2).

Per iteration, every cached feature partition computes its per-centroid
point sums/counts and objective inside one fused jitted assemble+assign
step (assignment via MXU-friendly expansion-trick distances; encoded
block decode traced into the same program), scheduled as a map stage
under the PDE; the master reduces the stats and recomputes centroids.
The workflow is the paper's: SQL select -> feature extraction -> 10
iterations, all in-memory.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np


class KMeans:
    def __init__(self, k: int, dims: int, iterations: int = 10, seed: int = 0):
        self.k = k
        self.dims = dims
        self.iterations = iterations
        rng = np.random.default_rng(seed)
        self.centroids = rng.normal(size=(k, dims)).astype(np.float32)
        self.objective_history: List[float] = []
        self.metrics = None

    def fit(self, data, feature_cols=None, label_col=None,
            map_rows=None, dtype=np.float32) -> "KMeans":
        """`data`: a features RDD, or a SharkFrame / TableRDD plus
        `feature_cols` (featurized on the same lineage graph).  Clustering
        ignores labels, but `label_col` still excludes that column from the
        default feature set when `feature_cols` is omitted."""
        from .featurize import as_features_rdd
        from .trainer import IterativeTrainer
        features_rdd = as_features_rdd(data, feature_cols, label_col,
                                       map_rows, dtype)
        features_rdd.cache()
        trainer = IterativeTrainer(features_rdd, "kmeans", dtype=dtype)
        self.metrics = trainer.metrics
        for _ in range(self.iterations):
            sums, counts, obj = trainer.kmeans_iteration(self.centroids)
            self.objective_history.append(obj)
            nonzero = counts > 0
            self.centroids = self.centroids.copy()
            self.centroids[nonzero] = (
                sums[nonzero] / counts[nonzero, None]).astype(np.float32)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        c = jnp.asarray(self.centroids)
        xj = jnp.asarray(x)
        d2 = (jnp.sum(xj * xj, 1, keepdims=True) - 2 * xj @ c.T
              + jnp.sum(c * c, 1)[None, :])
        return np.asarray(jnp.argmin(d2, axis=1))
