"""Distributed k-means clustering (paper §6.5, Figure 12).

Per iteration, every cached partition computes, with one jit-compiled kernel,
the per-centroid point sums and counts (assignment via MXU-friendly pairwise
distances); the master reduces these and recomputes centroids.  The workflow
is the paper's: SQL select -> feature extraction -> 10 iterations, all
in-memory.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batch import PartitionBatch
from ..core.expr import ColumnVal
from ..core.rdd import RDD


@jax.jit
def _assign_kernel(centroids: jnp.ndarray, x: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (per-centroid sums, per-centroid counts, objective)."""
    # pairwise squared distances via the expansion trick: one matmul
    x2 = jnp.sum(x * x, axis=1, keepdims=True)            # n x 1
    c2 = jnp.sum(centroids * centroids, axis=1)           # k
    xc = x @ centroids.T                                  # n x k (MXU)
    d2 = x2 - 2.0 * xc + c2[None, :]
    assign = jnp.argmin(d2, axis=1)
    obj = jnp.sum(jnp.min(d2, axis=1))
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=x.dtype)
    sums = onehot.T @ x                                   # k x d (MXU)
    counts = jnp.sum(onehot, axis=0)
    return sums, counts, obj


class KMeans:
    def __init__(self, k: int, dims: int, iterations: int = 10, seed: int = 0):
        self.k = k
        self.dims = dims
        self.iterations = iterations
        rng = np.random.default_rng(seed)
        self.centroids = rng.normal(size=(k, dims)).astype(np.float32)
        self.objective_history: List[float] = []

    def fit(self, data, feature_cols=None, label_col=None,
            map_rows=None) -> "KMeans":
        """`data`: a features RDD, or a SharkFrame / TableRDD plus
        `feature_cols` (featurized on the same lineage graph).  Clustering
        ignores labels, but `label_col` still excludes that column from the
        default feature set when `feature_cols` is omitted."""
        from .featurize import as_features_rdd
        features_rdd = as_features_rdd(data, feature_cols, label_col,
                                       map_rows)
        features_rdd.cache()
        sched = features_rdd.ctx.scheduler
        for _ in range(self.iterations):
            c = jnp.asarray(self.centroids)

            def map_stats(split: int, batch: PartitionBatch) -> PartitionBatch:
                x = jnp.asarray(np.asarray(batch.col("features").arr))
                sums, counts, obj = _assign_kernel(c, x)
                return PartitionBatch({
                    "sums": ColumnVal(np.asarray(sums)[None]),
                    "counts": ColumnVal(np.asarray(counts)[None]),
                    "obj": ColumnVal(np.array([float(obj)]))})

            parts = sched.run_result_stage(
                features_rdd.map_partitions(map_stats))
            sums = np.sum([np.asarray(b.col("sums").arr)[0] for b in parts],
                          axis=0)
            counts = np.sum([np.asarray(b.col("counts").arr)[0]
                             for b in parts], axis=0)
            self.objective_history.append(
                float(sum(np.asarray(b.col("obj").arr)[0] for b in parts)))
            nonzero = counts > 0
            self.centroids = self.centroids.copy()
            self.centroids[nonzero] = (sums[nonzero]
                                       / counts[nonzero, None]).astype(np.float32)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        c = jnp.asarray(self.centroids)
        xj = jnp.asarray(x)
        d2 = (jnp.sum(xj * xj, 1, keepdims=True) - 2 * xj @ c.T
              + jnp.sum(c * c, 1)[None, :])
        return np.asarray(jnp.argmin(d2, axis=1))
