"""PDE-scheduled iterative training (DESIGN.md §15.2).

Each training iteration is a real map stage under the Scheduler — the
same `run_map_stage` machinery SQL shuffles use — not a private loop:

  * the per-partition step maps over the CACHED FeatureRDD, so iteration
    i > 0 reads worker-resident (encoded, byte-accounted) blocks;
  * the step's gradient/stats payload materializes as single-bucket
    shuffle output; the master fetches the per-map pieces and reduces
    them host-side (an O(dims) sum — the paper's map(gradient).reduce(+));
  * chaos mid-iteration is survivable for free: a dead worker's map task
    retries elsewhere (WorkerLost), its lost cache blocks recompute from
    lineage, and lost shuffle pieces recover via `_recover_lineage` — the
    steps are deterministic, so the final model is identical to a
    failure-free run (asserted by tests/test_ml_compiled.py);
  * each partition routes through `pde.decide_train_backend`: the numpy
    oracle for tiny partitions, the fused jitted assemble+train step
    (decode traced in — the encoded-pipeline fast path), or the Pallas
    `train_grad` gradient kernel on large partitions when kernels are
    forced/on-TPU.

Observability mirrors the SQL executor: one `SegmentRecord` per iteration
(table `<train:name>`, consumer "train") tallies partitions/rows/routes,
and `ExecMetrics.train_iterations` records per-iteration wall-clock —
the estimators expose the ExecMetrics as `.metrics` after fit().
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.batch import PartitionBatch
from ..core.expr import ColumnVal, _x64
from ..core.pde import PDEConfig, decide_train_backend
from ..core.physical import ExecMetrics, SegmentRecord
from ..core.rdd import RDD, ShuffleDependency, ShuffledRDD
from ..core.runtime import FetchFailed
from ..core.shuffle import single_bucket
from .featurize import (FeatureRDD, fused_train_step, partition_recipes,
                        partition_xy_host)


def _np_sigmoid(z: np.ndarray) -> np.ndarray:
    out = np.empty_like(z)
    pos = z >= 0
    out[pos] = 1.0 / (1.0 + np.exp(-z[pos]))
    ez = np.exp(z[~pos])
    out[~pos] = ez / (1.0 + ez)
    return out


def partition_grad(batch: PartitionBatch, w: np.ndarray, kind: str,
                   cfg: PDEConfig, dtype, feature_cols, label_col,
                   on_tpu: bool):
    """(route, unnormalized gradient) for one feature partition, routed by
    the PDE.  All three routes compute the same sum-of-residual-weighted
    features; they differ only in where the decode and the matmul run."""
    n = batch.num_rows
    d = decide_train_backend(n, len(w), "train_grad", on_tpu, cfg)
    sigs, col_args, lsig, largs = partition_recipes(batch, feature_cols,
                                                    label_col)
    if d.route == "numpy":
        x, y = partition_xy_host(batch, feature_cols, label_col, dtype)
        z = x @ w.astype(dtype)
        p = _np_sigmoid(z) if kind == "logistic" else z
        return "numpy", (x.T @ (p - y.astype(dtype))).astype(dtype)
    if d.route == "train_grad":
        from ..kernels import ops
        with _x64():
            x, y = fused_train_step("assemble", sigs, lsig, dtype)(
                w, col_args, largs)
            x, y = np.asarray(x), np.asarray(y)
        g = ops.train_grad(x, y, w, kind)
        return "train_grad", g.astype(dtype)
    with _x64():
        g = fused_train_step(kind, sigs, lsig, dtype)(w, col_args, largs)
        return "jit", np.asarray(g)


def partition_kmeans_stats(batch: PartitionBatch, centroids: np.ndarray,
                           cfg: PDEConfig, dtype, feature_cols,
                           on_tpu: bool):
    """(route, sums, counts, objective) for one partition's assignment
    step.  No dedicated Pallas kernel (the one-hot matmul is already
    MXU-shaped inside the fused step), so kernel_eligible is None."""
    n = batch.num_rows
    d = decide_train_backend(n, centroids.shape[1], None, on_tpu, cfg)
    if d.route == "numpy":
        x, _ = partition_xy_host(batch, feature_cols, None, dtype)
        c = centroids.astype(dtype)
        d2 = ((x * x).sum(1, keepdims=True) - 2.0 * (x @ c.T)
              + (c * c).sum(1)[None, :])
        assign = np.argmin(d2, axis=1)
        obj = float(np.min(d2, axis=1).sum())
        sums = np.zeros_like(c)
        np.add.at(sums, assign, x)
        counts = np.bincount(assign, minlength=c.shape[0]).astype(dtype)
        return "numpy", sums, counts, obj
    sigs, col_args, lsig, largs = partition_recipes(batch, feature_cols,
                                                    None)
    with _x64():
        sums, counts, obj = fused_train_step("kmeans", sigs, None, dtype)(
            centroids, col_args, ())
        return ("jit", np.asarray(sums), np.asarray(counts),
                float(np.asarray(obj)))


class IterativeTrainer:
    """Drives an estimator's iterations as scheduled map stages over a
    cached features RDD (module docstring)."""

    def __init__(self, features_rdd: RDD, name: str,
                 cfg: Optional[PDEConfig] = None,
                 metrics: Optional[ExecMetrics] = None,
                 dtype=np.float32):
        self.rdd = features_rdd
        self.name = name
        self.cfg = cfg or PDEConfig()
        self.metrics = metrics or ExecMetrics()
        self.sched = features_rdd.ctx.scheduler
        self.bm = features_rdd.ctx.block_manager
        self.iteration = 0
        if isinstance(features_rdd, FeatureRDD):
            self.feature_cols = features_rdd.feature_cols
            self.label_col = features_rdd.label_col
            if features_rdd.map_rows is None:
                self.dtype = features_rdd.dtype
            else:
                self.dtype = np.dtype(dtype)
        else:
            # legacy featurized RDD: dense 'features'/'label' layout
            self.feature_cols = None
            self.label_col = None
            self.dtype = np.dtype(dtype)

    def run_stage(self, make_payload: Callable[[int, PartitionBatch],
                                               Dict[str, ColumnVal]]
                  ) -> List[PartitionBatch]:
        """One iteration: map `make_payload` over every feature partition
        as a scheduled single-bucket map stage, return the per-map payload
        pieces (master reduces them).  `make_payload` must be
        deterministic — lineage recovery re-runs it."""
        record = SegmentRecord(table=f"<train:{self.name}>", depth=0,
                               consumer="train", outputs=[], pred=None)
        self.metrics.segments.append(record)
        lock = threading.Lock()

        def note(route: str, rows: int) -> None:
            with lock:
                record.partitions += 1
                record.rows_in += rows
                record.routes[route] = record.routes.get(route, 0) + 1

        def step(split: int, batch: PartitionBatch) -> PartitionBatch:
            route, payload = make_payload(split, batch)
            note(route, batch.num_rows)
            return PartitionBatch(payload)

        payload_rdd = self.rdd.map_partitions(step)
        dep = ShuffleDependency(payload_rdd, 1, single_bucket())
        # recovery anchor: _recover_lineage locates lost shuffles by walking
        # an RDD's dependency DAG, and `dep` only appears BELOW a reduce-side
        # RDD — the payload rdd is dep's parent, not its consumer
        fetch_root = ShuffledRDD(dep)
        t0 = time.perf_counter()
        self.sched.run_map_stage(dep)
        pieces: List[PartitionBatch] = []
        for _ in range(self.sched.max_stage_retries):
            try:
                pieces = self.bm.fetch_shuffle(
                    dep.shuffle_id, payload_rdd.num_partitions, [0])
                break
            except FetchFailed as ff:     # worker died after the map stage
                self.sched._recover_lineage(fetch_root, ff)
        else:
            raise RuntimeError("exceeded max stage retries (train fetch)")
        elapsed = time.perf_counter() - t0
        # per-iteration shuffle output is consumed exactly once: drop it so
        # a 100-iteration fit doesn't pin 100 generations of (tiny) blocks
        self.bm.drop_shuffle(dep.shuffle_id)
        self.metrics.train_iterations.append({
            "iteration": self.iteration, "seconds": elapsed,
            "rows": record.rows_in, "routes": dict(record.routes)})
        self.iteration += 1
        return pieces

    def gradient_iteration(self, w: np.ndarray, kind: str):
        """(summed gradient, total rows) across all partitions."""
        from ..kernels.ops import on_tpu
        tpu = on_tpu()

        def payload(split, batch):
            route, g = partition_grad(batch, w, kind, self.cfg, self.dtype,
                                      self.feature_cols, self.label_col,
                                      tpu)
            return route, {"grad": ColumnVal(g[None, :]),
                           "count": ColumnVal(
                               np.array([batch.num_rows], np.int64))}

        pieces = self.run_stage(payload)
        g = np.sum([np.asarray(p.col("grad").arr)[0] for p in pieces],
                   axis=0)
        n = int(sum(np.asarray(p.col("count").arr)[0] for p in pieces))
        return g, n

    def kmeans_iteration(self, centroids: np.ndarray):
        """(per-centroid sums, counts, total objective)."""
        from ..kernels.ops import on_tpu
        tpu = on_tpu()

        def payload(split, batch):
            route, sums, counts, obj = partition_kmeans_stats(
                batch, centroids, self.cfg, self.dtype, self.feature_cols,
                tpu)
            return route, {"sums": ColumnVal(sums[None]),
                           "counts": ColumnVal(counts[None]),
                           "obj": ColumnVal(np.array([obj]))}

        pieces = self.run_stage(payload)
        sums = np.sum([np.asarray(p.col("sums").arr)[0] for p in pieces],
                      axis=0)
        counts = np.sum([np.asarray(p.col("counts").arr)[0]
                         for p in pieces], axis=0)
        obj = float(sum(np.asarray(p.col("obj").arr)[0] for p in pieces))
        return sums, counts, obj
