"""Distributed logistic regression over RDD partitions (paper §4.1 Listing 1,
§6.5 Figure 11; DESIGN.md §15.2).

Each iteration is a PDE-scheduled map stage over the cached feature RDD:
every partition routes through `decide_train_backend` — numpy oracle,
fused jitted assemble+train (decode of encoded blocks traced into the XLA
program), or the Pallas `train_grad` kernel — and the master reduces the
per-partition gradients, exactly the paper's `data.map(gradient).reduce(+)`
loop.  Per-iteration cost on cached encoded partitions is one pass of
MXU-bound compute plus an O(dims) aggregation; a lost worker only
recomputes its partitions (lineage), even mid-iteration.

After `fit()`, `self.metrics` (an ExecMetrics) carries one SegmentRecord
per iteration with the routes taken, plus `train_iterations` timings.
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _loss_kernel(w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logits = x @ w
    return jnp.sum(jnp.logaddexp(0.0, logits) - y * logits)


class LogisticRegression:
    def __init__(self, dims: int, lr: float = 0.1, iterations: int = 10,
                 seed: int = 0):
        self.dims = dims
        self.lr = lr
        self.iterations = iterations
        rng = np.random.default_rng(seed)
        self.w = rng.normal(scale=0.01, size=dims).astype(np.float32)
        self.loss_history: List[float] = []
        self.metrics = None

    def fit(self, data, feature_cols=None, label_col=None,
            map_rows=None, dtype=np.float32) -> "LogisticRegression":
        """Train over feature partitions.  `data` is a FeatureRDD (or a
        legacy featurized RDD), or a SharkFrame / TableRDD with
        `feature_cols`/`label_col` naming the columns to featurize — the
        paper's Listing-1 pipeline as one fluent chain on one lineage
        graph.  `dtype` sets the feature compute dtype when featurizing
        here (float32 default; see featurize module docstring)."""
        from .featurize import as_features_rdd
        from .trainer import IterativeTrainer
        features_rdd = as_features_rdd(data, feature_cols, label_col,
                                       map_rows, dtype)
        features_rdd.cache()
        trainer = IterativeTrainer(features_rdd, "logreg", dtype=dtype)
        self.metrics = trainer.metrics
        for _ in range(self.iterations):
            g, n = trainer.gradient_iteration(self.w, "logistic")
            self.w = self.w - self.lr * (g / max(n, 1)).astype(self.w.dtype)
        return self

    def loss(self, data, feature_cols=None, label_col=None) -> float:
        from ..core.batch import PartitionBatch
        from ..core.expr import ColumnVal
        from .featurize import (FeatureRDD, as_features_rdd,
                                partition_xy_host)
        features_rdd = as_features_rdd(data, feature_cols, label_col)
        fcols = getattr(features_rdd, "feature_cols", None)
        lcol = getattr(features_rdd, "label_col", None)
        sched = features_rdd.ctx.scheduler
        w = jnp.asarray(self.w)

        def map_loss(split: int, batch: PartitionBatch) -> PartitionBatch:
            x, y = partition_xy_host(batch, fcols, lcol, np.float32)
            val = float(_loss_kernel(w, jnp.asarray(x),
                                     jnp.asarray(y.astype(np.float32))))
            return PartitionBatch({
                "loss": ColumnVal(np.array([val])),
                "count": ColumnVal(np.array([x.shape[0]], np.int64))})

        parts = sched.run_result_stage(features_rdd.map_partitions(map_loss))
        total = sum(float(np.asarray(b.col("loss").arr)[0]) for b in parts)
        n = sum(int(np.asarray(b.col("count").arr)[0]) for b in parts)
        return total / max(n, 1)

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(jax.nn.sigmoid(jnp.asarray(x) @ jnp.asarray(self.w)))

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x) >= 0.5).astype(np.int32)
