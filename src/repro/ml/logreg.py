"""Distributed logistic regression over RDD partitions (paper §4.1 Listing 1,
§6.5 Figure 11).

Each iteration maps a jit-compiled gradient kernel over every cached feature
partition and reduces the per-partition gradients on the master — exactly the
paper's `data.map(gradient).reduce(+)` loop.  Because the feature RDD is
cached in worker memory and gradients are computed where the data lives,
per-iteration cost is one pass of MXU-bound compute plus an O(dims)
aggregation; a lost worker only recomputes its partitions (lineage).
"""

from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batch import PartitionBatch
from ..core.rdd import RDD


@functools.partial(jax.jit, static_argnames=())
def _grad_kernel(w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Sum of per-point logistic gradients: x^T (sigmoid(xw) - y)."""
    p = jax.nn.sigmoid(x @ w)
    return x.T @ (p - y)


@jax.jit
def _loss_kernel(w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logits = x @ w
    return jnp.sum(jnp.logaddexp(0.0, logits) - y * logits)


class LogisticRegression:
    def __init__(self, dims: int, lr: float = 0.1, iterations: int = 10,
                 seed: int = 0):
        self.dims = dims
        self.lr = lr
        self.iterations = iterations
        rng = np.random.default_rng(seed)
        self.w = rng.normal(scale=0.01, size=dims).astype(np.float32)
        self.loss_history: List[float] = []

    def fit(self, data, feature_cols=None, label_col=None,
            map_rows=None) -> "LogisticRegression":
        """Train over feature partitions carrying 'features' (n x d) and
        'label'.  `data` is a features RDD, or a SharkFrame / TableRDD with
        `feature_cols`/`label_col` naming the columns to featurize — the
        paper's Listing-1 pipeline as one fluent chain on one lineage
        graph."""
        from .featurize import as_features_rdd
        features_rdd = as_features_rdd(data, feature_cols, label_col,
                                       map_rows)
        features_rdd.cache()
        sched = features_rdd.ctx.scheduler
        n_total = None
        for it in range(self.iterations):
            w = jnp.asarray(self.w)

            def map_grad(split: int, batch: PartitionBatch) -> PartitionBatch:
                x = jnp.asarray(np.asarray(batch.col("features").arr))
                y = jnp.asarray(np.asarray(batch.col("label").arr))
                g = _grad_kernel(w, x, y)
                from ..core.expr import ColumnVal
                return PartitionBatch({
                    "grad": ColumnVal(np.asarray(g)[None, :]),
                    "count": ColumnVal(np.array([x.shape[0]], np.int64))})

            grads = sched.run_result_stage(features_rdd.map_partitions(map_grad))
            g = np.sum([np.asarray(b.col("grad").arr)[0] for b in grads], axis=0)
            n_total = int(sum(np.asarray(b.col("count").arr)[0] for b in grads))
            self.w = self.w - self.lr * (g / max(n_total, 1)).astype(np.float32)
        return self

    def loss(self, data, feature_cols=None, label_col=None) -> float:
        from .featurize import as_features_rdd
        features_rdd = as_features_rdd(data, feature_cols, label_col)
        sched = features_rdd.ctx.scheduler
        w = jnp.asarray(self.w)

        def map_loss(split: int, batch: PartitionBatch) -> PartitionBatch:
            x = jnp.asarray(np.asarray(batch.col("features").arr))
            y = jnp.asarray(np.asarray(batch.col("label").arr))
            from ..core.expr import ColumnVal
            return PartitionBatch({
                "loss": ColumnVal(np.array([float(_loss_kernel(w, x, y))])),
                "count": ColumnVal(np.array([x.shape[0]], np.int64))})

        parts = sched.run_result_stage(features_rdd.map_partitions(map_loss))
        total = sum(float(np.asarray(b.col("loss").arr)[0]) for b in parts)
        n = sum(int(np.asarray(b.col("count").arr)[0]) for b in parts)
        return total / max(n, 1)

    def predict_proba(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(jax.nn.sigmoid(jnp.asarray(x) @ jnp.asarray(self.w)))

    def predict(self, x: np.ndarray) -> np.ndarray:
        return (self.predict_proba(x) >= 0.5).astype(np.int32)
