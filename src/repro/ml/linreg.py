"""Distributed linear regression (paper §4.1: "We have implemented ... linear
regression, logistic regression, and k-means").

Gradient-descent least squares over cached feature partitions, same
PDE-scheduled map-stage / master-reduce structure as logistic regression
(DESIGN.md §15.2) — routes: numpy oracle / fused jitted assemble+train /
Pallas `train_grad` kernel.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np


class LinearRegression:
    def __init__(self, dims: int, lr: float = 0.05, iterations: int = 20,
                 seed: int = 0):
        self.dims = dims
        self.lr = lr
        self.iterations = iterations
        self.w = np.zeros(dims, np.float32)
        self.metrics = None

    def fit(self, data, feature_cols=None, label_col=None,
            map_rows=None, dtype=np.float32) -> "LinearRegression":
        """`data`: a features RDD, or a SharkFrame / TableRDD plus
        `feature_cols`/`label_col` (featurized on the same lineage
        graph)."""
        from .featurize import as_features_rdd
        from .trainer import IterativeTrainer
        features_rdd = as_features_rdd(data, feature_cols, label_col,
                                       map_rows, dtype)
        features_rdd.cache()
        trainer = IterativeTrainer(features_rdd, "linreg", dtype=dtype)
        self.metrics = trainer.metrics
        for _ in range(self.iterations):
            g, n = trainer.gradient_iteration(self.w, "linear")
            self.w = self.w - self.lr * (g / max(n, 1)).astype(self.w.dtype)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(jnp.asarray(x) @ jnp.asarray(self.w))
