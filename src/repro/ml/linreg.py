"""Distributed linear regression (paper §4.1: "We have implemented ... linear
regression, logistic regression, and k-means").

Gradient-descent least squares over cached feature partitions, same
map-gradient / reduce-sum structure as logistic regression.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batch import PartitionBatch
from ..core.expr import ColumnVal
from ..core.rdd import RDD


@jax.jit
def _grad_kernel(w: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    r = x @ w - y
    return x.T @ r


class LinearRegression:
    def __init__(self, dims: int, lr: float = 0.05, iterations: int = 20,
                 seed: int = 0):
        self.dims = dims
        self.lr = lr
        self.iterations = iterations
        self.w = np.zeros(dims, np.float32)

    def fit(self, data, feature_cols=None, label_col=None,
            map_rows=None) -> "LinearRegression":
        """`data`: a features RDD, or a SharkFrame / TableRDD plus
        `feature_cols`/`label_col` (featurized on the same lineage graph)."""
        from .featurize import as_features_rdd
        features_rdd = as_features_rdd(data, feature_cols, label_col,
                                       map_rows)
        features_rdd.cache()
        sched = features_rdd.ctx.scheduler
        for _ in range(self.iterations):
            w = jnp.asarray(self.w)

            def map_grad(split: int, batch: PartitionBatch) -> PartitionBatch:
                x = jnp.asarray(np.asarray(batch.col("features").arr))
                y = jnp.asarray(np.asarray(batch.col("label").arr))
                g = _grad_kernel(w, x, y)
                return PartitionBatch({
                    "grad": ColumnVal(np.asarray(g)[None, :]),
                    "count": ColumnVal(np.array([x.shape[0]], np.int64))})

            parts = sched.run_result_stage(
                features_rdd.map_partitions(map_grad))
            g = np.sum([np.asarray(b.col("grad").arr)[0] for b in parts], axis=0)
            n = sum(int(np.asarray(b.col("count").arr)[0]) for b in parts)
            self.w = self.w - self.lr * (g / max(n, 1)).astype(np.float32)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(jnp.asarray(x) @ jnp.asarray(self.w))
