#!/usr/bin/env bash
# CI entry point: fast deterministic tier-1 tests + a 2-client smoke of the
# concurrent server benchmark (emits BENCH_concurrent.json).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -q -m tier1

echo "== concurrent server smoke (2 clients) =="
python -m benchmarks.concurrent_bench --quick --clients 2 \
    --queries-per-client 4 --rows 60000 --json-out BENCH_concurrent.json
echo "wrote BENCH_concurrent.json"
