#!/usr/bin/env bash
# CI entry point: fast deterministic tier-1 tests (includes the SharkFrame
# API suite, the ~200-query dual-backend differential oracle, and the
# kernels_interpret-marked Pallas-route tests), a 2-client smoke of the
# concurrent server benchmark (emits BENCH_concurrent.json), the frame-vs-SQL
# plan-build micro-benchmark (emits BENCH_frame_api.json), the multi-way
# star-join PDE-on/off benchmark (emits BENCH_joins.json; asserts PDE-on
# beats PDE-off on the uniform star join and stays above a 2-core noise
# floor on the skewed one), the compiled-vs-interpreted
# execution benchmark (emits BENCH_exec_engine.json; asserts the fused
# compiled path beats the interpreted path on the filter+aggregate shapes,
# including the repaired dictionary-coded one), and the compiled-exchange
# benchmark (emits BENCH_shuffle.json; asserts the dictionary-preserving
# shuffle is decode-free and beats the legacy decoded exchange on
# string-keyed group-by/join shapes), and the out-of-core storage tier
# benchmark (emits BENCH_spill.json; asserts that with a working set 4x the
# cache budget the spill tier finishes with zero wrong results and less
# wall clock than eviction + recompute-from-lineage), and the cluster-tier
# leg (runs the multidevice-marked tests plus the fleet scale-out benchmark
# under XLA_FLAGS=--xla_force_host_platform_device_count=8; emits
# BENCH_scale.json and asserts QPS scales >= 1.6x from 1 to 4 replicas with
# zero wrong results, including one replica killed mid-storm; the scale
# bench also exercises the composed mesh-per-replica fleet when multiple
# XLA devices are visible), and the compiled in-engine ML benchmark
# (emits BENCH_ml.json; asserts cached encoded training iterations beat
# the reload-per-iteration pipeline >= 5x, encoded featurization beats
# host materialization >= 1.3x, zero host-side decodes on the encoded
# path, and zero wrong filtered-similarity results under 3 concurrent
# server sessions), and the resilience leg (the seeded chaos-storm sweep —
# every fault site injected over 20 seeds against a live spill-tier server
# with byte-identical results required — plus the Figure 9 mid-query
# fault-tolerance benchmark, which emits BENCH_chaos.json and asserts the
# with-failure run stays within 2.5x of failure-free with zero wrong
# results).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (includes the tier1-marked frame-API suite) =="
python -m pytest -q -m tier1

echo "== frame-API smoke (fluent SQL->ML pipeline end to end) =="
python examples/sql_ml_pipeline.py

echo "== concurrent server smoke (2 clients) =="
python -m benchmarks.concurrent_bench --quick --clients 2 \
    --queries-per-client 4 --rows 60000 --json-out BENCH_concurrent.json
echo "wrote BENCH_concurrent.json"

echo "== frame-vs-SQL plan-build overhead =="
python -m benchmarks.frame_overhead --quick --json-out BENCH_frame_api.json
echo "wrote BENCH_frame_api.json"

echo "== multi-way star join: PDE on/off, uniform + skewed keys =="
python -m benchmarks.join_bench --quick --json-out BENCH_joins.json
echo "wrote BENCH_joins.json"

echo "== compiled vectorized execution: compiled vs interpreted =="
python -m benchmarks.exec_engine --quick --json-out BENCH_exec_engine.json
echo "wrote BENCH_exec_engine.json"

echo "== compiled exchange: dictionary-preserving vs decoded shuffle =="
python -m benchmarks.shuffle_bench --quick --json-out BENCH_shuffle.json
echo "wrote BENCH_shuffle.json"

echo "== out-of-core storage tier: spill vs recompute-from-lineage =="
python -m benchmarks.spill_bench --quick --json-out BENCH_spill.json
echo "wrote BENCH_spill.json"

echo "== whole-stage compilation: fused stage programs vs seam-by-seam =="
python -m benchmarks.pipeline_bench --quick --json-out BENCH_pipeline.json
echo "wrote BENCH_pipeline.json"

echo "== compiled in-engine ML: cached/encoded training + similarity search =="
python -m benchmarks.ml_bench --quick --json-out BENCH_ml.json
echo "wrote BENCH_ml.json"

echo "== resilience: seeded chaos-storm sweep (every fault site, 20 seeds) =="
python -m pytest -q tests/test_chaos_storm.py tests/test_resilience.py

echo "== resilience: Figure 9 mid-query fault tolerance (chaos engine) =="
python -m benchmarks.chaos_bench --quick --assert-ceiling 2.5 \
    --json-out BENCH_chaos.json
echo "wrote BENCH_chaos.json"

echo "== cluster tier: 8-device mesh tests + fleet scale-out =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q -m multidevice
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.scale_bench --quick --assert-floor 1.6 \
    --json-out BENCH_scale.json
echo "wrote BENCH_scale.json"
