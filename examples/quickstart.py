"""Quickstart: the Shark engine in 60 lines — columnar store, the fluent
SharkFrame API (and its SQL twin), map pruning, PDE join selection, and
mid-query fault tolerance.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import DType, Schema, SharkSession, col, count, sum_

sess = SharkSession(num_workers=4, max_threads=4, default_partitions=8)
rng = np.random.default_rng(0)

# -- load a warehouse table into the columnar memory store -------------------
n = 200_000
sess.create_table(
    "visits",
    Schema.of(day=DType.INT32, url=DType.STRING, revenue=DType.FLOAT64),
    {"day": np.sort(rng.integers(0, 30, n)).astype(np.int32),  # clustered
     "url": np.array([f"url{i}" for i in rng.integers(0, 5000, n)]),
     "revenue": rng.uniform(0, 10, n)},
    num_partitions=16)

# -- selection with map pruning: only partitions overlapping day 7 scan ------
r = (sess.table("visits").filter(col("day") == 7)
     .select("url", "revenue").to_numpy())
m = sess.metrics()
print(f"day=7 rows: {len(r['url'])}  "
      f"(pruned {m.pruned_partitions}/16 partitions without launching tasks)")

# -- aggregation with PDE reducer coalescing; HAVING trims small groups ------
daily = (sess.table("visits").group_by(col("day"))
         .agg(count().alias("n"), sum_(col("revenue")).alias("rev"))
         .having(col("rev") > 100))
r = daily.to_numpy()
print(f"{len(r['day'])} groups; PDE: {sess.metrics().reducer_decisions[-1]}")

# -- join: PDE observes the filtered dim table is small -> broadcast join ----
# (SQL text binds to the identical plan: sess.sql("SELECT lang, ...") )
sess.create_table(
    "pages", Schema.of(purl=DType.STRING, lang=DType.STRING),
    {"purl": np.array([f"url{i}" for i in range(5000)]),
     "lang": np.array(["en", "de", "fr", "jp"])[rng.integers(0, 4, 5000)]})
r = (sess.table("visits").join("pages", on=("url", "purl"))
     .filter(col("lang") == "de")
     .group_by(col("lang")).agg(sum_(col("revenue")).alias("rev"))
     .to_numpy())
print(f"join result: {dict(zip(r['lang'], np.round(r['rev'], 1)))}")
print(f"join plan: {sess.metrics().join_decisions[-1]}")

# -- kill a worker mid-session: lineage recomputes lost partitions -----------
# .cache(name) is the fluent CREATE TABLE ... AS — materialize + register
sess.table("visits").filter(col("day") < 10).select("day", "revenue") \
    .cache("cache_demo")
sess.ctx.scheduler.kill_worker(0)
r = sess.table("cache_demo").agg(count().alias("c")).to_numpy()
print(f"after killing worker 0: COUNT = {r['c'][0]} "
      f"(recomputed {sess.ctx.scheduler.tasks_recomputed} tasks via lineage)")

sess.shutdown()
