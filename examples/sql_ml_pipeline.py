"""Paper Listing 1 via the fluent SharkFrame API: relational selection ->
feature extraction -> distributed logistic regression, one lineage graph end
to end (with a node failure in the middle of training to prove it) — and
zero SQL-string plumbing between stages.

    PYTHONPATH=src python examples/sql_ml_pipeline.py
"""

import numpy as np

from repro.core import DType, Schema, SharkSession, col
from repro.ml import KMeans, LogisticRegression

rng = np.random.default_rng(0)
n, d = 50_000, 10
w_true = rng.normal(size=d)
X = rng.normal(size=(n, d)).astype(np.float32)
y = (X @ w_true + rng.normal(scale=0.2, size=n) > 0).astype(np.float32)

sess = SharkSession(num_workers=4, max_threads=4)
cols = {f"f{i}": X[:, i] for i in range(d)}
cols["is_spammer"] = y
sess.create_table("users", Schema.of(
    **{f"f{i}": DType.FLOAT32 for i in range(d)}, is_spammer=DType.FLOAT32),
    cols, num_partitions=8)

# the frame is the query plan — lazy, composable, same lineage graph the
# executor and the ML layer extend
users = sess.table("users").filter(col("f0") > -3)
print("SharkFrame columns:", users.columns)
print(users.explain())

# .to_features() leaves the final narrow stage lazy (Listing 1's mapRows);
# the cached feature RDD is reused across .fit() calls below
feature_cols = [f"f{i}" for i in range(d)]
feats = users.to_features(feature_cols, "is_spammer")
clf = LogisticRegression(dims=d, lr=0.5, iterations=5).fit(feats)
print(f"after 5 iters: accuracy = {(clf.predict(X) == y).mean():.4f}")

# node failure mid-training: lineage recomputes that worker's partitions
sess.ctx.scheduler.kill_worker(1)
clf.iterations = 10
clf.fit(feats)
print(f"after failure + 10 more iters: accuracy = "
      f"{(clf.predict(X) == y).mean():.4f} "
      f"(recomputed {sess.ctx.scheduler.tasks_recomputed} tasks)")

# k-means over the same cached features — no data movement; estimators also
# accept the frame directly: KMeans(...).fit(users, feature_cols=...)
km = KMeans(k=4, dims=d, iterations=10).fit(feats)
print(f"k-means objective: {km.objective_history[0]:.0f} -> "
      f"{km.objective_history[-1]:.0f}")
sess.shutdown()
