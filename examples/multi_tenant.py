"""Multi-tenant SharkServer demo (DESIGN.md §6).

One shared warehouse, two tenants:

  * `etl`   — weight 1, floods the server with scan-heavy group-bys;
  * `dash`  — weight 4, fires small interactive point queries.

The weighted fair scheduler keeps the dashboard's latency low while the
flood is in progress; the unified memory manager runs the cached working
set under a budget smaller than the data (evicting + recomputing from
lineage); repeated dashboard queries are served from the plan-fingerprint
result cache until an ETL `CREATE TABLE` bumps the catalog epoch and
invalidates exactly the dependent entries.

    PYTHONPATH=src python examples/multi_tenant.py
"""

import json
import time

import numpy as np

from repro.core import DType, Schema
from repro.server import SharkServer


def main():
    rng = np.random.default_rng(11)
    n = 300_000
    data = {
        "user": rng.integers(0, 5_000, n).astype(np.int64),
        "lat_ms": rng.gamma(2.0, 30.0, n),
        "status": rng.choice(np.array([200, 200, 200, 404, 500],
                                      np.int32), n),
    }

    srv = SharkServer(num_workers=8, max_threads=8,
                      cache_budget_bytes=2 << 20,   # < working set
                      max_concurrent_queries=4, max_queue_depth=64,
                      default_partitions=16, default_shuffle_buckets=16)
    srv.create_table("logs", Schema.of(user=DType.INT64,
                                       lat_ms=DType.FLOAT64,
                                       status=DType.INT32), data)

    etl = srv.session("etl", weight=1.0)
    dash = srv.session("dash", weight=4.0)

    # ETL tenant floods the queue with heavy aggregations (async handles)
    flood = [etl.submit("SELECT user, SUM(lat_ms) AS total, COUNT(*) AS c "
                        f"FROM logs WHERE status < {s} GROUP BY user")
             for s in (300, 401, 404, 500, 501, 502)]

    # interactive tenant: small repeated dashboard queries
    dash_latencies = []
    for _ in range(8):
        t0 = time.perf_counter()
        errors = dash.sql_np(
            "SELECT COUNT(*) AS c FROM logs WHERE status = 500")
        dash_latencies.append(time.perf_counter() - t0)
    print(f"dashboard: {int(errors['c'][0])} errors; per-query latency "
          f"{[round(t * 1e3, 2) for t in dash_latencies]} ms "
          "(first is cold, rest are result-cache hits)")

    for h in flood:
        h.result()
    print(f"etl flood done: {len(flood)} heavy queries")

    # an ETL load mutates the warehouse -> dependent cache entries drop
    srv.sql("CREATE TABLE errors_only AS SELECT user, lat_ms FROM logs "
            "WHERE status = 500")
    t0 = time.perf_counter()
    dash.sql_np("SELECT COUNT(*) AS c FROM logs WHERE status = 500")
    print(f"after CREATE TABLE (epoch bump, logs untouched): "
          f"{(time.perf_counter() - t0) * 1e3:.2f} ms "
          "(still a hit: only tables a plan READS invalidate it)")

    print(json.dumps(srv.stats(), indent=2, default=str))
    srv.shutdown()


if __name__ == "__main__":
    main()
