"""Batched serving example: prefill + KV-cache decode on a reduced config.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "yi-9b-smoke", "--batch", "8",
                "--prompt-len", "32", "--new-tokens", "48"] + sys.argv[1:]
    main()
