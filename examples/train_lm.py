"""End-to-end LM training driver: SQL-selected corpus -> columnar pipeline ->
train a reduced qwen2.5 config for a few hundred steps with checkpointing
and a simulated preemption + restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(Thin wrapper over repro.launch.train; on TPU hardware the same driver
takes --arch qwen2.5-3b and the production mesh.)
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen2.5-3b-smoke",
                "--steps", "300", "--seq-len", "64", "--batch", "16",
                "--lr", "3e-3", "--ckpt-every", "100",
                "--simulate-preemption", "150",
                "--ckpt-dir", "/tmp/repro_example_ckpt"] + sys.argv[1:]
    main()
