"""PDE applied to MoE training: the paper's statistics-driven replanning
(§3.1) closing the loop on expert routing.

Trains a reduced phi3.5-MoE on a SQL-selected corpus; every step the router
emits per-expert load (the paper's "heavy hitters" accumulator), the
replanner keeps a lossy 1-byte-encoded history, and at stage boundaries it
re-selects the capacity factor from observed p99 load — snapping to buckets
so the jit cache stays small (the "pre-lowered stage-2 variants" pattern).

    PYTHONPATH=src python examples/pde_moe_training.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import SharkSession
from repro.data import TokenPipeline, synthetic_corpus
from repro.models import lm
from repro.models import moe as moe_mod
from repro.training import AdamWConfig, init_opt_state, make_train_step
from repro.training.pde_moe import MoEReplanner

cfg = get_config("phi3.5-moe-42b-a6.6b-smoke")
sess = SharkSession(num_workers=2, max_threads=2)
synthetic_corpus(sess, "corpus", cfg.vocab, n_docs=60, mean_doc_len=256)
pipe = TokenPipeline(sess, "corpus", seq_len=32, global_batch=8,
                     sql_filter="quality > 0.25")
print(f"SQL-selected corpus: {len(pipe.stream)} tokens")

params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
opt_state = init_opt_state(params)
replanner = MoEReplanner(cfg.moe.num_experts, cfg.moe.top_k)
tokens_per_step = 8 * 32

step_fns = {}  # capacity bucket -> compiled step (pre-lowered variants)
current_cf = cfg.moe.capacity_factor

for step in range(30):
    if step % 10 == 0 and step > 0:
        plan = replanner.plan(tokens_per_step)
        if plan.capacity_factor != current_cf:
            print(f"  [PDE] step {step}: re-planning — {plan.reason}")
            current_cf = plan.capacity_factor
        else:
            print(f"  [PDE] step {step}: plan unchanged ({plan.reason})")
    if current_cf not in step_fns:
        c = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=current_cf))
        step_fns[current_cf] = jax.jit(make_train_step(c, AdamWConfig(lr=3e-3)))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
    params, opt_state, metrics = step_fns[current_cf](params, opt_state,
                                                      batch)
    # observe expert load (stats already computed inside the step's MoE)
    lp = jax.tree_util.tree_map(lambda x: x, params)  # params current
    x = lm.embed_lookup(params["embed"], batch["tokens"])
    _, stats = moe_mod.moe_apply(
        jax.tree.map(lambda a: a[0], params["layers"]["moe"]), x, cfg.moe,
        return_stats=True)
    replanner.observe(np.asarray(stats["expert_load"]))
    if step % 5 == 0:
        print(f"step {step:3d} loss {float(metrics['loss']):.4f} "
              f"cf={current_cf} compiled_variants={len(step_fns)}")

print(f"done; executable cache held {len(step_fns)} capacity variants")
sess.shutdown()
