"""Property-based test: random expression trees evaluated by the engine's
compiler must match direct numpy evaluation (the §5 bytecode-compilation
analogue cannot change semantics)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

pytestmark = pytest.mark.tier1
from hypothesis import given, settings, strategies as st

from repro.core.expr import (And, Between, BinOp, Cmp, Col, ColumnVal, Func,
                             InList, Lit, Not, Or, evaluate)

COLS = {"a": None, "b": None, "c": None}


def _numeric_expr(depth):
    if depth == 0:
        return st.one_of(
            st.sampled_from(list(COLS)).map(Col),
            st.integers(-50, 50).map(Lit),
        )
    sub = _numeric_expr(depth - 1)
    return st.one_of(
        sub,
        st.tuples(st.sampled_from(["+", "-", "*"]), sub, sub)
        .map(lambda t: BinOp(*t)),
        sub.map(lambda e: Func("ABS", (e,))),
    )


def _bool_expr(depth):
    num = _numeric_expr(depth)
    base = st.tuples(st.sampled_from(["=", "!=", "<", "<=", ">", ">="]),
                     num, num).map(lambda t: Cmp(*t))
    if depth == 0:
        return base
    sub = _bool_expr(depth - 1)
    return st.one_of(
        base,
        st.tuples(sub, sub).map(lambda t: And(*t)),
        st.tuples(sub, sub).map(lambda t: Or(*t)),
        sub.map(Not),
        st.tuples(num, st.integers(-20, 0), st.integers(0, 20))
        .map(lambda t: Between(t[0], t[1], t[2])),
        st.tuples(num, st.lists(st.integers(-30, 30), min_size=1,
                                max_size=4))
        .map(lambda t: InList(t[0], tuple(t[1]))),
    )


def _ref_eval(e, env):
    if isinstance(e, Col):
        return env[e.name]
    if isinstance(e, Lit):
        return e.value
    if isinstance(e, BinOp):
        l, r = _ref_eval(e.left, env), _ref_eval(e.right, env)
        return {"+": l + r, "-": l - r, "*": l * r}[e.op]
    if isinstance(e, Cmp):
        l, r = _ref_eval(e.left, env), _ref_eval(e.right, env)
        return {"=": l == r, "!=": l != r, "<": l < r, "<=": l <= r,
                ">": l > r, ">=": l >= r}[e.op]
    if isinstance(e, And):
        return _ref_eval(e.left, env) & _ref_eval(e.right, env)
    if isinstance(e, Or):
        return _ref_eval(e.left, env) | _ref_eval(e.right, env)
    if isinstance(e, Not):
        return np.logical_not(_ref_eval(e.child, env))
    if isinstance(e, Between):
        v = _ref_eval(e.child, env)
        return (v >= e.lo) & (v <= e.hi)
    if isinstance(e, InList):
        v = _ref_eval(e.child, env)
        out = np.zeros_like(np.asarray(v), bool)
        for x in e.values:
            out |= np.asarray(v) == x
        return out
    if isinstance(e, Func) and e.name == "ABS":
        return np.abs(_ref_eval(e.args[0], env))
    raise TypeError(e)


@settings(max_examples=120, deadline=None)
@given(_bool_expr(3), st.integers(0, 2**31 - 1))
def test_random_predicates_match_numpy(expr, seed):
    rng = np.random.default_rng(seed)
    env = {n: rng.integers(-40, 40, 64).astype(np.int64) for n in COLS}
    ctx = {n: ColumnVal(v) for n, v in env.items()}
    got = np.asarray(evaluate(expr, ctx).arr)
    want = np.asarray(_ref_eval(expr, env))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=80, deadline=None)
@given(_numeric_expr(3), st.integers(0, 2**31 - 1))
def test_random_numeric_exprs_match_numpy(expr, seed):
    rng = np.random.default_rng(seed)
    env = {n: rng.integers(-20, 20, 32).astype(np.int64) for n in COLS}
    ctx = {n: ColumnVal(v) for n, v in env.items()}
    got = np.asarray(evaluate(expr, ctx).arr, dtype=np.float64)
    want = np.asarray(_ref_eval(expr, env), dtype=np.float64)
    np.testing.assert_allclose(got, want)
