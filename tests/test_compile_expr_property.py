"""Property-based test (hypothesis, gated like test_join_property.py):
`compile_expr` — the traced/jitted expression compiler — must agree with
`evaluate`, its numpy oracle, over randomly generated expression trees.

Coverage targets the places the lowering diverges structurally from the
interpreter:
  * dictionary-code-space predicates on dict-encoded STRING columns,
    including literals absent from a partition's dictionary (the dialect's
    NULL-ish case: the match set is empty, and != / NOT must still see
    every row);
  * dict-encoded NUMERIC columns evaluated on codes without decoding;
  * BITPACK-encoded columns with negative values (bias edge cases) read
    through the memoized decode;
  * mixed plain/encoded layouts — the per-partition signature machinery.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

pytestmark = pytest.mark.tier1

from hypothesis import given, settings, strategies as st

from repro.core.compression import Encoding
from repro.core.columnar import make_block
from repro.core.expr import (And, Between, BinOp, Cmp, Col, ColumnVal, Func,
                             InList, Lit, Not, Or, compile_expr, evaluate)
from repro.core.types import DType, Field

NUM_COLS = ["a", "d", "bp"]     # plain int64, DICT-encoded, BITPACK-encoded
STR_COL = "s"
# dictionary values on purpose include negatives; literals sample a superset
# so absent-from-dictionary comparisons are generated too
DICT_POOL = np.array([-19, -7, -3, 0, 4, 5, 11, 23], np.int64)
STR_POOL = ["apple", "fig", "kiwi", "lime", "mango", "pear"]
STR_LITS = STR_POOL + ["", "banana", "zzz"]     # absent literals included

CMPS = ["=", "!=", "<", "<=", ">", ">="]


def _numeric_expr(depth):
    if depth == 0:
        return st.one_of(
            st.sampled_from(NUM_COLS).map(Col),
            st.integers(-50, 50).map(Lit),
        )
    sub = _numeric_expr(depth - 1)
    return st.one_of(
        sub,
        st.tuples(st.sampled_from(["+", "-", "*"]), sub, sub)
        .map(lambda t: BinOp(*t)),
        sub.map(lambda e: Func("ABS", (e,))),
    )


def _string_pred():
    return st.one_of(
        st.tuples(st.sampled_from(CMPS), st.sampled_from(STR_LITS))
        .map(lambda t: Cmp(t[0], Col(STR_COL), Lit(t[1]))),
        st.lists(st.sampled_from(STR_LITS), min_size=1, max_size=3)
        .map(lambda vs: InList(Col(STR_COL), tuple(vs))),
        st.tuples(st.sampled_from(STR_LITS), st.sampled_from(STR_LITS))
        .map(lambda t: Between(Col(STR_COL), min(t), max(t))),
    )


def _bool_expr(depth):
    num = _numeric_expr(depth)
    base = st.one_of(
        st.tuples(st.sampled_from(CMPS), num, num).map(lambda t: Cmp(*t)),
        _string_pred(),
    )
    if depth == 0:
        return base
    sub = _bool_expr(depth - 1)
    return st.one_of(
        base,
        st.tuples(sub, sub).map(lambda t: And(*t)),
        st.tuples(sub, sub).map(lambda t: Or(*t)),
        sub.map(Not),
        st.tuples(num, st.integers(-20, 0), st.integers(0, 20))
        .map(lambda t: Between(t[0], t[1], t[2])),
        st.tuples(num, st.lists(st.integers(-30, 30), min_size=1,
                                max_size=4))
        .map(lambda t: InList(t[0], tuple(t[1]))),
    )


def _make_ctx(seed: int, n: int = 96):
    """Partition context mixing plain, DICT, and BITPACK layouts, exactly
    as the columnar store would hand them to a segment."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-40, 40, n).astype(np.int64)
    d_vals = rng.choice(DICT_POOL, n)
    bp_vals = rng.integers(-37, 29, n).astype(np.int64)   # negative bias
    s_vals = np.array([STR_POOL[i] for i in rng.integers(0, len(STR_POOL),
                                                         n)])
    d_blk = make_block(Field("d", DType.INT64), d_vals, Encoding.DICT)
    bp_blk = make_block(Field("bp", DType.INT64), bp_vals, Encoding.BITPACK)
    s_blk = make_block(Field("s", DType.STRING), s_vals)
    return {
        "a": ColumnVal(a),
        "d": ColumnVal(None, None, True, block=d_blk),
        "bp": ColumnVal(None, None, True, block=bp_blk),
        "s": ColumnVal(None, s_blk.str_dict, True, block=s_blk),
    }


def _assert_matches(expr, ctx):
    want = evaluate(expr, ctx)
    got = compile_expr(expr)(ctx)
    assert got.is_string == want.is_string
    if want.is_string:
        np.testing.assert_array_equal(got.decoded(), want.decoded())
        return
    w = np.asarray(want.arr)
    g = np.asarray(got.arr)
    if w.dtype.kind == "f" or g.dtype.kind == "f":
        np.testing.assert_allclose(g.astype(np.float64),
                                   w.astype(np.float64),
                                   rtol=1e-12, atol=0)
    else:
        np.testing.assert_array_equal(g, w)


@settings(max_examples=120, deadline=None)
@given(_bool_expr(3), st.integers(0, 2**31 - 1))
def test_random_predicates_compile_exactly(expr, seed):
    _assert_matches(expr, _make_ctx(seed))


@settings(max_examples=60, deadline=None)
@given(_numeric_expr(3), st.integers(0, 2**31 - 1))
def test_random_numeric_exprs_compile_exactly(expr, seed):
    _assert_matches(expr, _make_ctx(seed))


@settings(max_examples=40, deadline=None)
@given(_string_pred(), st.integers(0, 2**31 - 1))
def test_string_dictionary_predicates_compile_exactly(expr, seed):
    """Absent-literal string comparisons: the compiled code-space bounds
    must produce the same (possibly empty) match sets as the evaluator,
    and negation must recover every row."""
    ctx = _make_ctx(seed)
    _assert_matches(expr, ctx)
    _assert_matches(Not(expr), ctx)
