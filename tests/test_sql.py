"""End-to-end SQL engine tests against numpy references."""

import collections

import numpy as np
import pytest

from repro.core import DType, Schema, SharkSession

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def sess():
    rng = np.random.default_rng(0)
    s = SharkSession(num_workers=4, max_threads=4, default_partitions=6,
                     default_shuffle_buckets=8)
    n = 20000
    s.create_table("rankings", Schema.of(
        pageURL=DType.STRING, pageRank=DType.INT32, avgDuration=DType.INT32),
        {"pageURL": np.array([f"url{i % 997}" for i in range(n)]),
         "pageRank": rng.integers(0, 1000, n).astype(np.int32),
         "avgDuration": rng.integers(1, 100, n).astype(np.int32)})
    m = 5000
    s.create_table("uservisits", Schema.of(
        sourceIP=DType.STRING, destURL=DType.STRING,
        adRevenue=DType.FLOAT64, visitDate=DType.INT32),
        {"sourceIP": np.array([f"10.0.{i % 50}.{i % 7}" for i in range(m)]),
         "destURL": np.array([f"url{i % 997}" for i in range(m)]),
         "adRevenue": rng.uniform(0, 10, m),
         "visitDate": rng.integers(10000, 12000, m).astype(np.int32)})
    yield s
    s.shutdown()


def ref(sess, table):
    return sess.catalog.get(table).to_dict()


def test_selection(sess):
    r = sess.sql_np("SELECT pageURL, pageRank FROM rankings "
                    "WHERE pageRank > 500")
    d = ref(sess, "rankings")
    mask = d["pageRank"] > 500
    assert len(r["pageRank"]) == mask.sum()
    assert sorted(r["pageRank"].tolist()) == sorted(
        d["pageRank"][mask].tolist())


def test_compound_predicate(sess):
    r = sess.sql_np("SELECT pageRank FROM rankings WHERE "
                    "pageRank > 100 AND avgDuration < 50 OR pageRank = 7")
    d = ref(sess, "rankings")
    mask = (d["pageRank"] > 100) & (d["avgDuration"] < 50) | (d["pageRank"] == 7)
    assert len(r["pageRank"]) == mask.sum()


def test_string_predicate(sess):
    r = sess.sql_np("SELECT pageURL FROM rankings WHERE pageURL = 'url13'")
    d = ref(sess, "rankings")
    assert len(r["pageURL"]) == (d["pageURL"] == "url13").sum()
    assert set(r["pageURL"]) == {"url13"}


def test_aggregation_groups(sess):
    r = sess.sql_np("SELECT pageRank % 5 AS g, COUNT(*) AS c, "
                    "SUM(avgDuration) AS s, AVG(avgDuration) AS a "
                    "FROM rankings GROUP BY pageRank % 5")
    d = ref(sess, "rankings")
    g = d["pageRank"] % 5
    for gi, c, s_, a in zip(r["g"], r["c"], r["s"], r["a"]):
        m = g == gi
        assert c == m.sum()
        assert s_ == d["avgDuration"][m].sum()
        assert abs(a - d["avgDuration"][m].mean()) < 1e-9
    assert len(r["g"]) == 5


def test_global_aggregate(sess):
    r = sess.sql_np("SELECT COUNT(*) AS c, MIN(pageRank) AS mn, "
                    "MAX(pageRank) AS mx FROM rankings")
    d = ref(sess, "rankings")
    assert r["c"][0] == len(d["pageRank"])
    assert r["mn"][0] == d["pageRank"].min()
    assert r["mx"][0] == d["pageRank"].max()


def test_count_distinct(sess):
    r = sess.sql_np("SELECT COUNT(DISTINCT pageURL) AS u FROM rankings")
    d = ref(sess, "rankings")
    assert r["u"][0] == len(np.unique(d["pageURL"]))


def test_count_distinct_grouped_with_count(sess):
    r = sess.sql_np("SELECT pageRank % 3 AS g, COUNT(*) AS c, "
                    "COUNT(DISTINCT pageURL) AS u FROM rankings "
                    "GROUP BY pageRank % 3")
    d = ref(sess, "rankings")
    g = d["pageRank"] % 3
    for gi, c, u in zip(r["g"], r["c"], r["u"]):
        m = g == gi
        assert c == m.sum()
        assert u == len(np.unique(d["pageURL"][m]))


def test_substr_groupby(sess):
    r = sess.sql_np("SELECT SUBSTR(sourceIP, 1, 6) AS p, "
                    "SUM(adRevenue) AS s FROM uservisits "
                    "GROUP BY SUBSTR(sourceIP, 1, 6)")
    d = ref(sess, "uservisits")
    refsum = collections.defaultdict(float)
    for ip, rev in zip(d["sourceIP"], d["adRevenue"]):
        refsum[ip[:6]] += rev
    got = dict(zip(r["p"].tolist(), r["s"].tolist()))
    assert set(got) == set(refsum)
    for k in got:
        assert abs(got[k] - refsum[k]) < 1e-6


def test_join_with_filter(sess):
    r = sess.sql_np(
        "SELECT sourceIP, pageRank, adRevenue FROM rankings R, uservisits UV "
        "WHERE R.pageURL = UV.destURL AND UV.visitDate BETWEEN 10500 AND 11000")
    dr, dv = ref(sess, "rankings"), ref(sess, "uservisits")
    vmask = (dv["visitDate"] >= 10500) & (dv["visitDate"] <= 11000)
    url_count = collections.Counter(dr["pageURL"].tolist())
    expected = sum(url_count[u] for u in dv["destURL"][vmask])
    assert len(r["sourceIP"]) == expected


def test_join_aggregate(sess):
    r = sess.sql_np(
        "SELECT sourceIP, AVG(pageRank) AS avgRank, SUM(adRevenue) AS rev "
        "FROM rankings R JOIN uservisits UV ON R.pageURL = UV.destURL "
        "GROUP BY sourceIP")
    dr, dv = ref(sess, "rankings"), ref(sess, "uservisits")
    # reference join
    by_url = collections.defaultdict(list)
    for u, pr in zip(dr["pageURL"], dr["pageRank"]):
        by_url[u].append(pr)
    sums = collections.defaultdict(float)
    ranks = collections.defaultdict(list)
    for ip, u, rev in zip(dv["sourceIP"], dv["destURL"], dv["adRevenue"]):
        for pr in by_url.get(u, ()):
            sums[ip] += rev
            ranks[ip].append(pr)
    got = dict(zip(r["sourceIP"].tolist(), r["rev"].tolist()))
    assert set(got) == set(sums)
    for k in list(sums)[:20]:
        assert abs(got[k] - sums[k]) < 1e-6
    gotr = dict(zip(r["sourceIP"].tolist(), r["avgRank"].tolist()))
    for k in list(ranks)[:20]:
        assert abs(gotr[k] - np.mean(ranks[k])) < 1e-9


def test_order_by_limit(sess):
    r = sess.sql_np("SELECT pageURL, pageRank FROM rankings "
                    "ORDER BY pageRank DESC LIMIT 25")
    d = ref(sess, "rankings")
    top = np.sort(d["pageRank"])[-25:][::-1]
    np.testing.assert_array_equal(r["pageRank"], top)


def test_limit_pushdown(sess):
    r = sess.sql_np("SELECT pageURL FROM rankings LIMIT 10")
    assert len(r["pageURL"]) == 10


def test_ctas_and_query(sess):
    sess.sql("CREATE TABLE high_rank AS SELECT pageURL, pageRank "
             "FROM rankings WHERE pageRank > 900")
    r = sess.sql_np("SELECT COUNT(*) AS c FROM high_rank")
    d = ref(sess, "rankings")
    assert r["c"][0] == (d["pageRank"] > 900).sum()


def test_copartition_join(sess):
    sess.sql("CREATE TABLE r_mem TBLPROPERTIES ('shark.cache'='true') AS "
             "SELECT pageURL, pageRank FROM rankings DISTRIBUTE BY pageURL")
    sess.sql("CREATE TABLE v_mem TBLPROPERTIES ('shark.cache'='true', "
             "'copartition'='r_mem') AS SELECT destURL, adRevenue "
             "FROM uservisits DISTRIBUTE BY destURL")
    before = len(sess.metrics().join_decisions)
    r = sess.sql_np("SELECT pageRank, adRevenue FROM r_mem "
                    "JOIN v_mem ON r_mem.pageURL = v_mem.destURL")
    decisions = sess.metrics().join_decisions
    assert any("copartition" in d for d in decisions)
    dr, dv = ref(sess, "rankings"), ref(sess, "uservisits")
    url_count = collections.Counter(dr["pageURL"].tolist())
    expected = sum(url_count[u] for u in dv["destURL"])
    assert len(r["pageRank"]) == expected


def test_explain(sess):
    plan = sess.explain("SELECT pageURL FROM rankings WHERE pageRank > 10")
    assert "Filter" in plan and "Scan" in plan
