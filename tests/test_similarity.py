"""Vector analytics (DESIGN.md §15.3): embedding lane columns in the
catalog, `similarity_join` on the frame surface, its SQL-twin plan, the
Pallas top-k route, and correctness under server concurrency.
"""

import threading

import numpy as np
import pytest

from repro.core import DType, Schema, SharkSession
from repro.core.frame import FrameBindError
from repro.core.functions import col
from repro.core.pde import PDEConfig

pytestmark = pytest.mark.tier1

N, DIM = 6000, 8


def _docs_session(rows=N, **kw):
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(rows, DIM)).astype(np.float32)
    cat = rng.integers(0, 4, rows).astype(np.int64)
    sess = SharkSession(num_workers=2, **kw)
    sess.create_table("docs", Schema.of(id=DType.INT64, cat=DType.INT64),
                      {"id": np.arange(rows, dtype=np.int64), "cat": cat,
                       "emb": emb}, num_partitions=4)
    return sess, emb, cat


def _oracle(emb, cat, c, q, k):
    s = emb.astype(np.float64) @ q
    idx = np.nonzero(cat == c)[0] if c is not None else np.arange(len(s))
    return idx[np.argsort(-s[idx], kind="stable")[:k]]


def test_embedding_lanes_in_catalog():
    sess, emb, _ = _docs_session()
    t = sess.catalog.get("docs")
    assert t.embeddings == {"emb": [f"emb_{i}" for i in range(DIM)]}
    got = sess.sql_np("SELECT emb_3 FROM docs")["emb_3"]
    np.testing.assert_array_equal(got, emb[:, 3])
    sess.shutdown()


def test_embedding_lane_name_collision_rejected():
    from repro.core.columnar import from_arrays
    with pytest.raises(ValueError, match="emb_0"):
        from_arrays("t", Schema.of(emb_0=DType.FLOAT32),
                    {"emb_0": np.zeros(4, np.float32),
                     "emb": np.zeros((4, 2), np.float32)}, 1)


def test_similarity_join_matches_oracle_with_filter_below():
    sess, emb, cat = _docs_session()
    rng = np.random.default_rng(1)
    q = rng.normal(size=DIM)
    f = sess.table("docs").filter(col("cat") == 2).similarity_join(
        "emb", q, 25)
    plan = f.explain()
    # the filter sits BELOW the score projection: it prunes before scoring
    assert plan.index("Filter") > plan.index("Project")
    res = f.to_numpy()
    np.testing.assert_array_equal(res["id"], _oracle(emb, cat, 2, q, 25))
    np.testing.assert_allclose(res["score"],
                               emb.astype(np.float64)[res["id"]] @ q)
    sess.shutdown()


def test_similarity_join_sql_twin_same_plan():
    """The frame call lowers to the exact plan of its SQL twin — one
    fingerprint, one result-cache entry (non-negative weights: the SQL
    parser desugars unary minus to `0 - x`, which would differ textually)."""
    sess, emb, cat = _docs_session()
    q = np.array([1.5, 0.25, 2.0, 0.5, 1.0, 0.75, 3.0, 0.125])
    f = sess.table("docs").filter(col("cat") == 1).similarity_join(
        "emb", q, 10)
    lanes = " + ".join(f"emb_{i} * {float(w)!r}" for i, w in enumerate(q))
    cols = ", ".join(["id", "cat"] + [f"emb_{i}" for i in range(DIM)])
    twin = sess.sql(
        f"SELECT {cols}, {lanes} AS score FROM docs WHERE cat = 1 "
        f"ORDER BY score DESC LIMIT 10", lazy=True)
    assert f.explain() == twin.explain()
    np.testing.assert_array_equal(twin.to_numpy()["id"],
                                  _oracle(emb, cat, 1, q, 10))
    sess.shutdown()


@pytest.mark.kernels_interpret
def test_similarity_join_topk_kernel_route():
    sess, emb, cat = _docs_session(
        rows=20_000,
        pde_config=PDEConfig(segment_force_kernels=True))
    q = np.random.default_rng(2).normal(size=DIM)
    f = sess.table("docs").similarity_join("emb", q, 12)
    res = f.to_numpy()
    routes = sess.metrics().segment_routes()
    assert routes.get("topk_similarity", 0) > 0, routes
    np.testing.assert_array_equal(res["id"], _oracle(emb, cat, None, q, 12))
    sess.shutdown()


def test_similarity_join_error_paths():
    sess, _, _ = _docs_session(rows=200)
    q = np.zeros(DIM)
    with pytest.raises(FrameBindError, match="no embedding"):
        sess.table("docs").similarity_join("nope", q, 5)
    with pytest.raises(FrameBindError, match="lanes"):
        sess.table("docs").similarity_join("emb", q[:3], 5)
    with pytest.raises(FrameBindError, match="already exists"):
        sess.table("docs").similarity_join("emb", q, 5, score_col="id")
    with pytest.raises(FrameBindError, match="1 lanes"):
        # projecting away lanes breaks the embedding: the prefix fallback
        # finds only emb_0 and the 8-component query no longer fits
        sess.table("docs").select("id", "emb_0").similarity_join(
            "emb", q, 5)
    with pytest.raises(FrameBindError, match="no embedding"):
        sess.table("docs").select("id").similarity_join("emb", q, 5)
    sess.shutdown()


def test_similarity_join_prefix_fallback_after_projection():
    """A derived frame that keeps ALL lanes (but is no longer a bare scan
    walkable to the catalog) resolves lanes by name prefix."""
    from repro.core.functions import count
    sess, emb, cat = _docs_session()
    q = np.random.default_rng(3).normal(size=DIM)
    base = sess.table("docs").filter(col("cat") == 0)
    agg = (sess.table("docs").group_by(col("cat"))
           .agg(count(col("id")).alias("n")))
    joined = base.join(agg, on=("cat", "cat"))
    res = joined.similarity_join("emb", q, 8).to_numpy()
    np.testing.assert_array_equal(res["id"], _oracle(emb, cat, 0, q, 8))
    sess.shutdown()


def test_similarity_search_under_server_concurrency():
    """3 concurrent sessions storm filtered similarity searches through the
    fair scheduler — zero wrong results."""
    from repro.server import SharkServer
    rng = np.random.default_rng(4)
    rows = 4000
    emb = rng.normal(size=(rows, DIM)).astype(np.float32)
    cat = rng.integers(0, 3, rows).astype(np.int64)
    srv = SharkServer(num_workers=2, max_threads=4,
                      max_concurrent_queries=3, enable_result_cache=False,
                      default_partitions=4)
    srv.create_table("docs", Schema.of(id=DType.INT64, cat=DType.INT64),
                     {"id": np.arange(rows, dtype=np.int64), "cat": cat,
                      "emb": emb})
    wrong = [0, 0, 0]

    def storm(slot):
        sess = SharkSession(server=srv, client_id=f"sim-{slot}")
        srng = np.random.default_rng(50 + slot)
        for _ in range(3):
            c = int(srng.integers(0, 3))
            q = srng.normal(size=DIM)
            got = (sess.table("docs").filter(col("cat") == c)
                   .similarity_join("emb", q, 15).to_numpy())
            if not np.array_equal(got["id"], _oracle(emb, cat, c, q, 15)):
                wrong[slot] += 1

    threads = [threading.Thread(target=storm, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(wrong) == 0, wrong
    srv.shutdown()
