"""Compiled exchange (DESIGN.md §11): dictionary-preserving shuffle +
compiled reduce-side aggregation merge and join probe.

Covers the tentpole surface unit by unit — dictionary merge-remap concat,
decode-free string shuffles (asserted via the expr.DECODE_COUNTERS row
counter), the CompiledMerge / CompiledProbe jitted reduce kernels against
their interpreted oracles, int64-exact aggregation above 2^53, the
left-join string NULL fix, reduce-side route records in
ExecMetrics.segments, plan-fingerprint/explain invariance across exchange
modes, and (kernels_interpret-marked) the radix_partition and
segmented_merge Pallas kernels forced on CPU.
"""

import numpy as np
import pytest

from repro.core import DType, Schema, SharkSession
from repro.core.aggregate import (CompiledMerge, merge_aggregate,
                                  partial_aggregate)
from repro.core.batch import PartitionBatch, merge_string_dicts
from repro.core.expr import (Col, ColumnVal, DECODE_COUNTERS,
                             reset_decode_counters)
from repro.core.joins import _match_pairs, compile_probe, join_local
from repro.core.pde import PDEConfig, decide_reduce_backend
from repro.core.plan import AggFunc, AggSpec

pytestmark = pytest.mark.tier1

SESSION_KW = dict(num_workers=2, max_threads=4, default_partitions=3,
                  default_shuffle_buckets=4)


# ---------------------------------------------------------------------------
# dictionary-preserving concat
# ---------------------------------------------------------------------------


def test_merge_string_dicts_unifies_and_remaps():
    d1 = np.array(["b", "d", "f"])
    d2 = np.array(["a", "d", "z"])
    unified, (r1, r2) = merge_string_dicts([d1, d2])
    assert unified.tolist() == ["a", "b", "d", "f", "z"]
    assert unified[r1].tolist() == d1.tolist()
    assert unified[r2].tolist() == d2.tolist()


def test_concat_preserves_dictionaries_without_decoding():
    b1 = PartitionBatch.from_numpy({"s": np.array(["b", "a", "b"]),
                                    "v": np.array([1.0, 2.0, 3.0])})
    b2 = PartitionBatch.from_numpy({"s": np.array(["c", "a"]),
                                    "v": np.array([4.0, 5.0])})
    reset_decode_counters()
    merged = PartitionBatch.concat([b1, b2])
    assert DECODE_COUNTERS["string_rows"] == 0
    sv = merged.cols["s"]
    assert sv.is_string and sv.sorted_dict
    assert sv.sdict.tolist() == ["a", "b", "c"]
    assert sv.decoded().tolist() == ["b", "a", "b", "c", "a"]
    assert np.asarray(merged.cols["v"].arr).tolist() == [1, 2, 3, 4, 5]


def test_concat_normalizes_unsorted_transform_dicts():
    # a string-function output: unsorted, duplicate-bearing dictionary
    codes = np.array([0, 1, 2], np.int32)
    d = np.array(["bb", "aa", "bb"])
    piece = PartitionBatch({"s": ColumnVal(codes, d, sorted_dict=False)})
    merged = PartitionBatch.concat([piece])
    sv = merged.cols["s"]
    assert sv.sorted_dict and sv.sdict.tolist() == ["aa", "bb"]
    assert sv.decoded().tolist() == ["bb", "aa", "bb"]


# ---------------------------------------------------------------------------
# compiled join probe
# ---------------------------------------------------------------------------


def test_compiled_probe_matches_oracle():
    rng = np.random.default_rng(7)
    probe = compile_probe()
    for _ in range(25):
        lk = rng.integers(0, 40, rng.integers(0, 200)).astype(np.int64)
        rk = rng.integers(0, 40, rng.integers(0, 200)).astype(np.int64)
        l1, r1 = _match_pairs(lk, rk)
        l2, r2 = probe(lk, rk)
        assert np.array_equal(l1, l2) and np.array_equal(r1, r2)


def test_compiled_probe_sentinel_collision():
    """Real keys equal to the padding sentinel (int64 max / +inf) must not
    match the pad region."""
    probe = compile_probe()
    lk = np.array([2**63 - 1, 5], np.int64)
    rk = np.array([5, 2**63 - 1, 2**63 - 1], np.int64)
    l1, r1 = _match_pairs(lk, rk)
    l2, r2 = probe(lk, rk)
    assert np.array_equal(l1, l2) and np.array_equal(r1, r2)
    lkf = np.array([np.inf, 1.5])
    rkf = np.array([np.inf, 1.5, np.inf])
    l1, r1 = _match_pairs(lkf, rkf)
    l2, r2 = probe(lkf, rkf)
    assert np.array_equal(l1, l2) and np.array_equal(r1, r2)


def test_compiled_probe_nan_keys_fall_back():
    """NaN float keys sort after the +inf pad sentinel, breaking the
    padding invariant — the probe must refuse (TypeError) and the reduce
    runner must fall back to the interpreted oracle."""
    from repro.core.pde import PDEConfig
    from repro.core.physical import ReduceRunner, SegmentRecord
    probe = compile_probe()
    with pytest.raises(TypeError):
        probe(np.array([1.0, np.nan]), np.array([np.nan, 1.0]))
    rec = SegmentRecord(table="<exchange>", depth=1, consumer="join_probe",
                        outputs=[], pred=None)
    rr = ReduceRunner("compiled", PDEConfig(reduce_force_compiled=True), rec)
    l = PartitionBatch.from_numpy({"k": np.array([1.0, np.nan]),
                                   "lv": np.array([1.0, 2.0])})
    r = PartitionBatch.from_numpy({"k": np.array([np.nan, 1.0]),
                                   "rv": np.array([9.0, 8.0])})
    out = rr.join(l, r, "k", "k", "inner")
    ref = join_local(l, r, "k", "k", "inner")
    assert np.array_equal(np.asarray(out.cols["lv"].arr),
                          np.asarray(ref.cols["lv"].arr))
    assert rec.fallbacks == 1 and rec.routes.get("numpy") == 1


def test_compiled_probe_bool_keys_fall_back():
    """BOOL keys have no iinfo pad sentinel: the probe must refuse with
    TypeError (not ValueError) so the reduce runner's oracle fallback
    engages instead of failing the query."""
    with pytest.raises(TypeError):
        compile_probe()(np.array([True, False]), np.array([False, True]))


def test_dict_hash_cache_hits_and_evicts():
    import gc

    from repro.core.shuffle import _DICT_HASH_CACHE, _dict_hashes
    d = np.array(["alpha", "beta"])
    h1 = _dict_hashes(d)
    assert _dict_hashes(d) is h1        # memoized per dictionary object
    key_count = len(_DICT_HASH_CACHE)
    del d
    gc.collect()
    assert len(_DICT_HASH_CACHE) < key_count    # finalizer evicted it


def test_compiled_probe_empty_sides():
    probe = compile_probe()
    empty = np.zeros(0, np.int64)
    keys = np.array([1, 2], np.int64)
    for lk, rk in ((empty, keys), (keys, empty), (empty, empty)):
        l2, r2 = probe(lk, rk)
        assert len(l2) == 0 and len(r2) == 0


# ---------------------------------------------------------------------------
# compiled merge + int64 exactness
# ---------------------------------------------------------------------------


def _specs():
    return [AggSpec("s", AggFunc.SUM, Col("v")),
            AggSpec("mn", AggFunc.MIN, Col("v")),
            AggSpec("mx", AggFunc.MAX, Col("v")),
            AggSpec("c", AggFunc.COUNT, None),
            AggSpec("a", AggFunc.AVG, Col("v"))]


def test_compiled_merge_matches_oracle():
    rng = np.random.default_rng(3)
    aggs = _specs()
    pieces = []
    for _ in range(4):
        n = int(rng.integers(1, 50))
        batch = PartitionBatch.from_numpy({
            "g": np.array([f"g{i}" for i in rng.integers(0, 6, n)]),
            "v": rng.uniform(-10, 10, n)})
        pieces.append(partial_aggregate(batch, ["g"], aggs))
    merged = PartitionBatch.concat(pieces)
    ref = merge_aggregate(merged, ["g"], aggs)
    got = CompiledMerge(["g"], aggs)(merged)
    assert ref.cols["g"].decoded().tolist() == got.cols["g"].decoded().tolist()
    for k in ("s", "mn", "mx", "a"):
        np.testing.assert_allclose(np.asarray(got.cols[k].arr),
                                   np.asarray(ref.cols[k].arr), rtol=1e-12)
    assert np.array_equal(np.asarray(got.cols["c"].arr),
                          np.asarray(ref.cols["c"].arr))


def test_int64_aggregates_exact_above_2_53():
    """SUM/MIN/MAX of int64 values above 2^53 must not round-trip through
    float64 — deterministic values whose float64 images collide."""
    base = 2**60
    vals = np.array([base + 1, base + 3, base + 1, base + 7, base + 2],
                    np.int64)
    grp = np.array(["x", "y", "x", "y", "x"])
    aggs = [AggSpec("s", AggFunc.SUM, Col("v")),
            AggSpec("mn", AggFunc.MIN, Col("v")),
            AggSpec("mx", AggFunc.MAX, Col("v"))]
    batch = PartitionBatch.from_numpy({"g": grp, "v": vals})
    part = partial_aggregate(batch, ["g"], aggs)
    for out in (merge_aggregate(part, ["g"], aggs),
                CompiledMerge(["g"], aggs)(part)):
        order = np.argsort(out.cols["g"].decoded())
        s = np.asarray(out.cols["s"].arr)[order]
        assert s.dtype == np.int64
        assert s.tolist() == [3 * base + 4, 2 * base + 10]
        assert np.asarray(out.cols["mn"].arr)[order].tolist() == \
            [base + 1, base + 3]
        assert np.asarray(out.cols["mx"].arr)[order].tolist() == \
            [base + 2, base + 7]


def test_int64_sum_exact_through_sql():
    """End-to-end: the engine's default (compiled) path keeps integer sums
    integer across partial -> shuffle -> merge."""
    base = 2**60
    n = 96
    vals = (base + np.arange(1, n + 1)).astype(np.int64)
    grp = np.array(["a", "b"] * (n // 2))
    for kw in (dict(), dict(pde_config=PDEConfig(reduce_force_compiled=True))):
        sess = SharkSession(**SESSION_KW, **kw)
        sess.create_table("t", Schema.of(g=DType.STRING, v=DType.INT64),
                          {"g": grp, "v": vals})
        got = sess.sql_np("SELECT g, SUM(v) AS s, MIN(v) AS mn, "
                          "MAX(v) AS mx FROM t GROUP BY g")
        order = np.argsort(got["g"])
        for g, s, mn, mx in zip(np.asarray(got["g"])[order],
                                np.asarray(got["s"])[order],
                                np.asarray(got["mn"])[order],
                                np.asarray(got["mx"])[order]):
            mask = grp == g
            assert int(s) == int(vals[mask].sum())
            assert int(mn) == int(vals[mask].min())
            assert int(mx) == int(vals[mask].max())
        sess.shutdown()


def test_compiled_merge_refuses_count_distinct():
    from repro.core.expr import ExprCompileError
    with pytest.raises(ExprCompileError):
        CompiledMerge(["g"], [AggSpec("d", AggFunc.COUNT_DISTINCT,
                                      Col("v"))])


# ---------------------------------------------------------------------------
# left join NULL emulation for strings
# ---------------------------------------------------------------------------


def test_left_join_string_nulls():
    """Regression: right-side STRING columns of unmatched left rows used to
    keep row 0's value; they must take the reserved null code ("")."""
    left = PartitionBatch.from_numpy({
        "lk": np.array([1, 2, 3, 4], np.int64),
        "lv": np.array([10.0, 20.0, 30.0, 40.0])})
    right = PartitionBatch.from_numpy({
        "rk": np.array([1, 3], np.int64),
        "rs": np.array(["hit1", "hit3"]),
        "rv": np.array([100.0, 300.0])})
    out = join_local(left, right, "lk", "rk", how="left")
    rows = sorted(zip(np.asarray(out.cols["lk"].arr).tolist(),
                      out.cols["rs"].decoded().tolist(),
                      np.asarray(out.cols["rv"].arr).tolist()))
    assert rows == [(1, "hit1", 100.0), (2, "", 0.0),
                    (3, "hit3", 300.0), (4, "", 0.0)]


def test_left_join_string_nulls_compiled_probe():
    left = PartitionBatch.from_numpy({
        "lk": np.array([1, 2], np.int64), "lv": np.array([1.0, 2.0])})
    right = PartitionBatch.from_numpy({
        "rk": np.array([2], np.int64), "rs": np.array(["only2"])})
    out = join_local(left, right, "lk", "rk", how="left",
                     matcher=compile_probe())
    rows = sorted(zip(np.asarray(out.cols["lk"].arr).tolist(),
                      out.cols["rs"].decoded().tolist()))
    assert rows == [(1, ""), (2, "only2")]


def test_left_join_empty_right_side():
    left = PartitionBatch.from_numpy({
        "lk": np.array([7, 8], np.int64), "lv": np.array([1.0, 2.0])})
    right = PartitionBatch.from_numpy({
        "rk": np.zeros(0, np.int64), "rs": np.zeros(0, np.str_),
        "rv": np.zeros(0, np.float64)})
    out = join_local(left, right, "lk", "rk", how="left")
    assert np.asarray(out.cols["lk"].arr).tolist() == [7, 8]
    assert out.cols["rs"].decoded().tolist() == ["", ""]
    assert np.asarray(out.cols["rv"].arr).tolist() == [0.0, 0.0]


def test_string_join_keys_never_decode():
    left = PartitionBatch.from_numpy({
        "k": np.array(["a", "b", "c", "b"]), "lv": np.arange(4.0)})
    right = PartitionBatch.from_numpy({
        "k": np.array(["b", "z", "a"]), "rv": np.arange(3.0)})
    reset_decode_counters()
    out = join_local(left, right, "k", "k", how="inner")
    assert DECODE_COUNTERS["string_rows"] == 0
    assert sorted(out.cols["k"].decoded().tolist()) == ["a", "b", "b"]


# ---------------------------------------------------------------------------
# decode-free exchange end to end + route records + fingerprints
# ---------------------------------------------------------------------------


def _data(seed=0, n=3000):
    rng = np.random.default_rng(seed)
    return {
        "g": np.array([f"u{i:04d}" for i in rng.integers(0, 500, n)]),
        "v": rng.uniform(0, 10, n),
        "k": rng.integers(0, 40, n).astype(np.int64),
    }


SCHEMA = Schema.of(g=DType.STRING, v=DType.FLOAT64, k=DType.INT64)


def _mk(exchange="coded", **kw):
    sess = SharkSession(**SESSION_KW, exchange=exchange, **kw)
    sess.create_table("t", SCHEMA, _data())
    sess.create_table("d", Schema.of(dk=DType.INT64, ds=DType.STRING),
                      {"dk": np.arange(40, dtype=np.int64),
                       "ds": np.array([f"d{i % 5}" for i in range(40)])})
    return sess


def test_coded_exchange_is_decode_free():
    sess = _mk()
    queries = [
        "SELECT g, COUNT(*) AS c, SUM(v) AS s FROM t GROUP BY g",
        "SELECT ds, SUM(v) AS s FROM t JOIN d ON t.k = d.dk GROUP BY ds",
        "SELECT g, v FROM t ORDER BY g LIMIT 7",
    ]
    for q in queries:
        reset_decode_counters()
        sess.sql(q)          # execute eagerly, but don't materialize results
        assert DECODE_COUNTERS["string_rows"] == 0, \
            f"shuffle path decoded strings\n  {q}"
    sess.shutdown()


def test_exchange_modes_agree_row_identically():
    coded, decoded = _mk("coded"), _mk("decoded")
    queries = [
        "SELECT g, COUNT(*) AS c, SUM(v) AS s FROM t GROUP BY g",
        "SELECT ds, COUNT(*) AS c FROM t JOIN d ON t.k = d.dk GROUP BY ds",
        "SELECT g, v FROM t ORDER BY g, v LIMIT 25",
    ]
    for q in queries:
        a, b = coded.sql_np(q), decoded.sql_np(q)
        for col in a:
            av, bv = np.asarray(a[col]), np.asarray(b[col])
            oa = np.lexsort([np.asarray(a[c]).astype(str) for c in a])
            ob = np.lexsort([np.asarray(b[c]).astype(str) for c in b])
            if av.dtype.kind == "f":
                np.testing.assert_allclose(av[oa], bv[ob], rtol=1e-9)
            else:
                assert av[oa].tolist() == bv[ob].tolist(), (q, col)
    coded.shutdown()
    decoded.shutdown()


def test_exchange_mode_leaves_plans_untouched():
    """explain() and plan_fingerprint are functions of the logical plan;
    the exchange is physical-layer only — byte-identical across modes."""
    from repro.core.plan import optimize
    from repro.server.result_cache import plan_fingerprint
    coded, decoded = _mk("coded"), _mk("decoded")
    q = ("SELECT ds, SUM(v) AS s FROM t JOIN d ON t.k = d.dk "
         "WHERE v > 1.5 GROUP BY ds ORDER BY s LIMIT 3")
    assert coded.explain(q) == decoded.explain(q)
    fp_c, _ = plan_fingerprint(optimize(coded.plan(q), coded.catalog),
                               coded.catalog)
    fp_d, _ = plan_fingerprint(optimize(decoded.plan(q), decoded.catalog),
                               decoded.catalog)
    assert fp_c == fp_d
    coded.shutdown()
    decoded.shutdown()


def test_reduce_routes_recorded_in_metrics():
    sess = _mk(pde_config=PDEConfig(reduce_force_compiled=True))
    sess.sql("SELECT ds, SUM(v) AS s FROM t JOIN d ON t.k = d.dk GROUP BY ds")
    m = sess.metrics()
    consumers = {s.consumer for s in m.segments}
    assert "merge_aggregate" in consumers
    assert "join_probe" in consumers
    for s in m.segments:
        if s.consumer in ("merge_aggregate", "join_probe"):
            assert s.partitions > 0
            assert all(r != "numpy" for r in s.routes), s.describe()
    sess.shutdown()


def test_reduce_routes_numpy_for_tiny_and_oracle_backend():
    sess = _mk()     # default threshold: tiny reduce tasks stay interpreted
    sess.sql("SELECT g, COUNT(*) AS c FROM t GROUP BY g")
    m = sess.metrics()
    merges = [s for s in m.segments if s.consumer == "merge_aggregate"]
    assert merges and all(set(s.routes) == {"numpy"} for s in merges)
    sess.shutdown()
    oracle = _mk(backend="numpy")
    oracle.sql("SELECT ds, SUM(v) AS s FROM t JOIN d ON t.k = d.dk "
               "GROUP BY ds")
    m = oracle.metrics()
    assert m.compiled_partitions() == 0
    oracle.shutdown()


def test_decide_reduce_backend_routes():
    cfg = PDEConfig()
    assert decide_reduce_backend(10, cfg=cfg).route == "numpy"
    # on CPU, host numpy is the reduce fast path even for large tasks
    assert decide_reduce_backend(100_000, cfg=cfg).route == "numpy"
    assert decide_reduce_backend(100_000, on_tpu=True, cfg=cfg).route == "jit"
    # tiny bucket groups stay interpreted even on TPU
    assert decide_reduce_backend(10, on_tpu=True, cfg=cfg).route == "numpy"
    forced = PDEConfig(reduce_force_compiled=True)
    assert decide_reduce_backend(10, cfg=forced).route == "jit"
    kcfg = PDEConfig(segment_force_kernels=True,
                     reduce_force_compiled=True)
    assert decide_reduce_backend(
        100_000, "segmented_merge", 32, cfg=kcfg).route == "segmented_merge"
    assert decide_reduce_backend(
        100_000, "segmented_merge", 10_000, cfg=kcfg).route == "jit"
    assert decide_reduce_backend(
        100_000, "segmented_merge", 32, on_tpu=True,
        cfg=cfg).route == "segmented_merge"


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.kernels_interpret
def test_segmented_merge_kernel_matches_numpy():
    from repro.core.expr import _x64
    from repro.kernels import ops
    rng = np.random.default_rng(11)
    n, num_groups = 3000, 19
    codes = rng.integers(0, num_groups, n).astype(np.int32)
    vals = rng.uniform(-5, 5, n)
    with _x64():
        res = np.asarray(ops.segmented_merge(codes, vals, num_groups,
                                             acc_dtype="float64"))
    np.testing.assert_allclose(
        res[:, 0], np.bincount(codes, weights=vals, minlength=num_groups),
        rtol=1e-12)
    assert np.array_equal(res[:, 1].astype(np.int64),
                          np.bincount(codes, minlength=num_groups))
    for g in range(num_groups):
        sel = vals[codes == g]
        assert np.isclose(res[g, 2], sel.min())
        assert np.isclose(res[g, 3], sel.max())


@pytest.mark.kernels_interpret
def test_segmented_merge_kernel_empty_groups():
    from repro.core.expr import _x64
    from repro.kernels import ops
    codes = np.array([0, 2, 2], np.int32)     # group 1 empty
    vals = np.array([1.0, 2.0, 3.0])
    with _x64():
        res = np.asarray(ops.segmented_merge(codes, vals, 3,
                                             acc_dtype="float64"))
    assert res[1, 1] == 0 and res[1, 2] == np.inf and res[1, 3] == -np.inf


@pytest.mark.kernels_interpret
def test_radix_partition_kernel_matches_reference():
    from repro.kernels import ops
    from repro.kernels.radix_partition import (fold_keys_u32,
                                               radix_partition_ref)
    rng = np.random.default_rng(5)
    keys = rng.integers(-2**62, 2**62, 5000).astype(np.int64)
    folded = fold_keys_u32(keys)
    for nb in (4, 16, 130):
        b, c = ops.radix_partition(folded, nb)
        rb, rc = radix_partition_ref(folded, nb)
        assert np.array_equal(np.asarray(b), rb)
        assert np.array_equal(np.asarray(c), rc)
        assert int(np.asarray(c).sum()) == len(keys)


@pytest.mark.kernels_interpret
def test_forced_kernel_session_uses_radix_and_segmented_merge():
    from repro.core.shuffle import RADIX_KERNEL_CALLS
    before = RADIX_KERNEL_CALLS["count"]
    sess = _mk(pde_config=PDEConfig(segment_force_kernels=True,
                                    reduce_force_compiled=True))
    ref = _mk()
    q = "SELECT ds, SUM(v) AS s FROM t JOIN d ON t.k = d.dk GROUP BY ds"
    got, want = sess.sql_np(q), ref.sql_np(q)
    og, ow = np.argsort(got["ds"]), np.argsort(want["ds"])
    assert np.asarray(got["ds"])[og].tolist() == \
        np.asarray(want["ds"])[ow].tolist()
    np.testing.assert_allclose(np.asarray(got["s"])[og],
                               np.asarray(want["s"])[ow], rtol=1e-9)
    assert RADIX_KERNEL_CALLS["count"] > before
    routes = sess.metrics().segment_routes()
    assert routes.get("segmented_merge", 0) > 0, routes
    sess.shutdown()
    ref.shutdown()
