"""Property tests (hypothesis): join-order invariance and ordering-cost
sanity for 3-table star joins.

  1. Every valid left-deep join order of the same 3-table query produces
     row-identical results (joins are commutative/associative for inner
     equi-joins — and PDE's per-boundary strategy choices must not change
     that).
  2. The optimizer's chosen order never loses to the WORST order on
     estimated cost (plan.estimate_plan_cost, the objective order_joins
     minimizes).

A deterministic single-dataset twin of these properties runs unconditionally
in tests/test_multiway_join.py; this file explores random data shapes when
hypothesis is installed.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import DType, Schema, SharkSession
from repro.core.plan import estimate_plan_cost, optimize

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def sess():
    s = SharkSession(num_workers=2, max_threads=2, default_partitions=3,
                     default_shuffle_buckets=4)
    yield s
    s.shutdown()


def _register(sess, seed: int):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(100, 1500))
    d1 = int(rng.integers(3, 40))
    d2 = int(rng.integers(3, 40))
    sess.create_table("pf", Schema.of(
        k1=DType.INT64, k2=DType.INT64, rev=DType.FLOAT64),
        {"k1": rng.integers(0, d1, n).astype(np.int64),
         "k2": rng.integers(0, d2, n).astype(np.int64),
         "rev": rng.uniform(0, 10, n)})
    sess.create_table("pd1", Schema.of(p1=DType.INT64, x1=DType.INT64),
                      {"p1": np.arange(d1, dtype=np.int64),
                       "x1": rng.integers(0, 5, d1).astype(np.int64)})
    sess.create_table("pd2", Schema.of(p2=DType.INT64, x2=DType.INT64),
                      {"p2": np.arange(d2, dtype=np.int64),
                       "x2": rng.integers(0, 5, d2).astype(np.int64)})


def _orders(sess):
    """All valid left-deep join orders of pf ⋈ pd1 ⋈ pd2 as frames (each
    newly attached relation must connect via an equi predicate)."""
    f, a, b = (lambda: sess.table("pf"), lambda: sess.table("pd1"),
               lambda: sess.table("pd2"))
    return [
        f().join(a(), on=("k1", "p1")).join(b(), on=("k2", "p2")),
        f().join(b(), on=("k2", "p2")).join(a(), on=("k1", "p1")),
        a().join(f(), on=("p1", "k1")).join(b(), on=("k2", "p2")),
        b().join(f(), on=("p2", "k2")).join(a(), on=("k1", "p1")),
    ]


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(seed=st.integers(0, 2**31 - 1))
def test_all_join_orders_row_identical(sess, seed):
    _register(sess, seed)
    results = []
    for frame in _orders(sess):
        out = frame.select("rev", "x1", "x2").to_numpy()
        rows = sorted(zip(np.round(out["rev"], 9).tolist(),
                          out["x1"].tolist(), out["x2"].tolist()))
        results.append(rows)
    assert all(r == results[0] for r in results[1:]), \
        "join orders disagree on result rows"


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(seed=st.integers(0, 2**31 - 1))
def test_chosen_order_never_loses_to_worst(sess, seed):
    _register(sess, seed)
    raw_costs = [estimate_plan_cost(fr.logical_plan(), sess.catalog)
                 for fr in _orders(sess)]
    chosen_costs = [estimate_plan_cost(fr.optimized_plan(), sess.catalog)
                    for fr in _orders(sess)]
    worst = max(raw_costs)
    for c in chosen_costs:
        assert c <= worst + 1e-9, \
            f"optimizer chose cost {c} > worst raw order {worst}"
