"""Shared test fixtures.

`SHARK_SPILL_DIR` isolation: the storage tier (DESIGN.md §12) writes spill
segments to the directory named by this env var (falling back to a private
mkdtemp).  Tests must never share spill state with each other or with
whatever the developer's shell exports, so every test gets a fresh tmpdir.
"""

import pytest


@pytest.fixture(autouse=True)
def _isolated_spill_dir(tmp_path, monkeypatch):
    spill = tmp_path / "spill"
    spill.mkdir()
    monkeypatch.setenv("SHARK_SPILL_DIR", str(spill))
    yield str(spill)
