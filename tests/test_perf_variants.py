"""Perf-variant correctness: every §Perf optimization must preserve model
semantics (EXPERIMENTS.md iteration log)."""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.attention import _blockwise_attention
from repro.models.flash import flash_attention

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(0)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,kv_chunk", [(64, 16), (128, 64), (32, 32)])
def test_flash_matches_blockwise(causal, s, kv_chunk):
    b, h, kv, hd = 2, 8, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o1 = np.asarray(flash_attention(q, k, v, pos, kv_chunk, causal),
                    np.float32)
    o2 = np.asarray(_blockwise_attention(q, k, v, pos, kv_chunk, causal),
                    np.float32)
    np.testing.assert_allclose(o1, o2, rtol=0.05, atol=0.05)


def test_flash_gradients_match_autodiff():
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, hd)), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def lf(q, k, v):
        return jnp.sum(flash_attention(q, k, v, pos, 16, True)
                       .astype(jnp.float32) ** 2)

    def lr(q, k, v):
        return jnp.sum(_blockwise_attention(q, k, v, pos, 16, True)
                       .astype(jnp.float32) ** 2)

    gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        a32, b32 = np.asarray(a, np.float32), np.asarray(b_, np.float32)
        assert np.abs(a32 - b32).max() / (np.abs(b32).max() + 1e-9) < 0.06


def test_scores_bf16_loss_close():
    cfg = get_config("yi-9b-smoke")
    cfg_bf = dataclasses.replace(cfg, attn_scores_dtype="bf16")
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 64))
                                   .astype(np.int32)),
             "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 64))
                                   .astype(np.int32))}
    l1 = float(lm.loss_fn(cfg, params, batch))
    l2 = float(lm.loss_fn(cfg_bf, params, batch))
    assert abs(l1 - l2) < 0.02


def test_flash_variant_full_model():
    cfg = dataclasses.replace(get_config("phi3-medium-14b-smoke"),
                              attn_impl="flash")
    base = get_config("phi3-medium-14b-smoke")
    params, _ = lm.init_params(base, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(RNG.integers(0, base.vocab, (2, 64))
                                   .astype(np.int32)),
             "labels": jnp.asarray(RNG.integers(0, base.vocab, (2, 64))
                                   .astype(np.int32))}
    l1 = float(lm.loss_fn(base, params, batch))
    l2 = float(lm.loss_fn(cfg, params, batch))
    assert abs(l1 - l2) < 0.02, (l1, l2)


def test_kv_int8_decode_close():
    cfg = get_config("phi3-medium-14b-smoke")
    cfgq = dataclasses.replace(cfg, kv_cache_quant=True)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S, MAXS = 2, 48, 64
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, S))
                                   .astype(np.int32))}
    lg1, c1 = lm.prefill_fn(cfg, params, batch, MAXS)
    lg2, c2 = lm.prefill_fn(cfgq, params, batch, MAXS)
    assert c2["k"].dtype == jnp.int8
    assert "k_scale" in c2
    tok = jnp.argmax(lg1[:, 0], -1).astype(jnp.int32)[:, None]
    d1, _ = lm.decode_fn(cfg, params, tok, c1, jnp.int32(S))
    d2, _ = lm.decode_fn(cfgq, params, tok, c2, jnp.int32(S))
    p1 = jax.nn.softmax(d1[:, 0], -1)
    p2 = jax.nn.softmax(d2[:, 0], -1)
    tv = 0.5 * np.abs(np.asarray(p1) - np.asarray(p2)).sum(-1).max()
    assert tv < 0.05
    assert (np.asarray(jnp.argmax(d1[:, 0], -1))
            == np.asarray(jnp.argmax(d2[:, 0], -1))).all()


@pytest.mark.xfail(
    reason="known jax-0.4.37 bug: shard_map EP MoE mis-lowers through XLA "
           "on host-platform debug meshes and diverges from the GSPMD "
           "reference (pre-existing since the seed; tracked so tier-1 stays "
           "green and NEW regressions in this test become visible)",
    strict=False)
def test_moe_ep_matches_gspmd_subprocess():
    """EP shard_map MoE vs GSPMD MoE on a 8-device debug mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_debug_mesh
from repro.models.moe import MoEConfig, moe_apply, moe_apply_ep, moe_init
from repro.parallel.compat import set_mesh
mesh = make_debug_mesh(2, 4)
cfg = MoEConfig(num_experts=8, top_k=2, d_expert=32, capacity_factor=4.0)
p, _ = moe_init(jax.random.PRNGKey(0), 64, cfg)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(4, 16, 64)).astype(np.float32)).astype(jnp.bfloat16)
with set_mesh(mesh):
    y1 = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, x)
    y2 = jax.jit(lambda p, x: moe_apply_ep(p, x, cfg))(p, x)
rel = np.abs(np.asarray(y1, np.float32) - np.asarray(y2, np.float32)).max()
assert rel < 1e-2, rel
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
